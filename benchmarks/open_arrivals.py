"""Open-arrival sweep (beyond paper): OURS vs ORACLE vs PAIRWISE under a
continuous Poisson job stream, reported as windowed STP/ANTT.

The paper's Fig. 6 drains a closed batch; a production cluster never
drains. This sweep feeds the simulator Poisson arrivals at several load
levels (jobs/s) and reports, per policy:

* overall STP/ANTT over the stream (gmean across streams),
* per-completion-window STP/ANTT (the windowed view a cluster operator
  actually watches),
* OOM kills and the online-refresher fold-in count for OURS.

    PYTHONPATH=src python -m benchmarks.run --bench open_arrivals
"""
from __future__ import annotations

import copy
from collections import Counter

from benchmarks.common import SMOKE, N_MIXES, emit, get_suite, save_result

RATES_PER_S = (0.05,) if SMOKE else (0.01, 0.05, 0.2)  # light/mod/heavy
N_JOBS = 8 if SMOKE else 30
N_HOSTS = 4 if SMOKE else 16        # small enough that load contends
WINDOW_S = 2000.0
POLICIES = ("ours", "oracle", "pairwise")


def _policy_factory(name, moe, refreshers: list):
    import os

    from repro.core.predictor import OraclePredictor
    from repro.core.simulator import (OraclePolicy, OursPolicy,
                                      PairwisePolicy)
    from repro.sched import OnlineRefresher, get_estimator

    def make(stream_seed: int):
        if name == "ours":
            # The refresher streams into the registry HANDLE
            # (DemandEstimator protocol: families / select_family /
            # partial_update) — no reaching into MoEPredictor internals.
            est_name = os.environ.get("REPRO_ESTIMATOR", "") or "moe"
            est = get_estimator(est_name, predictor=moe)
            ref = None
            if est.supports_online_update:
                # partial_update mutates the estimator's selector —
                # wrap a COPY so streams/rates stay independent and
                # reruns against the module-cached suite stay
                # reproducible (estimators that ignore the predictor
                # skip the copy entirely)
                est = get_estimator(est_name,
                                    predictor=copy.deepcopy(moe))
                ref = OnlineRefresher(est)
                refreshers.append(ref)
            return OursPolicy(estimator=est, refresher=ref)
        if name == "oracle":
            return OraclePolicy(OraclePredictor())
        if name == "pairwise":
            return PairwisePolicy()
        raise ValueError(name)
    return make


def main() -> dict:
    from repro.core.metrics import run_open_scenario
    from repro.core.simulator import SimConfig
    from repro.core.workloads import size_class_of
    from repro.sched import ArrivalConfig, poisson_arrivals

    apps, train, moe, ann = get_suite()
    n_streams = max(N_MIXES // 2, 2)
    cfg = SimConfig(n_hosts=N_HOSTS)
    payload: dict = {"rates": {}}
    for rate in RATES_PER_S:
        acfg = ArrivalConfig(rate_per_s=rate, n_jobs=N_JOBS)
        # stream composition by paper Table-4 size class (stream 0's
        # seed, matching run_open_scenario's [seed, stream] scheme)
        mix = Counter(size_class_of(a.items) for a in poisson_arrivals(
            apps, acfg, seed=[7, 0]))
        emit(f"open_arrivals/{rate}/class_mix",
             " ".join(f"{c}:{mix.get(c, 0)}"
                      for c in ("small", "medium", "large")),
             "arrivals per size class, stream 0")
        row: dict = {}
        for pol in POLICIES:
            refreshers: list = []
            r = run_open_scenario(
                apps, _policy_factory(pol, moe, refreshers),
                acfg, n_streams=n_streams, cfg=cfg, seed=7,
                window_s=WINDOW_S)
            row[pol] = r
            emit(f"open_arrivals/{rate}/{pol}/stp",
                 f"{r['stp_gmean']:.3f}", "windowed Poisson stream")
            emit(f"open_arrivals/{rate}/{pol}/antt",
                 f"{r['antt_gmean']:.3f}", "")
            emit(f"open_arrivals/{rate}/{pol}/oom", r["oom_total"], "")
            if refreshers:
                acc = sum(x.accepted for x in refreshers)
                rej = sum(x.rejected for x in refreshers)
                row[pol]["refresh"] = {"accepted": acc, "rejected": rej}
                emit(f"open_arrivals/{rate}/{pol}/refresh_folds",
                     acc, f"{rej} rejected across {len(refreshers)} "
                     f"streams")
            # the operator view: STP trajectory over completion windows
            for w in r["windows"][0]:
                if w["completed"]:
                    emit(f"open_arrivals/{rate}/{pol}"
                         f"/window_{int(w['t0'])}",
                         f"{w['stp']:.3f}",
                         f"antt={w['antt']:.2f}; {w['completed']} done, "
                         f"{w['in_flight']} in flight")
        frac = row["ours"]["stp_gmean"] / max(
            row["oracle"]["stp_gmean"], 1e-12)
        emit(f"open_arrivals/{rate}/ours_vs_oracle",
             f"{frac:.3f}", "fraction of oracle STP under open arrivals")
        payload["rates"][str(rate)] = row
    save_result("open_arrivals", payload)
    return payload


if __name__ == "__main__":
    main()
