"""Paper Fig. 11/12: profiling (feature extraction + calibration) time as
a fraction of total execution, per scenario and per benchmark."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.metrics import SCENARIOS, make_mix
from repro.core.simulator import OursPolicy, SimConfig, Simulator


def main() -> dict:
    apps, _, moe, _ = get_suite()
    cfg = SimConfig()
    payload = {"per_scenario": {}, "per_benchmark": {}}
    for sc, n_jobs in list(SCENARIOS.items())[:6]:
        fracs = []
        for mix in range(4):
            rng = np.random.default_rng([3, mix, n_jobs])
            jobs = make_mix(apps, n_jobs, rng)
            sim = Simulator(jobs, OursPolicy(moe), cfg, seed=mix)
            out = sim.run()
            for j, c in zip(sim.jobs, out["c_cl"]):
                fracs.append(min(j.profiled_at / max(c, 1e-9), 1.0))
        payload["per_scenario"][sc] = float(np.mean(fracs))
        emit(f"fig11_overhead_{sc}",
             round(float(np.mean(fracs)) * 100, 1), "percent of exec")
    # per-benchmark (fig 12): profiling fraction relative to isolated time
    rng = np.random.default_rng(0)
    for app in apps[:16]:
        f = float(rng.uniform(cfg.profile_frac_lo, cfg.profile_frac_hi))
        payload["per_benchmark"][app.name] = f
    avg = float(np.mean(list(payload["per_scenario"].values())))
    payload["derived"] = {"avg_overhead": avg,
                          "paper_claims": {"feature+calib": 0.13}}
    emit("fig11_avg_overhead", round(avg * 100, 1),
         "paper: ~13 percent, <10 relative to total")
    save_result("fig11", payload)
    return payload


if __name__ == "__main__":
    main()
