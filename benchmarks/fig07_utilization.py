"""Paper Fig. 7/8: CPU utilization trace + makespan for one 30-app (L10)
mix under each policy — ours should show the highest utilization and the
fastest completion."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_policies, get_suite, save_result
from repro.core.metrics import make_mix
from repro.core.simulator import SimConfig, Simulator


def main() -> dict:
    apps, _, _, _ = get_suite()
    pols = get_policies()
    payload = {n: {"mean_turnaround": [], "mean_utilization": [],
                   "stp": []} for n in ("ours", "quasar", "pairwise")}
    for mix in range(4):
        rng = np.random.default_rng([0, mix, 30])
        jobs = make_mix(apps, 30, rng)
        for name in payload:
            sim = Simulator(jobs, pols[name], SimConfig(), seed=mix)
            out = sim.run()
            trace = np.asarray(out["util_trace"])
            t, u = trace[:, 0], trace[:, 1]
            dt = np.diff(t, append=t[-1])
            payload[name]["mean_turnaround"].append(
                float(np.mean(out["c_cl"])))
            payload[name]["mean_utilization"].append(
                float(np.sum(u * dt) / max(np.sum(dt), 1e-9)))
            payload[name]["stp"].append(out["stp"])
    for name, v in payload.items():
        for key in list(v):
            v[key] = float(np.mean(v[key]))
        emit(f"fig07_mean_util_{name}", round(v["mean_utilization"], 3))
        emit(f"fig07_turnaround_{name}", round(v["mean_turnaround"], 1))
    payload["derived"] = {
        # paper Fig.8: turnaround time to finish the job set
        "ours_turnaround_speedup_vs_pairwise":
            payload["pairwise"]["mean_turnaround"]
            / payload["ours"]["mean_turnaround"],
        "ours_turnaround_speedup_vs_quasar":
            payload["quasar"]["mean_turnaround"]
            / payload["ours"]["mean_turnaround"],
        "paper_claims": {"vs_pairwise": 1.46, "vs_quasar": 1.28},
    }
    emit("fig08_turnaround_vs_pairwise",
         round(payload["derived"]["ours_turnaround_speedup_vs_pairwise"],
               2), "paper: 1.46")
    emit("fig08_turnaround_vs_quasar",
         round(payload["derived"]["ours_turnaround_speedup_vs_quasar"], 2),
         "paper: 1.28")
    save_result("fig07", payload)
    return payload


if __name__ == "__main__":
    main()
