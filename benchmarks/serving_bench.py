"""Continuous-batching vs wave serving sweep (beyond paper): the paper's
budget-inverse admission applied per DECODE STEP instead of per wave,
over arrival rate x HBM budget x placement policy — plus a multi-replica
routing cell over the ``net`` axis (the ``repro.sched.cluster`` Router
registry) and a paged-vs-dense KV residency cell (the
``repro.serve.paged`` backends).

The paged cell is the goodput-per-HBM acceptance bar for the paged
KV-cache: on contended cells the paged backend's padding-waste ratio
(resident KV slots that held no live token) must be STRICTLY below the
dense shim's, at goodput no worse.  Its numbers are also written to
``BENCH_serving.json`` at the repo root — goodput, TTFT p50/p99 and the
waste ratios, dense vs paged — so the serving perf trajectory is pinned
across PRs instead of invisible.

Both modes share the request population, demand model, budget vector and
(virtual-time) execution cost model — the only difference is when
admission runs.  Reported per cell:

* goodput (completed requests' tokens per second) for both modes and
  the continuous/wave ratio — the serving analogue of the paper's STP
  gain from co-location,
* SLO goodput (tokens from requests meeting their TTFT and TPOT
  deadlines) and attainment for continuous mode,
* TTFT mean / p95 and preemption rate for continuous mode,
* the per-step binding-axis histogram (hbm vs host_ram).

The replica cell serves a net-contended population (per-request egress
bandwidth against a tight per-replica ``net`` budget) on N replica
Nodes and compares the selected router against the ``single`` routing
baseline — routed goodput must beat single-node goodput, which is the
acceptance bar for multi-replica routing being real.

The tenancy cell is the noisy-neighbor acceptance bar for the
``repro.sched.tenancy`` fairness subsystem: two compliant tenants at
their fair arrival rate plus one flooding at 4x it, on a contended
2-replica cell.  Weighted-DRF routing + per-node knapsack joins
(``Engine(tenants=...)``, ``router="drf"``) must keep every compliant
tenant's SLO goodput within 10% of its isolated run (attainment
>= 0.9) while aggregate goodput stays >= 0.95x the untenanted
least-loaded baseline.  Numbers land in ``BENCH_tenancy.json`` at the
repo root (per-tenant SLO goodput drf vs isolated vs untenanted,
rejects by requeue-vs-new origin, end-of-run credit scores).

The topology cell binds a ``repro.sched.topology`` two-rack fabric
with one NARROW rack uplink and streams a bursty trace whose prompt
payloads ride real ingress Transmissions: ``topo-aware`` routing
(bottleneck-link path headroom) + KV migration on eviction must
STRICTLY beat the topology-blind ``net-aware`` router with local
requeue on SLO goodput, and at least one migration must fire.  Its
numbers land in ``BENCH_topology.json`` at the repo root (SLO goodput
both cells, migration count, p99 KV transfer time).

    PYTHONPATH=src python -m benchmarks.run --bench serving_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --bench serving_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --replicas 2 \
        --router net-aware --bench serving_bench
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import SMOKE, emit, save_result

# arrival rate (requests/s of virtual time), HBM budget as a multiple of
# one full-context request's KV (weights excluded), placement policies
RATES_PER_S = (40.0,) if SMOKE else (10.0, 40.0, 160.0)
BUDGET_KV_MULT = (3.0,) if SMOKE else (1.5, 3.0, 8.0)
PLACEMENTS = ("fcfs", "sjf") if SMOKE \
    else ("fcfs", "sjf", "arrival-aware")
N_REQUESTS = 24 if SMOKE else 96
MAX_NEW = 32
PROMPT_LEN = 24
WEIGHTS_GB = 0.5
KV_GB_PER_TOKEN = 2e-4
HOST_RAM_PER_REQ_GB = 0.01
# SLO deadlines (virtual seconds): generous enough that an uncontended
# run attains them, tight enough that wave-style queueing misses TTFT
TTFT_SLO_S = 0.25
TPOT_SLO_S = 0.05
SEED = 7

# --- the paged-vs-dense KV residency cell (repro.serve.paged) --------------
PAGE_SIZE = 8
PREFILL_CHUNK = 16
#: BENCH_serving.json lands at the repo root so the serving perf
#: trajectory is tracked in-tree across PRs
BENCH_SERVING_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")

# --- the multi-replica routing cell (repro.sched.cluster) ------------------
# benchmarks/run.py --replicas / --router land here via the environment
REPLICAS = int(os.environ.get("REPRO_SERVE_REPLICAS", "2"))
ROUTER = os.environ.get("REPRO_SERVE_ROUTER", "net-aware")
NET_GBPS_PER_REQ = 0.1
NET_BUDGET_GBPS = 0.25          # per replica: ~2 concurrent requests

# --- the multi-tenant fairness cell (repro.sched.tenancy) ------------------
# the noisy-neighbor scenario: two compliant tenants at their fair
# arrival rate plus one flooding at TEN_NOISY_MULT x it, on a contended
# cell.  Weighted-DRF routing + knapsack joins must keep every
# compliant tenant's SLO goodput within 10% of its ISOLATED run (the
# same requests alone on the same cluster) while aggregate goodput
# stays >= 0.95x the untenanted least-loaded baseline ("best-fit":
# fairness must not buy its protection with throughput)
TEN_COMPLIANT = ("gold", "silver")
TEN_NOISY = "flood"
TEN_RATE_PER_S = 10.0           # each compliant tenant's arrival rate
TEN_NOISY_MULT = 4.0            # the noisy neighbor's rate multiple
TEN_N = 8 if SMOKE else 24      # requests per compliant tenant
TEN_REPLICAS = 2
TEN_KV_MULT = 4.0               # tight HBM: joins actually compete
TEN_MAX_BATCH = 16
BENCH_TENANCY_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_tenancy.json")

# --- the network-topology cell (repro.sched.topology) ----------------------
# a 2-rack cell with one NARROW rack uplink: prompt payloads ride real
# ingress Transmissions, so a topology-blind router that lands half the
# deliveries behind the slow uplink pays the TTFT SLO for it
TOPO_REPLICAS = 4
TOPO_RATE = 120.0               # bursty: arrivals outrun delivery
TOPO_GBPS = 10.0                # intra-rack links
TOPO_UPLINKS = (0.2, 4.0)       # rack0 is the narrow one
TOPO_INGRESS_GB_PER_TOKEN = 2e-3
TOPO_NET_BUDGET_GBPS = 1.0      # roomy: delivery, not egress, binds
TOPO_KV_MULT = 2.5              # tight HBM: decode growth preempts
TOPO_PREFILL_S_PER_TOKEN = 2e-3  # recompute dear enough to migrate
# looser than the sweep's TTFT SLO: compute queueing on the preferred
# rack passes, multi-second deliveries behind the narrow uplink do not
TOPO_TTFT_SLO_S = 0.5
BENCH_TOPOLOGY_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_topology.json")


def _requests(n: int, rate: float, seed: int,
              ttft: float = TTFT_SLO_S, tenant: str | None = None):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt_len=int(rng.integers(PROMPT_LEN // 2,
                                                PROMPT_LEN + 1)),
                    max_new_tokens=int(rng.integers(MAX_NEW // 4,
                                                    MAX_NEW + 1)),
                    arrival=float(t[i]),
                    ttft_deadline=ttft,
                    tpot_deadline=TPOT_SLO_S,
                    tenant=tenant)
            for i in range(n)]


def _run(mode: str, rate: float, kv_mult: float, placement: str):
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand, SimBackend

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        host_ram_per_req_gb=HOST_RAM_PER_REQ_GB)
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * kv_mult,
        host_ram=HOST_RAM_PER_REQ_GB * max(2.0 * kv_mult, 2.0))
    engine = Engine(_requests(N_REQUESTS, rate, SEED), demand, budget,
                    SimBackend(), mode=mode, placement=placement,
                    max_batch=32)
    summary = engine.run()
    # the acceptance invariant, enforced here too: no unforced
    # over-budget step anywhere in the sweep
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, (
            f"unforced over-budget step in {mode} sweep: {dec}")
    return summary


def _ttft_pcts(engine):
    ttft = [r.first_token_t - r.arrival for r in engine.requests
            if r.first_token_t is not None]
    return (float(np.percentile(ttft, 50)) if ttft else 0.0,
            float(np.percentile(ttft, 99)) if ttft else 0.0)


def _run_paged_cell(rate: float, kv_mult: float, backend: str):
    """One contended cell on the virtual-time paged / dense-twin
    backends: same requests, demand slope and budget — only the KV
    residency model (and its booked quantization) differs."""
    from repro.sched.resources import ResourceVector
    from repro.serve import (DenseSimBackend, Engine, PagedSimBackend,
                             ServingDemand, pages_for)

    full_ctx = PROMPT_LEN + MAX_NEW
    max_len = full_ctx + 1
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * kv_mult)
    if backend == "paged":
        demand = ServingDemand(weights_gb=WEIGHTS_GB,
                               kv_gb_per_token=KV_GB_PER_TOKEN,
                               page_size=PAGE_SIZE)
        be = PagedSimBackend(
            num_pages=1 + 32 * pages_for(max_len, PAGE_SIZE),
            page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK)
    else:
        demand = ServingDemand(weights_gb=WEIGHTS_GB,
                               kv_gb_per_token=KV_GB_PER_TOKEN)
        be = DenseSimBackend(max_len=max_len, sync=8)
    engine = Engine(_requests(N_REQUESTS, rate, SEED), demand, budget,
                    be, mode="continuous", placement="fcfs",
                    max_batch=32)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec
    p50, p99 = _ttft_pcts(engine)
    return {"goodput_tok_s": summary["goodput_tok_s"],
            "completed": summary["completed"],
            "ttft_p50_s": p50, "ttft_p99_s": p99,
            "waste_ratio": be.waste_ratio()}


def _run_replicated(router: str, replicas: int):
    """The net-contended routing cell: per-request egress bandwidth
    against a tight per-replica net budget, served on ``replicas``
    Nodes with arrivals routed by ``router``."""
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        extra_axes={"net": NET_GBPS_PER_REQ})
    # generous HBM so the net axis is what binds joins
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * 16.0,
        net=NET_BUDGET_GBPS)
    engine = Engine(_requests(N_REQUESTS, 40.0, SEED + 1), demand,
                    budget, mode="continuous", placement="fcfs",
                    max_batch=32, replicas=replicas, router=router)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec
    return summary


def _tenant_population(seed: int, only: str | None = None):
    """The noisy-neighbor request population: one Poisson stream per
    tenant (compliant tenants at TEN_RATE_PER_S, the noisy one at
    TEN_NOISY_MULT x it), merged by arrival and re-rid'd.  ``only``
    keeps a single tenant's requests at their ORIGINAL arrival times —
    the isolated-run population.  Requests are mutable lifecycle
    records, so every run gets a fresh (deterministic) build."""
    from repro.serve import Request

    streams = []
    for i, name in enumerate(TEN_COMPLIANT):
        streams.append(_requests(TEN_N, TEN_RATE_PER_S, seed + i,
                                 tenant=name))
    streams.append(_requests(int(TEN_N * TEN_NOISY_MULT),
                             TEN_RATE_PER_S * TEN_NOISY_MULT,
                             seed + len(TEN_COMPLIANT),
                             tenant=TEN_NOISY))
    merged = sorted((r for s in streams for r in s),
                    key=lambda r: (r.arrival, r.tenant))
    if only is not None:
        merged = [r for r in merged if r.tenant == only]
    return [Request(rid=i, prompt_len=r.prompt_len,
                    max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                    ttft_deadline=r.ttft_deadline,
                    tpot_deadline=r.tpot_deadline, tenant=r.tenant)
            for i, r in enumerate(merged)]


def _run_tenancy(requests, router: str, registry=None):
    """One run of the noisy-neighbor population on the contended
    tenancy cell: same replicas / demand / budget for every variant —
    only the router and whether a TenantRegistry is bound differ."""
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        host_ram_per_req_gb=HOST_RAM_PER_REQ_GB)
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * TEN_KV_MULT,
        host_ram=HOST_RAM_PER_REQ_GB * 2.0 * TEN_KV_MULT)
    engine = Engine(requests, demand, budget, mode="continuous",
                    placement="fcfs", max_batch=TEN_MAX_BATCH,
                    replicas=TEN_REPLICAS, router=router,
                    tenants=registry)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec
    return summary


def _run_topology_cell(router: str, migrate: bool, tracer=None):
    """One bursty run on the asymmetric two-rack fabric.  Same trace,
    demand, budget and backends for every router — only where requests
    land (and whether evicted KV may move) differs.  ``tracer`` (a
    ``repro.obs.Tracer``) records the run; None must leave the summary
    bit-identical (the --trace acceptance check relies on it).
    Returns ``(summary, engine)``."""
    from repro.sched import get_topology
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand, SimBackend

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        extra_axes={"net": NET_GBPS_PER_REQ})
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * TOPO_KV_MULT,
        net=TOPO_NET_BUDGET_GBPS)
    topo = get_topology("two-rack", nodes=TOPO_REPLICAS,
                        gbps=TOPO_GBPS, uplink_gbps=TOPO_UPLINKS)
    backends = [SimBackend(
        t_prefill_per_token=TOPO_PREFILL_S_PER_TOKEN)
        for _ in range(TOPO_REPLICAS)]
    engine = Engine(_requests(N_REQUESTS, TOPO_RATE, SEED + 2,
                              ttft=TOPO_TTFT_SLO_S), demand,
                    budget, mode="continuous", placement="fcfs",
                    max_batch=32, replicas=TOPO_REPLICAS, router=router,
                    backends=backends, topology=topo, migrate=migrate,
                    ingress_gb_per_token=TOPO_INGRESS_GB_PER_TOKEN,
                    tracer=tracer)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec
    return summary, engine


def _traced_topology_cell(untraced: dict, trace_path: str) -> None:
    """The --trace acceptance check: re-run the topo-aware cell with a
    Tracer bound, assert its metrics are BIT-IDENTICAL to the untraced
    run (tracing must be pure observation), write the schema-validated
    trace, and prove the trace is a faithful record by reproducing the
    bench's goodput and migration count from the trace alone."""
    from repro.obs import Tracer, validate_chrome_trace
    from repro.obs.report import summarize

    tracer = Tracer()
    traced, _ = _run_topology_cell("topo-aware", migrate=True,
                                   tracer=tracer)
    assert traced == untraced, (
        "tracing changed the run: traced topo-cell summary is not "
        "bit-identical to the untraced one")
    payload = tracer.dump(trace_path)       # dump() schema-validates
    validate_chrome_trace(payload)
    rep = summarize(payload)
    assert rep["goodput_tok_s"] == untraced["goodput_tok_s"], (
        f"trace report goodput {rep['goodput_tok_s']!r} != bench "
        f"goodput {untraced['goodput_tok_s']!r}")
    assert rep["migrations"] == untraced["migrations"], (
        f"trace report migrations {rep['migrations']} != bench "
        f"{untraced['migrations']}")
    emit("serving/topology/trace", trace_path,
         f"{len(tracer)} events, schema-valid, metrics bit-identical "
         f"to untraced; goodput reproduced from trace alone")


def main() -> dict:
    payload: dict = {"cells": []}
    worst = np.inf
    for rate in RATES_PER_S:
        for mult in BUDGET_KV_MULT:
            for pl in PLACEMENTS:
                cont = _run("continuous", rate, mult, pl)
                wave = _run("wave", rate, mult, pl)
                ratio = cont["goodput_tok_s"] \
                    / max(wave["goodput_tok_s"], 1e-12)
                worst = min(worst, ratio)
                cell = f"serving/{rate}/{mult}/{pl}"
                emit(f"{cell}/goodput_continuous",
                     f"{cont['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_wave",
                     f"{wave['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_ratio", f"{ratio:.3f}",
                     "continuous / wave at equal budget")
                emit(f"{cell}/slo_goodput", f"{cont['slo_goodput_tok_s']:.1f}",
                     f"attainment {cont['slo_attainment']:.2f} "
                     f"(ttft<={TTFT_SLO_S}s tpot<={TPOT_SLO_S}s)")
                emit(f"{cell}/ttft_mean_ms",
                     f"{cont['ttft_mean_s'] * 1e3:.1f}",
                     f"p95 {cont['ttft_p95_s'] * 1e3:.1f}ms")
                emit(f"{cell}/preemption_rate",
                     f"{cont['preemption_rate']:.3f}",
                     f"{cont['preemptions']} evictions")
                axes = " ".join(
                    f"{a}:{n}" for a, n in
                    sorted(cont["binding_axes"].items())) or "-"
                emit(f"{cell}/binding_axes", f"[{axes}]",
                     "join decisions per binding axis")
                payload["cells"].append(
                    {"rate": rate, "kv_mult": mult, "placement": pl,
                     "continuous": cont, "wave": wave, "ratio": ratio})
    emit("serving/goodput_ratio_min", f"{worst:.3f}",
         "continuous >= wave expected at every cell")
    payload["ratio_min"] = worst

    # --- paged vs dense KV residency (repro.serve.paged) ------------------
    paged_cells = []
    for rate in RATES_PER_S:
        for mult in BUDGET_KV_MULT:
            paged = _run_paged_cell(rate, mult, "paged")
            dense = _run_paged_cell(rate, mult, "dense")
            cell = f"serving/paged/{rate}/{mult}"
            emit(f"{cell}/goodput_paged",
                 f"{paged['goodput_tok_s']:.1f}",
                 f"dense {dense['goodput_tok_s']:.1f} tok/s")
            emit(f"{cell}/ttft_p50_ms",
                 f"{paged['ttft_p50_s'] * 1e3:.1f}",
                 f"p99 {paged['ttft_p99_s'] * 1e3:.1f}ms (dense p50 "
                 f"{dense['ttft_p50_s'] * 1e3:.1f} p99 "
                 f"{dense['ttft_p99_s'] * 1e3:.1f}ms)")
            emit(f"{cell}/waste_ratio",
                 f"{paged['waste_ratio']:.3f}",
                 f"dense {dense['waste_ratio']:.3f} (resident KV "
                 f"slots with no live token)")
            paged_cells.append({"rate": rate, "kv_mult": mult,
                                "paged": paged, "dense": dense})
    payload["paged_vs_dense"] = paged_cells
    with open(BENCH_SERVING_JSON, "w") as f:
        json.dump({"page_size": PAGE_SIZE,
                   "prefill_chunk": PREFILL_CHUNK,
                   "n_requests": N_REQUESTS, "smoke": SMOKE,
                   "cells": paged_cells}, f, indent=1, default=float)
    emit("serving/paged/pinned", BENCH_SERVING_JSON,
         "goodput + TTFT p50/p99 + waste, dense vs paged")

    # --- multi-replica routing over the net axis -------------------------
    routed = _run_replicated(ROUTER, REPLICAS)
    single = _run_replicated("single", REPLICAS)
    route_ratio = routed["goodput_tok_s"] \
        / max(single["goodput_tok_s"], 1e-12)
    spread = " ".join(f"n{n}:{c}" for n, c in
                      sorted(routed["node_steps"].items()))
    emit(f"serving/replicas{REPLICAS}/{ROUTER}/goodput",
         f"{routed['goodput_tok_s']:.1f}", f"step spread [{spread}]")
    emit(f"serving/replicas{REPLICAS}/single/goodput",
         f"{single['goodput_tok_s']:.1f}",
         "routing baseline (all on node 0)")
    emit(f"serving/replicas{REPLICAS}/route_ratio", f"{route_ratio:.3f}",
         f"{ROUTER} / single under net contention")
    payload["replicas"] = {
        "replicas": REPLICAS, "router": ROUTER,
        "routed": routed, "single": single, "ratio": route_ratio}

    # --- multi-tenant fairness: the noisy-neighbor cell -------------------
    from repro.sched import Tenant, TenantRegistry
    registry = TenantRegistry(
        [Tenant(n) for n in TEN_COMPLIANT] + [Tenant(TEN_NOISY)])
    drf = _run_tenancy(_tenant_population(SEED + 3), "drf",
                       registry=registry)
    bestfit = _run_tenancy(_tenant_population(SEED + 3), "least-loaded")
    isolated = {name: _run_tenancy(_tenant_population(SEED + 3,
                                                      only=name),
                                   "least-loaded")
                for name in TEN_COMPLIANT}
    ten_ratio = drf["goodput_tok_s"] \
        / max(bestfit["goodput_tok_s"], 1e-12)
    for name in TEN_COMPLIANT:
        td = drf["tenants"][name]
        iso = isolated[name]
        # token-denominated: per-tenant tok/s rates divide by the
        # whole shared-run window, so tokens are the comparable unit
        frac = td["slo_good_tokens"] \
            / max(iso["slo_good_tokens"], 1e-12)
        emit(f"serving/tenancy/{name}/slo_good_tokens",
             f"{td['slo_good_tokens']}",
             f"isolated {iso['slo_good_tokens']} "
             f"({frac:.3f}x), attainment {td['slo_attainment']:.2f}, "
             f"credit {registry.credit(name):.2f}")
    noisy = drf["tenants"][TEN_NOISY]
    emit(f"serving/tenancy/{TEN_NOISY}/slo_goodput",
         f"{noisy['slo_goodput_tok_s']:.1f}",
         f"the {TEN_NOISY_MULT:.0f}x noisy neighbor: attainment "
         f"{noisy['slo_attainment']:.2f}, {noisy['rejects']} rejects, "
         f"credit {registry.credit(TEN_NOISY):.2f}")
    emit("serving/tenancy/goodput_ratio", f"{ten_ratio:.3f}",
         "drf+knapsack / untenanted least-loaded, aggregate")
    origins = " ".join(f"{o}:{n}" for o, n in
                       sorted(drf["rejects_by_origin"].items())) or "-"
    emit("serving/tenancy/rejects_by_origin", f"[{origins}]",
         "knapsack skips, requeue-vs-new")
    ten_payload = {
        "tenants": list(TEN_COMPLIANT) + [TEN_NOISY],
        "noisy": TEN_NOISY, "noisy_mult": TEN_NOISY_MULT,
        "rate_per_tenant": TEN_RATE_PER_S, "n_per_tenant": TEN_N,
        "replicas": TEN_REPLICAS, "kv_mult": TEN_KV_MULT,
        "smoke": SMOKE,
        "drf": {"goodput_tok_s": drf["goodput_tok_s"],
                "slo_goodput_tok_s": drf["slo_goodput_tok_s"],
                "rejects_by_origin": drf["rejects_by_origin"],
                "tenants": drf["tenants"],
                "credits": {n: registry.credit(n)
                            for n in registry.names()}},
        "bestfit": {"goodput_tok_s": bestfit["goodput_tok_s"],
                    "slo_goodput_tok_s": bestfit["slo_goodput_tok_s"],
                    "tenants": bestfit["tenants"]},
        "isolated": {n: {"goodput_tok_s": s["goodput_tok_s"],
                         "slo_goodput_tok_s": s["slo_goodput_tok_s"],
                         "slo_attainment": s["slo_attainment"]}
                     for n, s in isolated.items()},
        "goodput_ratio": ten_ratio}
    payload["tenancy"] = ten_payload
    with open(BENCH_TENANCY_JSON, "w") as f:
        json.dump(ten_payload, f, indent=1, default=float)
    emit("serving/tenancy/pinned", BENCH_TENANCY_JSON,
         "per-tenant SLO goodput drf vs isolated vs untenanted")

    # --- topology: topo-aware + KV migration vs net-aware + local requeue --
    topo, topo_engine = _run_topology_cell("topo-aware", migrate=True)
    blind, _ = _run_topology_cell("net-aware", migrate=False)
    topo_ratio = topo["slo_goodput_tok_s"] \
        / max(blind["slo_goodput_tok_s"], 1e-12)
    spread = " ".join(f"n{n}:{c}" for n, c in
                      sorted(topo["node_steps"].items()))
    emit("serving/topology/topo_aware_slo_goodput",
         f"{topo['slo_goodput_tok_s']:.1f}",
         f"migrations {topo['migrations']}, step spread [{spread}]")
    emit("serving/topology/net_aware_slo_goodput",
         f"{blind['slo_goodput_tok_s']:.1f}",
         "topology-blind baseline, local requeue on eviction")
    emit("serving/topology/slo_ratio", f"{topo_ratio:.3f}",
         "topo-aware+migrate / net-aware+requeue on the 2-rack fabric")
    emit("serving/topology/kv_transfer_p99_ms",
         f"{topo['kv_transfer_p99_s'] * 1e3:.2f}",
         f"{topo['migrations']} migrated KV transfer(s)")
    # per-link utilization (Link busy/bytes/peak ledgers): the narrow
    # rack0 uplink should show the congestion the router routes around
    for lname, st in sorted(topo["links"].items()):
        if st["bytes_gb"] <= 0.0:
            continue
        emit(f"serving/topology/link/{lname}",
             f"{st['busy_frac']:.3f}",
             f"busy {st['busy_s']:.2f}s, {st['bytes_gb']:.3f}GB "
             f"moved, peak {st['peak_flows']} flows")
    rejects = " ".join(
        f"{a}:{n}" for a, n in
        sorted(topo["rejects_by_axis"].items())) or "-"
    emit("serving/topology/rejected_joins",
         str(topo["rejected_joins"]), f"by axis [{rejects}]")
    # EventLoop telemetry: deterministic per-kind dispatch counters,
    # wall-clock events/sec from the gauge registry (never in summary)
    tm = topo_engine.telemetry
    kinds = " ".join(
        f"{k[len('events.'):]}:{int(v)}"
        for k, v in sorted(tm.counters_with_prefix("events.").items())
        if not k.startswith("events.stale.")
        and k not in ("events.dispatched",))
    emit("serving/topology/events", f"[{kinds}]",
         f"{tm.gauges.get('events_per_s_wall', 0.0):.0f} events/s "
         f"wall ({tm.gauges.get('wall_s', 0.0):.2f}s wall)")
    topo_payload = {
        "replicas": TOPO_REPLICAS, "uplink_gbps": list(TOPO_UPLINKS),
        "rate": TOPO_RATE, "n_requests": N_REQUESTS, "smoke": SMOKE,
        "topo_aware": {
            "goodput_tok_s": topo["goodput_tok_s"],
            "slo_goodput_tok_s": topo["slo_goodput_tok_s"],
            "slo_attainment": topo["slo_attainment"],
            "preemptions": topo["preemptions"],
            "migrations": topo["migrations"],
            "kv_transfer_p99_s": topo["kv_transfer_p99_s"],
            "rejected_joins": topo["rejected_joins"],
            "rejects_by_axis": topo["rejects_by_axis"],
            "links": topo["links"]},
        "net_aware": {
            "goodput_tok_s": blind["goodput_tok_s"],
            "slo_goodput_tok_s": blind["slo_goodput_tok_s"],
            "slo_attainment": blind["slo_attainment"],
            "preemptions": blind["preemptions"],
            "migrations": blind["migrations"],
            "links": blind["links"]},
        "slo_ratio": topo_ratio}
    payload["topology"] = topo_payload
    with open(BENCH_TOPOLOGY_JSON, "w") as f:
        json.dump(topo_payload, f, indent=1, default=float)
    emit("serving/topology/pinned", BENCH_TOPOLOGY_JSON,
         "SLO goodput + migrations + p99 transfer, both routers")

    # --- --trace: traced re-run of the topo cell, bit-identical check --
    trace_path = os.environ.get("REPRO_TRACE", "")
    if trace_path:
        _traced_topology_cell(topo, trace_path)
    save_result("serving_bench", payload)

    if worst < 0.99:
        raise AssertionError(
            f"continuous batching lost to wave mode somewhere in the "
            f"sweep (min ratio {worst:.3f}) — step-level admission "
            f"regressed")
    if REPLICAS > 1 and ROUTER != "single" and route_ratio < 1.02:
        raise AssertionError(
            f"{ROUTER!r} routing over {REPLICAS} replicas did not beat "
            f"single-node routing under net contention "
            f"(ratio {route_ratio:.3f}) — the Router registry regressed")
    for c in paged_cells:
        # the paged-KV acceptance bar: strictly less padding waste at
        # goodput no worse, on every contended cell
        if c["paged"]["waste_ratio"] >= c["dense"]["waste_ratio"]:
            raise AssertionError(
                f"paged backend did not cut padding waste at "
                f"rate={c['rate']} kv_mult={c['kv_mult']}: "
                f"{c['paged']['waste_ratio']:.3f} vs dense "
                f"{c['dense']['waste_ratio']:.3f}")
        if c["paged"]["goodput_tok_s"] < \
                c["dense"]["goodput_tok_s"] * 0.95:
            raise AssertionError(
                f"paged backend lost goodput at rate={c['rate']} "
                f"kv_mult={c['kv_mult']}: "
                f"{c['paged']['goodput_tok_s']:.1f} vs dense "
                f"{c['dense']['goodput_tok_s']:.1f} tok/s")
    # the tenancy acceptance bar: with one tenant flooding at
    # TEN_NOISY_MULT x its fair rate, weighted-DRF + knapsack joins
    # must hold every compliant tenant's SLO goodput within 10% of its
    # isolated run AND its attainment >= 0.9, without giving up more
    # than 5% aggregate goodput vs the untenanted best-fit baseline
    for name in TEN_COMPLIANT:
        td = drf["tenants"][name]
        iso = isolated[name]
        if td["slo_good_tokens"] < iso["slo_good_tokens"] * 0.9:
            raise AssertionError(
                f"compliant tenant {name!r} lost SLO goodput to the "
                f"noisy neighbor under drf+knapsack: "
                f"{td['slo_good_tokens']} SLO-good tokens vs isolated "
                f"{iso['slo_good_tokens']}")
        if td["slo_attainment"] < 0.9:
            raise AssertionError(
                f"compliant tenant {name!r} SLO attainment "
                f"{td['slo_attainment']:.2f} < 0.9 under drf+knapsack")
    if ten_ratio < 0.95:
        raise AssertionError(
            f"tenancy fairness cost too much aggregate goodput: "
            f"drf+knapsack at {ten_ratio:.3f}x the untenanted "
            f"least-loaded baseline (floor 0.95)")
    # the topology acceptance bar: on the contended 2-rack fabric,
    # path-headroom routing + KV migration must STRICTLY beat the
    # topology-blind router with local requeue on SLO goodput, and
    # migration must actually fire
    if topo["slo_goodput_tok_s"] <= blind["slo_goodput_tok_s"]:
        raise AssertionError(
            f"topo-aware+migrate did not beat net-aware+requeue on SLO "
            f"goodput over the asymmetric 2-rack fabric: "
            f"{topo['slo_goodput_tok_s']:.1f} vs "
            f"{blind['slo_goodput_tok_s']:.1f} tok/s")
    if topo["migrations"] < 1:
        raise AssertionError(
            "no KV migration fired in the topology cell — the "
            "migrate-vs-recompute path is dead")
    return payload


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SMOKE", "1")
    main()
