"""Continuous-batching vs wave serving sweep (beyond paper): the paper's
budget-inverse admission applied per DECODE STEP instead of per wave,
over arrival rate x HBM budget x placement policy — plus a multi-replica
routing cell over the ``net`` axis (the ``repro.sched.cluster`` Router
registry).

Both modes share the request population, demand model, budget vector and
(virtual-time) execution cost model — the only difference is when
admission runs.  Reported per cell:

* goodput (completed requests' tokens per second) for both modes and
  the continuous/wave ratio — the serving analogue of the paper's STP
  gain from co-location,
* SLO goodput (tokens from requests meeting their TTFT and TPOT
  deadlines) and attainment for continuous mode,
* TTFT mean / p95 and preemption rate for continuous mode,
* the per-step binding-axis histogram (hbm vs host_ram).

The replica cell serves a net-contended population (per-request egress
bandwidth against a tight per-replica ``net`` budget) on N replica
Nodes and compares the selected router against the ``single`` routing
baseline — routed goodput must beat single-node goodput, which is the
acceptance bar for multi-replica routing being real.

    PYTHONPATH=src python -m benchmarks.run --bench serving_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --bench serving_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --replicas 2 \
        --router net-aware --bench serving_bench
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import SMOKE, emit, save_result

# arrival rate (requests/s of virtual time), HBM budget as a multiple of
# one full-context request's KV (weights excluded), placement policies
RATES_PER_S = (40.0,) if SMOKE else (10.0, 40.0, 160.0)
BUDGET_KV_MULT = (3.0,) if SMOKE else (1.5, 3.0, 8.0)
PLACEMENTS = ("fcfs", "sjf") if SMOKE \
    else ("fcfs", "sjf", "arrival-aware")
N_REQUESTS = 24 if SMOKE else 96
MAX_NEW = 32
PROMPT_LEN = 24
WEIGHTS_GB = 0.5
KV_GB_PER_TOKEN = 2e-4
HOST_RAM_PER_REQ_GB = 0.01
# SLO deadlines (virtual seconds): generous enough that an uncontended
# run attains them, tight enough that wave-style queueing misses TTFT
TTFT_SLO_S = 0.25
TPOT_SLO_S = 0.05
SEED = 7

# --- the multi-replica routing cell (repro.sched.cluster) ------------------
# benchmarks/run.py --replicas / --router land here via the environment
REPLICAS = int(os.environ.get("REPRO_SERVE_REPLICAS", "2"))
ROUTER = os.environ.get("REPRO_SERVE_ROUTER", "net-aware")
NET_GBPS_PER_REQ = 0.1
NET_BUDGET_GBPS = 0.25          # per replica: ~2 concurrent requests


def _requests(n: int, rate: float, seed: int):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt_len=int(rng.integers(PROMPT_LEN // 2,
                                                PROMPT_LEN + 1)),
                    max_new_tokens=int(rng.integers(MAX_NEW // 4,
                                                    MAX_NEW + 1)),
                    arrival=float(t[i]),
                    ttft_deadline=TTFT_SLO_S,
                    tpot_deadline=TPOT_SLO_S)
            for i in range(n)]


def _run(mode: str, rate: float, kv_mult: float, placement: str):
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand, SimBackend

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        host_ram_per_req_gb=HOST_RAM_PER_REQ_GB)
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * kv_mult,
        host_ram=HOST_RAM_PER_REQ_GB * max(2.0 * kv_mult, 2.0))
    engine = Engine(_requests(N_REQUESTS, rate, SEED), demand, budget,
                    SimBackend(), mode=mode, placement=placement,
                    max_batch=32)
    summary = engine.run()
    # the acceptance invariant, enforced here too: no unforced
    # over-budget step anywhere in the sweep
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, (
            f"unforced over-budget step in {mode} sweep: {dec}")
    return summary


def _run_replicated(router: str, replicas: int):
    """The net-contended routing cell: per-request egress bandwidth
    against a tight per-replica net budget, served on ``replicas``
    Nodes with arrivals routed by ``router``."""
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        extra_axes={"net": NET_GBPS_PER_REQ})
    # generous HBM so the net axis is what binds joins
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * 16.0,
        net=NET_BUDGET_GBPS)
    engine = Engine(_requests(N_REQUESTS, 40.0, SEED + 1), demand,
                    budget, mode="continuous", placement="fcfs",
                    max_batch=32, replicas=replicas, router=router)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec
    return summary


def main() -> dict:
    payload: dict = {"cells": []}
    worst = np.inf
    for rate in RATES_PER_S:
        for mult in BUDGET_KV_MULT:
            for pl in PLACEMENTS:
                cont = _run("continuous", rate, mult, pl)
                wave = _run("wave", rate, mult, pl)
                ratio = cont["goodput_tok_s"] \
                    / max(wave["goodput_tok_s"], 1e-12)
                worst = min(worst, ratio)
                cell = f"serving/{rate}/{mult}/{pl}"
                emit(f"{cell}/goodput_continuous",
                     f"{cont['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_wave",
                     f"{wave['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_ratio", f"{ratio:.3f}",
                     "continuous / wave at equal budget")
                emit(f"{cell}/slo_goodput", f"{cont['slo_goodput_tok_s']:.1f}",
                     f"attainment {cont['slo_attainment']:.2f} "
                     f"(ttft<={TTFT_SLO_S}s tpot<={TPOT_SLO_S}s)")
                emit(f"{cell}/ttft_mean_ms",
                     f"{cont['ttft_mean_s'] * 1e3:.1f}",
                     f"p95 {cont['ttft_p95_s'] * 1e3:.1f}ms")
                emit(f"{cell}/preemption_rate",
                     f"{cont['preemption_rate']:.3f}",
                     f"{cont['preemptions']} evictions")
                axes = " ".join(
                    f"{a}:{n}" for a, n in
                    sorted(cont["binding_axes"].items())) or "-"
                emit(f"{cell}/binding_axes", f"[{axes}]",
                     "join decisions per binding axis")
                payload["cells"].append(
                    {"rate": rate, "kv_mult": mult, "placement": pl,
                     "continuous": cont, "wave": wave, "ratio": ratio})
    emit("serving/goodput_ratio_min", f"{worst:.3f}",
         "continuous >= wave expected at every cell")
    payload["ratio_min"] = worst

    # --- multi-replica routing over the net axis -------------------------
    routed = _run_replicated(ROUTER, REPLICAS)
    single = _run_replicated("single", REPLICAS)
    route_ratio = routed["goodput_tok_s"] \
        / max(single["goodput_tok_s"], 1e-12)
    spread = " ".join(f"n{n}:{c}" for n, c in
                      sorted(routed["node_steps"].items()))
    emit(f"serving/replicas{REPLICAS}/{ROUTER}/goodput",
         f"{routed['goodput_tok_s']:.1f}", f"step spread [{spread}]")
    emit(f"serving/replicas{REPLICAS}/single/goodput",
         f"{single['goodput_tok_s']:.1f}",
         "routing baseline (all on node 0)")
    emit(f"serving/replicas{REPLICAS}/route_ratio", f"{route_ratio:.3f}",
         f"{ROUTER} / single under net contention")
    payload["replicas"] = {
        "replicas": REPLICAS, "router": ROUTER,
        "routed": routed, "single": single, "ratio": route_ratio}
    save_result("serving_bench", payload)

    if worst < 0.99:
        raise AssertionError(
            f"continuous batching lost to wave mode somewhere in the "
            f"sweep (min ratio {worst:.3f}) — step-level admission "
            f"regressed")
    if REPLICAS > 1 and ROUTER != "single" and route_ratio < 1.02:
        raise AssertionError(
            f"{ROUTER!r} routing over {REPLICAS} replicas did not beat "
            f"single-node routing under net contention "
            f"(ratio {route_ratio:.3f}) — the Router registry regressed")
    return payload


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SMOKE", "1")
    main()
