"""Continuous-batching vs wave serving sweep (beyond paper): the paper's
budget-inverse admission applied per DECODE STEP instead of per wave,
over arrival rate x HBM budget x placement policy.

Both modes share the request population, demand model, budget vector and
(virtual-time) execution cost model — the only difference is when
admission runs.  Reported per cell:

* goodput (completed requests' tokens per second) for both modes and
  the continuous/wave ratio — the serving analogue of the paper's STP
  gain from co-location,
* TTFT mean / p95 and preemption rate for continuous mode,
* the per-step binding-axis histogram (hbm vs host_ram).

    PYTHONPATH=src python -m benchmarks.run --bench serving_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --bench serving_bench
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import SMOKE, emit, save_result

# arrival rate (requests/s of virtual time), HBM budget as a multiple of
# one full-context request's KV (weights excluded), placement policies
RATES_PER_S = (40.0,) if SMOKE else (10.0, 40.0, 160.0)
BUDGET_KV_MULT = (3.0,) if SMOKE else (1.5, 3.0, 8.0)
PLACEMENTS = ("fcfs", "sjf") if SMOKE \
    else ("fcfs", "sjf", "arrival-aware")
N_REQUESTS = 24 if SMOKE else 96
MAX_NEW = 32
PROMPT_LEN = 24
WEIGHTS_GB = 0.5
KV_GB_PER_TOKEN = 2e-4
HOST_RAM_PER_REQ_GB = 0.01
SEED = 7


def _requests(n: int, rate: float, seed: int):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt_len=int(rng.integers(PROMPT_LEN // 2,
                                                PROMPT_LEN + 1)),
                    max_new_tokens=int(rng.integers(MAX_NEW // 4,
                                                    MAX_NEW + 1)),
                    arrival=float(t[i]))
            for i in range(n)]


def _run(mode: str, rate: float, kv_mult: float, placement: str):
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand, SimBackend

    full_ctx = PROMPT_LEN + MAX_NEW
    demand = ServingDemand(
        weights_gb=WEIGHTS_GB, kv_gb_per_token=KV_GB_PER_TOKEN,
        host_ram_per_req_gb=HOST_RAM_PER_REQ_GB)
    budget = ResourceVector(
        hbm=WEIGHTS_GB + KV_GB_PER_TOKEN * full_ctx * kv_mult,
        host_ram=HOST_RAM_PER_REQ_GB * max(2.0 * kv_mult, 2.0))
    engine = Engine(_requests(N_REQUESTS, rate, SEED), demand, budget,
                    SimBackend(), mode=mode, placement=placement,
                    max_batch=32)
    summary = engine.run()
    # the acceptance invariant, enforced here too: no unforced
    # over-budget step anywhere in the sweep
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, (
            f"unforced over-budget step in {mode} sweep: {dec}")
    return summary


def main() -> dict:
    payload: dict = {"cells": []}
    worst = np.inf
    for rate in RATES_PER_S:
        for mult in BUDGET_KV_MULT:
            for pl in PLACEMENTS:
                cont = _run("continuous", rate, mult, pl)
                wave = _run("wave", rate, mult, pl)
                ratio = cont["goodput_tok_s"] \
                    / max(wave["goodput_tok_s"], 1e-12)
                worst = min(worst, ratio)
                cell = f"serving/{rate}/{mult}/{pl}"
                emit(f"{cell}/goodput_continuous",
                     f"{cont['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_wave",
                     f"{wave['goodput_tok_s']:.1f}", "tok/s")
                emit(f"{cell}/goodput_ratio", f"{ratio:.3f}",
                     "continuous / wave at equal budget")
                emit(f"{cell}/ttft_mean_ms",
                     f"{cont['ttft_mean_s'] * 1e3:.1f}",
                     f"p95 {cont['ttft_p95_s'] * 1e3:.1f}ms")
                emit(f"{cell}/preemption_rate",
                     f"{cont['preemption_rate']:.3f}",
                     f"{cont['preemptions']} evictions")
                axes = " ".join(
                    f"{a}:{n}" for a, n in
                    sorted(cont["binding_axes"].items())) or "-"
                emit(f"{cell}/binding_axes", f"[{axes}]",
                     "join decisions per binding axis")
                payload["cells"].append(
                    {"rate": rate, "kv_mult": mult, "placement": pl,
                     "continuous": cont, "wave": wave, "ratio": ratio})
    emit("serving/goodput_ratio_min", f"{worst:.3f}",
         "continuous >= wave expected at every cell")
    payload["ratio_min"] = worst
    save_result("serving_bench", payload)
    if worst < 0.99:
        raise AssertionError(
            f"continuous batching lost to wave mode somewhere in the "
            f"sweep (min ratio {worst:.3f}) — step-level admission "
            f"regressed")
    return payload


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SMOKE", "1")
    main()
