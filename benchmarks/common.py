"""Shared benchmark scaffolding: suite/predictor construction, CSV output.

Every module prints ``name,value,derived`` CSV rows (one per paper
table/figure datapoint) and returns a dict for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
N_MIXES = int(os.environ.get("REPRO_BENCH_MIXES", "8"))
# --smoke (benchmarks/run.py): tiny n_jobs/n_hosts/n_mixes everywhere —
# a CI-speed end-to-end pass over the bench plumbing, not a measurement
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
DRYRUN_JSON = os.path.join(RESULTS_DIR, "dryrun_baseline.json")

_cache: Dict[str, object] = {}


def get_suite():
    if "suite" not in _cache:
        from repro.core import (ANNPredictor, MoEPredictor, spark_sim_suite,
                                training_apps)
        apps = spark_sim_suite()
        train = training_apps(apps)
        moe = MoEPredictor().fit(train)
        ann = ANNPredictor().fit(train)
        _cache["suite"] = (apps, train, moe, ann)
    return _cache["suite"]


def get_policies():
    from repro.core import make_policies
    apps, train, moe, ann = get_suite()
    return make_policies(moe, ann)


def load_dryrun() -> Optional[dict]:
    if os.path.exists(DRYRUN_JSON):
        with open(DRYRUN_JSON) as f:
            return json.load(f)
    return None


def emit(name: str, value, derived: str = ""):
    print(f"{name},{value},{derived}")


def save_result(bench: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"bench_{bench}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
