"""Paper Fig. 14: slowdown distribution when co-locating each training
benchmark with every other app under OUR scheme (paper: <25%, avg <10%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.simulator import OursPolicy, SimConfig, Simulator
from repro.core.workloads import training_apps


def main() -> dict:
    apps, train, moe, _ = get_suite()
    cfg = SimConfig()
    slowdowns = {}
    items = 30.0  # ~280 GB-class inputs in the paper's experiment
    for target in train:
        sds = []
        # baseline: target alone
        solo = Simulator([(target, items)], OursPolicy(moe), cfg, seed=0)
        c_solo = solo.run()["c_cl"][0]
        for other in apps:
            if other.name == target.name:
                continue
            sim = Simulator([(target, items), (other, items)],
                            OursPolicy(moe), cfg, seed=0)
            out = sim.run()
            sds.append(out["c_cl"][0] / max(c_solo, 1e-9) - 1.0)
        slowdowns[target.name] = {
            "median": float(np.median(sds)),
            "p95": float(np.percentile(sds, 95)),
            "max": float(np.max(sds)),
        }
    med = float(np.mean([v["median"] for v in slowdowns.values()]))
    worst = float(np.max([v["max"] for v in slowdowns.values()]))
    payload = {"per_target": slowdowns,
               "avg_median_slowdown": med, "worst_slowdown": worst,
               "paper_claims": {"avg": 0.10, "max": 0.25}}
    emit("fig14_avg_median_slowdown", round(med * 100, 1),
         "percent; paper: <10")
    emit("fig14_worst_slowdown", round(worst * 100, 1),
         "percent; paper: <25")
    save_result("fig14", payload)
    return payload


if __name__ == "__main__":
    main()
