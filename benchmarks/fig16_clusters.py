"""Paper Fig. 16 / Section 6.9: the 44 benchmarks form 3 clusters in
PCA-projected feature space, each mapped to one memory function family;
within-cluster correlation to the center > 0.9999."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.pca import PCA, Scaler


def main() -> dict:
    apps, train, moe, _ = get_suite()
    X = np.asarray([a.features for a in apps])
    scaler = Scaler.fit(X)
    pca = PCA.fit(scaler.transform(X), n_components=2)
    Z = pca.transform(scaler.transform(X))
    fams = np.asarray([a.family for a in apps])
    payload = {"clusters": {}}
    purity_ok = True
    for fam in np.unique(fams):
        pts = Z[fams == fam]
        center = pts.mean(axis=0)
        # pearson correlation of each point with its cluster center
        corrs = []
        for p in pts:
            denom = (np.linalg.norm(p - p.mean())
                     * np.linalg.norm(center - center.mean()))
            if denom < 1e-12:
                corrs.append(1.0)
            else:
                corrs.append(float(
                    np.dot(p - p.mean(), center - center.mean()) / denom))
        # cluster tightness: max in-cluster distance vs distance to the
        # nearest other cluster center
        others = [Z[fams == f].mean(axis=0) for f in np.unique(fams)
                  if f != fam]
        sep = min(np.linalg.norm(center - o) for o in others)
        radius = float(np.max(np.linalg.norm(pts - center, axis=1)))
        payload["clusters"][fam] = {
            "n": int((fams == fam).sum()),
            "min_corr": float(np.min(corrs)),
            "radius": radius, "separation": float(sep),
        }
        purity_ok &= radius < sep
        emit(f"fig16_cluster_{fam}", int((fams == fam).sum()),
             f"min_corr={np.min(corrs):.4f};r/sep={radius/sep:.2f}")
    # selector accuracy over all 44 (the clusters are why KNN works)
    acc = np.mean([moe.select_family(a.features)[0] == a.family
                   for a in apps])
    payload["selector_accuracy"] = float(acc)
    payload["clusters_separable"] = bool(purity_ok)
    emit("fig16_selector_accuracy", round(float(acc), 4), "paper: 0.974")
    save_result("fig16", payload)
    return payload


if __name__ == "__main__":
    main()
