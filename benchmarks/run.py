"""Benchmark orchestrator: one module per paper table/figure.

``python -m benchmarks.run``              runs everything
``python -m benchmarks.run --bench fig06 roofline``  subset
``python -m benchmarks.run --smoke --bench open_arrivals tpu_colocation``
    tiny n_jobs/n_hosts/n_mixes end-to-end pass (the CI gate)
``python -m benchmarks.run --placement sjf --bench fig06``
    run every simulation under a non-default placement policy
    (repro.sched.placement registry: fcfs / sjf / best-fit /
    arrival-aware)
``python -m benchmarks.run --estimator conservative --bench open_arrivals``
    run the OURS policy through a non-default demand estimator
    (sweepable repro.sched.estimator entries: moe / oracle /
    single-family / conservative; baselines keep their defining
    predictors) — the CI smoke gate sweeps moe + conservative
``python -m benchmarks.run --smoke --replicas 2 --router net-aware --bench serving_bench``
    size the serving bench's multi-replica routing cell
    (repro.sched.cluster Router registry: single / least-loaded /
    net-aware / drf — drf is the weighted-DRF fairness router from
    repro.sched.tenancy; the serving bench's noisy-neighbor tenancy
    cell always runs drf internally regardless of --router)

Prints ``name,value,derived`` CSV rows; per-bench JSON lands in results/.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

BENCHES = [
    "fig06_stp_antt",      # main result: STP/ANTT L1..L10, 5 policies
    "fig07_utilization",   # utilization trace + makespan, L10 mix
    "fig09_unified",       # MoE vs unified single-model predictors
    "fig10_online_search",  # vs descent-search allocation
    "fig11_overhead",      # profiling overhead fractions
    "fig13_cpu_load",      # isolation CPU load distribution
    "fig14_interference",  # pairwise co-location slowdown distribution
    "fig16_clusters",      # PCA cluster structure + selector accuracy
    "fig17_accuracy",      # LOOCV memory prediction error
    "table5_classifiers",  # alternative expert selectors
    "roofline",            # dry-run roofline table (all cells)
    "kernel_bench",        # kernel wrappers (interpret-mode) + XLA refs
    "tpu_colocation",      # beyond-paper: TPU-jobs universe
    "open_arrivals",       # beyond-paper: Poisson stream, windowed STP
    "serving_bench",       # beyond-paper: continuous vs wave serving
    "elastic_bench",       # beyond-paper: elastic vs rigid under failures
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", nargs="*", default=None,
                    help="prefixes of benchmarks to run")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny n_jobs/n_hosts/n_mixes smoke pass (CI)")
    ap.add_argument("--placement", default=None,
                    help="placement policy for every SimConfig "
                         "(fcfs/sjf/best-fit/arrival-aware)")
    ap.add_argument("--estimator", default=None,
                    help="demand estimator for the OURS policy in every "
                         "SimConfig (moe/oracle/single-family/"
                         "conservative)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="replica count for the serving bench's "
                         "multi-replica routing cell")
    ap.add_argument("--router", default=None,
                    help="router for the serving bench's multi-replica "
                         "cell (single/least-loaded/net-aware/drf)")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome/Perfetto trace of the serving "
                         "bench's two-rack cell to this path; the bench "
                         "validates the trace against the trace_event "
                         "schema and asserts the traced run's metrics "
                         "are bit-identical to the untraced run")
    args = ap.parse_args()
    # env, not arguments: bench modules build their SimConfigs
    # themselves; the environment is read at (deferred) import time
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        os.environ.setdefault("REPRO_BENCH_MIXES", "2")
    if args.placement is not None:
        from repro.sched.placement import available_placements
        if args.placement not in available_placements():
            ap.error(f"unknown placement {args.placement!r} "
                     f"(available: {available_placements()})")
        os.environ["REPRO_PLACEMENT"] = args.placement
    if args.estimator is not None:
        from repro.sched.estimator import SWEEPABLE_ESTIMATORS
        if args.estimator not in SWEEPABLE_ESTIMATORS:
            ap.error(f"estimator {args.estimator!r} is not sweepable "
                     f"(choose from: {SWEEPABLE_ESTIMATORS})")
        os.environ["REPRO_ESTIMATOR"] = args.estimator
    if args.replicas is not None:
        if args.replicas < 1:
            ap.error(f"--replicas must be >= 1 (got {args.replicas})")
        os.environ["REPRO_SERVE_REPLICAS"] = str(args.replicas)
    if args.router is not None:
        from repro.sched.cluster import available_routers
        if args.router not in available_routers():
            ap.error(f"unknown router {args.router!r} "
                     f"(available: {available_routers()})")
        os.environ["REPRO_SERVE_ROUTER"] = args.router
    if args.trace is not None:
        os.environ["REPRO_TRACE"] = args.trace
    todo = BENCHES if not args.bench else [
        b for b in BENCHES if any(b.startswith(p) for p in args.bench)]
    failures = []
    for name in todo:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks completed")


if __name__ == "__main__":
    main()
