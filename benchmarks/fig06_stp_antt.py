"""Paper Fig. 6: normalized STP (a) and ANTT reduction (b) across runtime
scenarios L1..L10 for OURS / QUASAR / PAIRWISE / ONLINE / ORACLE."""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_MIXES, emit, get_policies, get_suite, \
    save_result
from repro.core.metrics import SCENARIOS, run_all_scenarios


def main() -> dict:
    apps, _, _, _ = get_suite()
    pols = get_policies()
    factories = {n: (lambda mix, p=p: p) for n, p in pols.items()}
    res = run_all_scenarios(apps, factories, n_mixes=N_MIXES, seed=0)

    payload = {}
    for pol in res:
        per_sc = res[pol]
        stps = [per_sc[sc].stp_gmean for sc in SCENARIOS]
        reds = [per_sc[sc].antt_reduction_mean for sc in SCENARIOS]
        payload[pol] = {
            "stp_per_scenario": dict(zip(SCENARIOS, stps)),
            "antt_reduction_per_scenario": dict(zip(SCENARIOS, reds)),
            "stp_min": {sc: per_sc[sc].stp_min for sc in SCENARIOS},
            "stp_max": {sc: per_sc[sc].stp_max for sc in SCENARIOS},
            "stp_avg": float(np.mean(stps)),
            "antt_reduction_avg": float(np.mean(reds)),
        }
        for sc in SCENARIOS:
            emit(f"fig06_stp_{pol}_{sc}", round(per_sc[sc].stp_gmean, 3),
                 f"min={per_sc[sc].stp_min:.2f};max={per_sc[sc].stp_max:.2f}")
        emit(f"fig06_stp_avg_{pol}", round(float(np.mean(stps)), 3))
        emit(f"fig06_anttred_avg_{pol}",
             round(float(np.mean(reds)) * 100, 1), "percent")

    ours, oracle = payload["ours"], payload["oracle"]
    quasar, pairwise = payload["quasar"], payload["pairwise"]
    derived = {
        "ours_stp_avg": ours["stp_avg"],
        "ours_frac_of_oracle_stp": ours["stp_avg"] / oracle["stp_avg"],
        "ours_over_quasar_stp": ours["stp_avg"] / quasar["stp_avg"],
        "ours_over_pairwise_stp": ours["stp_avg"] / pairwise["stp_avg"],
        "ours_antt_reduction_avg": ours["antt_reduction_avg"],
        "paper_claims": {
            "stp_avg": 8.69, "frac_of_oracle": 0.839,
            "over_quasar": 1.28, "antt_reduction": 0.49},
    }
    emit("fig06_ours_frac_of_oracle",
         round(derived["ours_frac_of_oracle_stp"], 3), "paper: 0.839")
    emit("fig06_ours_antt_reduction",
         round(derived["ours_antt_reduction_avg"], 3), "paper: 0.49")
    payload["derived"] = derived
    save_result("fig06", payload)
    return payload


if __name__ == "__main__":
    main()
