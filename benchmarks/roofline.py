"""Roofline table from the dry-run JSON: three terms per (arch x shape x
mesh), dominant bottleneck, MODEL_FLOPS/HLO_FLOPS utilization ratio."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, load_dryrun, save_result
from repro.launch.mesh import PEAK_FLOPS_BF16


def model_flops(rec) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N_active*D for
    inference steps — GLOBAL flops for the cell's token count.

    Token count comes from the shape cell: train/prefill process B x S
    tokens per step; decode processes B (one new token per sequence)."""
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    if rec["kind"] in ("train", "prefill"):
        d = shape.global_batch * shape.seq_len
    else:
        d = shape.global_batch
    n = rec["params_active"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n * d


def main() -> dict:
    res = load_dryrun()
    if not res:
        print("roofline,SKIPPED,no dryrun json (run repro.launch.dryrun)")
        return {}
    payload = {}
    rows = []
    for key, rec in sorted(res.items()):
        if not rec.get("ok"):
            continue
        r = rec["roofline"]
        step_bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mf = model_flops(rec)
        hlo_global = rec["cost"]["flops_per_device"] * rec["chips"]
        useful = mf / max(hlo_global, 1e-9)
        # roofline fraction: useful-compute time over the bound step time
        ideal_s = mf / (rec["chips"] * PEAK_FLOPS_BF16)
        frac = ideal_s / max(step_bound, 1e-12)
        payload[key] = {
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_flops_ratio": useful,
            "roofline_fraction": frac,
        }
        rows.append((key, frac, r["dominant"]))
        emit(f"roofline_{key}", round(frac, 4),
             f"dom={r['dominant']};c={r['compute_s']:.3g}s;"
             f"m={r['memory_s']:.3g}s;n={r['collective_s']:.3g}s;"
             f"useful={useful:.2f}")
    fracs = [f for _, f, _ in rows]
    doms = [d for _, _, d in rows]
    payload["summary"] = {
        "cells": len(rows),
        "median_fraction": float(np.median(fracs)),
        "worst": min(rows, key=lambda x: x[1])[0] if rows else None,
        "best": max(rows, key=lambda x: x[1])[0] if rows else None,
        "dominant_histogram": {d: doms.count(d) for d in set(doms)},
    }
    emit("roofline_median_fraction",
         round(payload["summary"]["median_fraction"], 4))
    emit("roofline_dominant_hist",
         str(payload["summary"]["dominant_histogram"]).replace(",", ";"))
    save_result("roofline", payload)
    return payload


if __name__ == "__main__":
    main()
