"""Paper Fig. 13: CPU load distribution in isolation (mostly < 40%) —
the headroom co-location exploits."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result


def main() -> dict:
    apps, _, _, _ = get_suite()
    loads = np.asarray([a.cpu_load for a in apps])
    payload = {
        "mean": float(loads.mean()),
        "median": float(np.median(loads)),
        "p90": float(np.percentile(loads, 90)),
        "frac_under_40pct": float(np.mean(loads < 0.4)),
        "per_app": {a.name: a.cpu_load for a in apps},
    }
    emit("fig13_mean_load", round(payload["mean"], 3),
         "paper: averaged CPU load under 40%")
    emit("fig13_frac_under_40pct", round(payload["frac_under_40pct"], 3))
    save_result("fig13", payload)
    return payload


if __name__ == "__main__":
    main()
