"""Paper Table 5: expert-selection accuracy for alternative classifiers.
Evaluated over the 44 apps with LOOCV (training labels from curve fits)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.classifiers import make_table5_classifiers
from repro.core.pca import PCA, Scaler
from repro.core import experts
from repro.core.predictor import profile_curve
from repro.core.workloads import loocv_training_set


def main() -> dict:
    apps, train, _, _ = get_suite()
    rng = np.random.default_rng(0)
    # label every training app by its best-fit family
    labels = {}
    for a in train:
        xs, ys = profile_curve(a, rng)
        fn, _ = experts.best_family(xs, ys)
        labels[a.name] = fn.family
    payload = {}
    for name in make_table5_classifiers():
        correct = 0
        for target in apps:
            tr = loocv_training_set(apps, target)
            X = np.asarray([a.features for a in tr])
            y = np.asarray([labels.get(a.name, a.family) for a in tr])
            scaler = Scaler.fit(X)
            pca = PCA.fit(scaler.transform(X),
                          n_components=min(5, X.shape[1]))
            clf = make_table5_classifiers()[name]
            clf.fit(pca.transform(scaler.transform(X)), y)
            z = pca.transform(scaler.transform(target.features[None]))
            correct += (clf.predict(z)[0] == target.family)
        acc = correct / len(apps)
        payload[name] = float(acc)
        emit(f"table5_{name.replace(' ', '_')}", round(acc * 100, 1),
             "percent")
    payload["paper_claims"] = {
        "Naive Bayes": 92.5, "SVM": 95.4, "MLP": 94.1,
        "Random Forests": 95.5, "Decision Tree": 96.8, "ANN": 96.9,
        "KNN": 97.4}
    save_result("table5", payload)
    return payload


if __name__ == "__main__":
    main()
