"""Paper Fig. 10: ours vs online descent-search input-size allocation
(search overhead makes it ~2.4x/2.6x worse on STP/ANTT)."""
from __future__ import annotations

from benchmarks.common import N_MIXES, emit, get_policies, get_suite, \
    save_result
from repro.core.metrics import run_scenario


def main() -> dict:
    apps, _, _, _ = get_suite()
    pols = get_policies()
    payload = {}
    for name in ("ours", "online"):
        r = run_scenario(apps, lambda mix, p=pols[name]: p, n_jobs=13,
                         n_mixes=N_MIXES, seed=2)
        payload[name] = {"stp": r.stp_gmean,
                         "antt": r.antt_gmean,
                         "antt_reduction": r.antt_reduction_mean}
        emit(f"fig10_stp_{name}", round(r.stp_gmean, 3))
    payload["derived"] = {
        "ours_over_online_stp":
            payload["ours"]["stp"] / payload["online"]["stp"],
        "ours_over_online_antt":
            payload["online"]["antt"] / payload["ours"]["antt"],
        "paper_claims": {"stp": 2.4, "antt": 2.6},
    }
    emit("fig10_ours_over_online_stp",
         round(payload["derived"]["ours_over_online_stp"], 2),
         "paper: 2.4")
    save_result("fig10", payload)
    return payload


if __name__ == "__main__":
    main()
