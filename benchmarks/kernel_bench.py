"""Kernel microbenchmarks. NOTE: interpret=True on CPU measures the
python-level Pallas simulator — correctness-scale numbers only; real-TPU
timing requires hardware. The XLA-reference timings below are the
meaningful CPU datapoints (kernel wrappers vs jnp oracles)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_result, timed


def main() -> dict:
    rng = np.random.default_rng(0)
    payload = {}

    # flash attention: oracle XLA path at a few sizes
    from repro.kernels.flash_attention.ref import attention_ref
    import jax
    ref_j = jax.jit(lambda q, k, v: attention_ref(q, k, v, scale=0.125))
    for S in (128, 256, 512):
        q = jnp.asarray(rng.normal(0, 1, (1, 4, S, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (1, 2, S, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (1, 2, S, 64)), jnp.float32)
        t = timed(lambda: jax.block_until_ready(ref_j(q, k, v)))
        flops = 4 * 1 * 4 * S * S * 64
        payload[f"attn_ref_S{S}"] = {"us": t * 1e6,
                                     "gflops": flops / t / 1e9}
        emit(f"kernel_attn_ref_S{S}", round(t * 1e6, 1),
             f"us_per_call;gflops={flops / t / 1e9:.1f}")

    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    ssd_j = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=64))
    for S in (256, 1024):
        xb = jnp.asarray(rng.normal(0, .5, (1, S, 8, 64)), jnp.float32)
        a = jnp.asarray(-np.abs(rng.normal(0, .3, (1, S, 8))), jnp.float32)
        B = jnp.asarray(rng.normal(0, .5, (1, S, 1, 64)), jnp.float32)
        C = jnp.asarray(rng.normal(0, .5, (1, S, 1, 64)), jnp.float32)
        t = timed(lambda: jax.block_until_ready(ssd_j(xb, a, B, C)))
        payload[f"ssd_ref_S{S}"] = {"us": t * 1e6}
        emit(f"kernel_ssd_ref_S{S}", round(t * 1e6, 1), "us_per_call")

    # interpret-mode Pallas (correctness-scale only)
    from repro.kernels.rmsnorm.ops import rmsnorm
    x = jnp.asarray(rng.normal(0, 1, (256, 512)), jnp.float32)
    w = jnp.ones((512,), jnp.float32)
    t = timed(lambda: jax.block_until_ready(rmsnorm(x, w, blk=128)))
    payload["rmsnorm_interpret"] = {"us": t * 1e6}
    emit("kernel_rmsnorm_interpret", round(t * 1e6, 1),
         "us_per_call;python-simulated, not TPU perf")
    save_result("kernels", payload)
    return payload


if __name__ == "__main__":
    main()
