"""Paper Fig. 17/18: predicted vs measured memory footprint under
leave-one-out cross-validation (paper: ~5% average error, worst ~8-12%
over-provision) — reported per registered demand estimator.

The MoE rows keep the paper's protocol (LOOCV for HB/BDB training apps,
the full trained selector for SP/SB); the other registry entries
(oracle / single-family / ann / conservative) run the same probe budget
through ``estimate()`` so the table compares like for like.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.predictor import MoEPredictor
from repro.core.workloads import loocv_training_set, training_apps
from repro.sched.estimator import JobTarget, get_estimator

ITEMS = 30.0    # ~280GB-class input as in the paper's figure
TOTAL = 1000.0  # full input the 5%/10% probes are taken from

#: registry entries evaluated (kv-growth targets serving models, not
#: jobs); single-family uses the power family — the strongest of the
#: one-family baselines on this suite
ESTIMATORS = ("moe", "oracle", "single-family", "ann", "conservative")


def _estimator_for(name: str, app, apps, train, full_moe, ann):
    if name == "moe":
        # LOOCV for HB/BDB apps; the full trained model for SP/SB
        # (paper 5.2)
        if app.suite in ("HB", "BDB"):
            pred = MoEPredictor().fit(loocv_training_set(apps, app))
        else:
            pred = full_moe
        return get_estimator("moe", predictor=pred)
    if name == "ann":
        return get_estimator("ann", predictor=ann)
    if name == "single-family":
        return get_estimator("single-family", family="power")
    return get_estimator(name)


def main() -> dict:
    apps, train, full_moe, ann = get_suite()
    payload: dict = {"per_estimator": {}}
    for est_name in ESTIMATORS:
        rng = np.random.default_rng(0)
        per_app, errs = {}, []
        for app in apps:
            est = _estimator_for(est_name, app, apps, train, full_moe,
                                 ann)
            de = est.estimate(JobTarget(app, TOTAL), rng=rng)
            t = float(app.true_fn(ITEMS))
            p = float(de.primary_fn(ITEMS))
            err = (p - t) / t
            errs.append(abs(err))
            per_app[app.name] = {
                "true_gb": t, "pred_gb": p, "rel_err": err,
                "family_sel": de.info.get("family"),
                "family_true": app.family,
                "conservative": de.conservative}
        payload["per_estimator"][est_name] = {
            "per_app": per_app,
            "mean_abs_err": float(np.mean(errs)),
            "max_abs_err": float(np.max(errs)),
        }
        emit(f"fig17_mean_abs_err_{est_name}",
             round(float(np.mean(errs)) * 100, 2), "percent")
        emit(f"fig17_max_abs_err_{est_name}",
             round(float(np.max(errs)) * 100, 2), "percent")
    moe_row = payload["per_estimator"]["moe"]
    payload["mean_abs_err"] = moe_row["mean_abs_err"]
    payload["max_abs_err"] = moe_row["max_abs_err"]
    payload["paper_claims"] = {"mean": 0.05, "worst": 0.12}
    # the paper's headline numbers keep their original row names
    emit("fig17_mean_abs_err",
         round(moe_row["mean_abs_err"] * 100, 2), "percent; paper: ~5")
    emit("fig17_max_abs_err",
         round(moe_row["max_abs_err"] * 100, 2),
         "percent; paper: 8-12 over-provision on worst apps")
    save_result("fig17", payload)
    return payload


if __name__ == "__main__":
    main()
