"""Paper Fig. 17/18: predicted vs measured memory footprint under
leave-one-out cross-validation (paper: ~5% average error, worst ~8-12%
over-provision)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_suite, save_result
from repro.core.predictor import MoEPredictor
from repro.core.workloads import loocv_training_set, training_apps


def main() -> dict:
    apps, train, _, _ = get_suite()
    rng = np.random.default_rng(0)
    payload = {"per_app": {}}
    errs = []
    # LOOCV for HB/BDB apps; the full trained model for SP/SB (paper 5.2)
    full = MoEPredictor().fit(train)
    items = 30.0  # ~280GB-class input as in the paper's figure
    for app in apps:
        if app.suite in ("HB", "BDB"):
            pred = MoEPredictor().fit(loocv_training_set(apps, app))
        else:
            pred = full
        fn, info = pred.predict_function(app, 1000.0, rng)
        t = float(app.true_fn(items))
        p = float(fn(items))
        err = (p - t) / t
        errs.append(abs(err))
        payload["per_app"][app.name] = {
            "true_gb": t, "pred_gb": p, "rel_err": err,
            "family_sel": info["family"], "family_true": app.family}
    payload["mean_abs_err"] = float(np.mean(errs))
    payload["max_abs_err"] = float(np.max(errs))
    payload["paper_claims"] = {"mean": 0.05, "worst": 0.12}
    emit("fig17_mean_abs_err", round(float(np.mean(errs)) * 100, 2),
         "percent; paper: ~5")
    emit("fig17_max_abs_err", round(float(np.max(errs)) * 100, 2),
         "percent; paper: 8-12 over-provision on worst apps")
    save_result("fig17", payload)
    return payload


if __name__ == "__main__":
    main()
