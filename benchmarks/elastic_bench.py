"""Elastic runtime acceptance bench (``repro.sched.elastic``): rigid
OURS vs elastic OURS under the same hostile environment — a diurnal
arrival stream with deterministic seeded host failures on the cluster
simulator, and a bursty request stream with replica failures on the
serving engine.

Two cells, one acceptance bar each, both STRICT:

* **simulator / diurnal+failures** — a memory-scarce cluster fed a
  low-high-low diurnal stream of spill-friendly (slope-dominated) jobs
  while a :class:`FailureSchedule` knocks hosts out.  Elastic OURS
  (``SimConfig.elastic`` bound: a chunk that does not fit a host's
  headroom may run on a shrunken memory fraction at the modeled spill
  slowdown) must STRICTLY beat rigid OURS on STP.  The mechanism:
  rigid admission either waits or force-places on empty hosts and pays
  the 8x paging slowdown + OOM kill-retry churn; elastic admission
  caps the resident set at the granted fraction and pays a PRICED
  <= ``SIM_MAX_SLOWDOWN`` spill slowdown instead.  Both runs share the
  identical failure plan (same seed, pre-drawn events).

* **serving / burst+failures** — a steady request stream with a 7.5x
  burst on a KV-tight replica cell while the failure plan kills and
  repairs replicas (live requests drain and requeue).  Elastic serving
  (SHALLOW shrunken joins — fractions >= 0.75 priced under a 1.5x cap
  — plus queue/SLO-trend autoscaling over pre-provisioned spare
  replicas) must STRICTLY beat the rigid fleet on SLO goodput, under
  the same failures and the same arrivals.  Deep shrinks lose here
  (admit-evict churn as frozen grants outgrow the budget), which is
  exactly why the depth knob exists — the bench pins the regime where
  shrinking helps.

Numbers land in ``BENCH_elastic.json`` at the repo root (STP and SLO
goodput both cells, shrink/fail/repair/scale event counts), so the
elastic-runtime trajectory is pinned across PRs.

    PYTHONPATH=src python -m benchmarks.run --bench elastic_bench
    PYTHONPATH=src python -m benchmarks.run --smoke --bench elastic_bench
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import SMOKE, emit, get_suite, save_result

# --- the simulator cell: diurnal stream + host failures --------------------
SIM_SEED = 42
# (rate jobs/s, duration s): quiet ramp, peak, quiet drain.  The peak
# keeps the memory-scarce hosts busy enough that chunk-sized headroom
# is rare, so spill-aware shrinking has something to relieve.
SIM_PHASES = ((0.004, 400.0), (0.06, 1200.0), (0.004, 400.0))
SIM_HOSTS = 4
SIM_HOST_MEM_GB = 10.0          # memory-scarce vs medium-job chunks
SIM_TASKS_PER_SLOT = 2          # coarse partitions: chunks big enough
#                                 that a full-size slot is a real ask
SIM_MTBF_S = 600.0              # per-fleet failure cadence (virtual s)
SIM_REPAIR_S = 120.0
SIM_MAX_SLOWDOWN = 2.9          # just under the spill model's 3.0 cost:
#                                 deep shrinks admit, disk-bound ones don't
#: jobs whose memory floor (quarter-chunk intercept) stays under 1 GB —
#: the slope-dominated ETL mix where spilling is physically meaningful
#: (a PageRank-style 20 GB resident floor cannot spill)
SIM_FLOOR_GB = 1.0
SIM_SIZE_WEIGHTS = {"small": 0.5, "medium": 0.5, "large": 0.0}

# --- the serving cell: burst + replica failures ----------------------------
SRV_SEED = 11
SRV_REPLICAS = 2                # the rigid fleet
SRV_AUTOSCALE_MAX = 4           # elastic fleet ceiling (spares start down)
SRV_N_STEADY = 8
SRV_N_BURST = 32
SRV_RATE_STEADY = 8.0           # requests/s of virtual time
SRV_RATE_BURST = 60.0           # the 7.5x burst
SRV_PROMPT_LEN = 24
SRV_MAX_NEW = 32
SRV_WEIGHTS_GB = 0.5
SRV_KV_GB_PER_TOKEN = 2e-4
SRV_KV_MULT = 2.0               # KV-tight: joins actually compete
SRV_TTFT_SLO_S = 0.15
SRV_TPOT_SLO_S = 0.05
SRV_MTBF_S = 1.5                # replica failures during the burst
SRV_REPAIR_S = 0.4
SRV_FAIL_HORIZON_S = 2.5
SRV_AUTOSCALE_INTERVAL_S = 0.1
# shallow shrink: joins at >= 3/4 of the full KV grant, priced under a
# 1.5x step-slowdown cap (the sweep showed deep shrinks churn)
SRV_SHRINK_SLOWDOWN = 1.4
SRV_SHRINK_MIN_FRACTION = 0.75
SRV_MAX_SLOWDOWN = 1.5

BENCH_ELASTIC_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_elastic.json")


def _spilly_apps():
    """The slope-dominated sub-universe: apps whose quarter-chunk
    footprint is essentially all working set (intercept < 1 GB), so a
    shrunken grant genuinely spills items instead of cutting an
    incompressible resident floor."""
    apps, train, moe, ann = get_suite()
    return [a for a in apps if a.measure(0.0625) < SIM_FLOOR_GB], moe


def _diurnal_arrivals(apps, seed: int):
    """A deterministic low-high-low job stream: per-phase Poisson gaps
    at the phase rate, apps uniform over the spilly mix, sizes from
    the small/medium class mix (the 1000 M-item "large" class would
    saturate the 4-host cell for the whole run)."""
    from repro.sched.arrivals import Arrival, sample_input_size

    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for rate, dur in SIM_PHASES:
        end = t + dur
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= end:
                t = end
                break
            app = apps[int(rng.choice(len(apps)))]
            out.append(Arrival(t, app,
                               sample_input_size(rng, SIM_SIZE_WEIGHTS)))
    return out


def _sim_failure_plan():
    """A fresh identical plan per run (attach pushes events into the
    run's own runtime; sharing one object would double-count its
    ``n_failed`` ledger across cells)."""
    from repro.sched import FailureSchedule
    horizon = sum(d for _, d in SIM_PHASES)
    return FailureSchedule.poisson(
        seed=SIM_SEED, mtbf_s=SIM_MTBF_S, n_targets=SIM_HOSTS,
        horizon_s=horizon, repair_s=SIM_REPAIR_S)


def _run_sim(elastic_on: bool):
    """One diurnal+failures run of OURS on the memory-scarce cluster;
    only ``SimConfig.elastic`` differs between the rigid and elastic
    variants."""
    from repro.core.simulator import OursPolicy, SimConfig, Simulator
    from repro.sched import ElasticController, get_estimator

    apps, moe = _spilly_apps()
    cfg = SimConfig(
        n_hosts=SIM_HOSTS, host_mem_gb=SIM_HOST_MEM_GB,
        tasks_per_slot=SIM_TASKS_PER_SLOT,
        failure_plan=_sim_failure_plan(),
        elastic=ElasticController(max_slowdown=SIM_MAX_SLOWDOWN)
        if elastic_on else None)
    policy = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    sim = Simulator(None, policy, cfg, seed=SIM_SEED,
                    arrivals=_diurnal_arrivals(apps, SIM_SEED))
    out = sim.run()
    out["shrunk_spawns"] = int(
        sim.telemetry.counters.get("elastic.shrink", 0))
    out["failures_injected"] = cfg.failure_plan.n_failed
    return out


def _burst_requests():
    """Steady arrivals, then a 7.5x burst: the queue-depth signal the
    autoscaler keys on, and the contention the shrunken joins relieve."""
    from repro.serve import Request

    rng = np.random.default_rng(SRV_SEED)
    arrivals = []
    t = 0.0
    for _ in range(SRV_N_STEADY):
        t += float(rng.exponential(1.0 / SRV_RATE_STEADY))
        arrivals.append(t)
    for _ in range(SRV_N_BURST):
        t += float(rng.exponential(1.0 / SRV_RATE_BURST))
        arrivals.append(t)
    return [Request(rid=i,
                    prompt_len=int(rng.integers(SRV_PROMPT_LEN // 2,
                                                SRV_PROMPT_LEN + 1)),
                    max_new_tokens=int(rng.integers(SRV_MAX_NEW // 4,
                                                    SRV_MAX_NEW + 1)),
                    arrival=float(a),
                    ttft_deadline=SRV_TTFT_SLO_S,
                    tpot_deadline=SRV_TPOT_SLO_S)
            for i, a in enumerate(arrivals)]


def _srv_failure_plan():
    from repro.sched import FailureSchedule
    return FailureSchedule.poisson(
        seed=SRV_SEED + 1, mtbf_s=SRV_MTBF_S, n_targets=SRV_REPLICAS,
        horizon_s=SRV_FAIL_HORIZON_S, repair_s=SRV_REPAIR_S)


def _run_serving(elastic_on: bool):
    """One burst+failures serving run; the elastic variant adds
    shallow shrunken joins and autoscaling over pre-provisioned
    spares, the failure plan and the arrivals are identical."""
    from repro.sched import Autoscaler, ElasticController
    from repro.sched.elastic import SlowdownCurve
    from repro.sched.resources import ResourceVector
    from repro.serve import Engine, ServingDemand

    full_ctx = SRV_PROMPT_LEN + SRV_MAX_NEW
    demand = ServingDemand(weights_gb=SRV_WEIGHTS_GB,
                           kv_gb_per_token=SRV_KV_GB_PER_TOKEN)
    budget = ResourceVector(
        hbm=SRV_WEIGHTS_GB
        + SRV_KV_GB_PER_TOKEN * full_ctx * SRV_KV_MULT)
    elastic = autoscaler = None
    if elastic_on:
        # the serving demand's shrink curve: the kv-growth estimator
        # attaches one on the CLI path; the bench's hand-built demand
        # declares the shallow linear family explicitly
        demand.shrink = SlowdownCurve.linear(
            SRV_SHRINK_SLOWDOWN,
            min_fraction=SRV_SHRINK_MIN_FRACTION)
        elastic = ElasticController(max_slowdown=SRV_MAX_SLOWDOWN)
        autoscaler = Autoscaler(max_replicas=SRV_AUTOSCALE_MAX,
                                min_replicas=SRV_REPLICAS,
                                interval_s=SRV_AUTOSCALE_INTERVAL_S,
                                sustain=2)
    engine = Engine(_burst_requests(), demand, budget,
                    mode="continuous", placement="fcfs", max_batch=32,
                    replicas=SRV_REPLICAS, router="least-loaded",
                    failures=_srv_failure_plan(), elastic=elastic,
                    autoscaler=autoscaler)
    summary = engine.run()
    for dec in engine.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, (
            f"unforced over-budget step in elastic bench: {dec}")
    return summary


def main() -> dict:
    # --- simulator: diurnal + host failures, rigid vs elastic -------------
    rigid = _run_sim(elastic_on=False)
    elastic = _run_sim(elastic_on=True)
    stp_ratio = elastic["stp"] / max(rigid["stp"], 1e-12)
    emit("elastic/sim/stp_rigid", f"{rigid['stp']:.3f}",
         f"antt {rigid['antt']:.1f}, {rigid['oom_count']} OOM kills, "
         f"{rigid['failures_injected']} host failures injected")
    emit("elastic/sim/stp_elastic", f"{elastic['stp']:.3f}",
         f"antt {elastic['antt']:.1f}, {elastic['oom_count']} OOM "
         f"kills, {elastic['shrunk_spawns']} shrunken executor spawns")
    emit("elastic/sim/stp_ratio", f"{stp_ratio:.3f}",
         "elastic / rigid OURS, diurnal stream + failure plan")

    # --- serving: burst + replica failures, rigid vs elastic fleet --------
    srigid = _run_serving(elastic_on=False)
    selastic = _run_serving(elastic_on=True)
    slo_ratio = selastic["slo_goodput_tok_s"] \
        / max(srigid["slo_goodput_tok_s"], 1e-12)
    el = selastic.get("elastic", {})
    ev = el.get("replica_events", {})
    rigid_fails = srigid.get("elastic", {}).get(
        "replica_events", {}).get("fail", 0)
    emit("elastic/serve/slo_goodput_rigid",
         f"{srigid['slo_goodput_tok_s']:.1f}",
         f"attainment {srigid['slo_attainment']:.2f}, "
         f"{rigid_fails} replica failures")
    emit("elastic/serve/slo_goodput_elastic",
         f"{selastic['slo_goodput_tok_s']:.1f}",
         f"attainment {selastic['slo_attainment']:.2f}, "
         f"{el.get('shrunk_joins', 0)} shrunken joins, events "
         f"[{' '.join(f'{k}:{n}' for k, n in sorted(ev.items()))}]")
    emit("elastic/serve/slo_ratio", f"{slo_ratio:.3f}",
         "elastic (shallow shrink + autoscale) / rigid fleet")

    payload = {
        "smoke": SMOKE,
        "sim": {
            "seed": SIM_SEED, "hosts": SIM_HOSTS,
            "host_mem_gb": SIM_HOST_MEM_GB,
            "phases": [list(p) for p in SIM_PHASES],
            "mtbf_s": SIM_MTBF_S, "repair_s": SIM_REPAIR_S,
            "max_slowdown": SIM_MAX_SLOWDOWN,
            "rigid": {"stp": rigid["stp"], "antt": rigid["antt"],
                      "oom": rigid["oom_count"],
                      "failures": rigid["failures_injected"]},
            "elastic": {"stp": elastic["stp"], "antt": elastic["antt"],
                        "oom": elastic["oom_count"],
                        "shrunk_spawns": elastic["shrunk_spawns"],
                        "failures": elastic["failures_injected"]},
            "stp_ratio": stp_ratio},
        "serving": {
            "seed": SRV_SEED, "replicas": SRV_REPLICAS,
            "autoscale_max": SRV_AUTOSCALE_MAX,
            "kv_mult": SRV_KV_MULT, "mtbf_s": SRV_MTBF_S,
            "shrink": {"slowdown": SRV_SHRINK_SLOWDOWN,
                       "min_fraction": SRV_SHRINK_MIN_FRACTION,
                       "cap": SRV_MAX_SLOWDOWN},
            "rigid": {
                "goodput_tok_s": srigid["goodput_tok_s"],
                "slo_goodput_tok_s": srigid["slo_goodput_tok_s"],
                "slo_attainment": srigid["slo_attainment"],
                "preemptions": srigid["preemptions"],
                "elastic": srigid.get("elastic", {})},
            "elastic": {
                "goodput_tok_s": selastic["goodput_tok_s"],
                "slo_goodput_tok_s": selastic["slo_goodput_tok_s"],
                "slo_attainment": selastic["slo_attainment"],
                "preemptions": selastic["preemptions"],
                "elastic": el},
            "slo_ratio": slo_ratio}}
    with open(BENCH_ELASTIC_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    emit("elastic/pinned", BENCH_ELASTIC_JSON,
         "STP + SLO goodput, rigid vs elastic, both cells")
    save_result("elastic_bench", payload)

    # --- the acceptance bars, both STRICT ---------------------------------
    if elastic["shrunk_spawns"] < 1:
        raise AssertionError(
            "no shrunken executor spawn fired in the simulator cell — "
            "the spill-aware admission path is dead")
    if elastic["stp"] <= rigid["stp"]:
        raise AssertionError(
            f"elastic OURS did not strictly beat rigid OURS on STP "
            f"under the diurnal+failures stream: {elastic['stp']:.3f} "
            f"vs {rigid['stp']:.3f}")
    if el.get("shrunk_joins", 0) < 1:
        raise AssertionError(
            "no shrunken join fired in the serving cell — the elastic "
            "batcher path is dead")
    if not ev.get("scale_up"):
        raise AssertionError(
            "the autoscaler never scaled up under the 7.5x burst — "
            "the queue-depth trigger is dead")
    if selastic["slo_goodput_tok_s"] <= srigid["slo_goodput_tok_s"]:
        raise AssertionError(
            f"the elastic fleet did not strictly beat the rigid fleet "
            f"on SLO goodput under burst+failures: "
            f"{selastic['slo_goodput_tok_s']:.1f} vs "
            f"{srigid['slo_goodput_tok_s']:.1f} tok/s")
    return payload


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SMOKE", "1")
    main()
