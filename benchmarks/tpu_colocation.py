"""Beyond-paper: the paper's co-location scheduler applied to the TPU-jobs
universe — the assigned (arch x shape) cells as schedulable jobs on a
fleet of pods. The affine expert (our library extension) is what makes
these weight-dominated/SSM curves predictable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_MIXES, emit, load_dryrun, save_result
from repro.core import MoEPredictor, OraclePredictor, tpu_jobs_suite
from repro.core.metrics import run_scenario
from repro.core.simulator import (OraclePolicy, OursPolicy, PairwisePolicy,
                                  SimConfig)


def main() -> dict:
    jobs = tpu_jobs_suite(load_dryrun())
    # "hosts" are pods: 256 chips x 16 GB HBM = 4 TB per pod; a 16-pod fleet
    cfg = SimConfig(n_hosts=16, host_mem_gb=4096.0, min_alloc_gb=64.0)
    moe = MoEPredictor().fit(jobs[:16])  # half the cells train the selector
    factories = {
        "ours": lambda m: OursPolicy(moe),
        "oracle": lambda m: OraclePolicy(OraclePredictor()),
        "pairwise": lambda m: PairwisePolicy(),
    }
    payload = {}
    for name, factory in factories.items():
        r = run_scenario(jobs, factory, n_jobs=12,
                         n_mixes=max(N_MIXES // 2, 3), cfg=cfg, seed=9)
        payload[name] = {"stp": r.stp_gmean,
                         "antt_reduction": r.antt_reduction_mean,
                         "oom": r.oom_total}
        emit(f"tpu_colocation_stp_{name}", round(r.stp_gmean, 3),
             f"oom={r.oom_total}")
    payload["derived"] = {
        "ours_frac_of_oracle": payload["ours"]["stp"]
        / payload["oracle"]["stp"]}
    emit("tpu_colocation_ours_frac_of_oracle",
         round(payload["derived"]["ours_frac_of_oracle"], 3))
    save_result("tpu_colocation", payload)
    return payload


if __name__ == "__main__":
    main()
