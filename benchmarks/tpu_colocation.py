"""Beyond-paper: the paper's co-location scheduler applied to the TPU-jobs
universe — the assigned (arch x shape) cells as schedulable jobs on a
fleet of pods. The affine expert (our library extension) is what makes
these weight-dominated/SSM curves predictable.

Two scenarios:

* **single-axis** (the original): pods expose one memory budget
  (HBM-as-host_mem), admission inverts the calibrated curve alone.
* **multi-axis** (vector-resource admission): the calibrated curve
  budgets the pod's **hbm** axis while each job also pins **host
  staging RAM** (input/token buffers, ~0.5 GB per M-item) against a
  much smaller per-pod host_ram capacity.  Admission inverts along the
  binding axis — for large splits the host_ram axis runs out before
  HBM does, which the emitted ``binding_axes`` histogram shows.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import (SMOKE, N_MIXES, emit, load_dryrun,
                               save_result)
from repro.core import MoEPredictor, OraclePredictor, tpu_jobs_suite
from repro.core.experts import MemoryFunction
from repro.core.metrics import run_scenario
from repro.core.simulator import (OraclePolicy, OursPolicy, PairwisePolicy,
                                  SimConfig)

# host staging demand per admitted M-item (GB): token queues + input
# buffers pinned in pod-host DRAM while the split is resident in HBM
HOST_STAGING_GB_PER_ITEM = 0.5
HOST_RAM_PER_POD_GB = 12.0


def _staged(jobs):
    """The multi-axis universe: same jobs, plus a host_ram side-car
    demand curve (affine through ~0: staging scales with the split)."""
    return [replace(j, aux_demand={"host_ram": MemoryFunction(
        "affine", 0.25, HOST_STAGING_GB_PER_ITEM)}) for j in jobs]


def main() -> dict:
    jobs = tpu_jobs_suite(load_dryrun())
    # "hosts" are pods: 256 chips x 16 GB HBM = 4 TB per pod; a 16-pod fleet
    n_mixes = 1 if SMOKE else max(N_MIXES // 2, 3)
    n_jobs = 6 if SMOKE else 12
    n_hosts = 4 if SMOKE else 16
    cfg = SimConfig(n_hosts=n_hosts, host_mem_gb=4096.0, min_alloc_gb=64.0)
    moe = MoEPredictor().fit(jobs[:16])  # half the cells train the selector
    factories = {
        "ours": lambda m: OursPolicy(moe),
        "oracle": lambda m: OraclePolicy(OraclePredictor()),
        "pairwise": lambda m: PairwisePolicy(),
    }
    payload = {}
    for name, factory in factories.items():
        r = run_scenario(jobs, factory, n_jobs=n_jobs,
                         n_mixes=n_mixes, cfg=cfg, seed=9)
        payload[name] = {"stp": r.stp_gmean,
                         "antt_reduction": r.antt_reduction_mean,
                         "oom": r.oom_total}
        emit(f"tpu_colocation_stp_{name}", round(r.stp_gmean, 3),
             f"oom={r.oom_total}")
    payload["derived"] = {
        "ours_frac_of_oracle": payload["ours"]["stp"]
        / payload["oracle"]["stp"]}
    emit("tpu_colocation_ours_frac_of_oracle",
         round(payload["derived"]["ours_frac_of_oracle"], 3))

    # --- multi-axis: HBM primary + host staging RAM ---------------------
    staged = _staged(jobs)
    cfg_vec = SimConfig(n_hosts=n_hosts, host_mem_gb=4096.0,
                        min_alloc_gb=64.0, primary_axis="hbm",
                        extra_capacity={"host_ram": HOST_RAM_PER_POD_GB})
    payload["multiaxis"] = {}
    for name, factory in (("ours", factories["ours"]),
                          ("oracle", factories["oracle"])):
        r = run_scenario(staged, factory, n_jobs=n_jobs,
                         n_mixes=n_mixes, cfg=cfg_vec, seed=9)
        payload["multiaxis"][name] = {
            "stp": r.stp_gmean,
            "antt_reduction": r.antt_reduction_mean,
            "oom": r.oom_total, "binding_axes": r.binding_axes}
        emit(f"tpu_colocation_multiaxis_stp_{name}", round(r.stp_gmean, 3),
             " ".join(f"{a}:{c}" for a, c in
                      sorted(r.binding_axes.items())))
    ours_bind = payload["multiaxis"]["ours"]["binding_axes"]
    non_primary = sum(c for a, c in ours_bind.items()
                      if a not in ("hbm", "cap"))
    emit("tpu_colocation_multiaxis_nonprimary_bound", non_primary,
         "admissions bound by a non-HBM axis (host staging RAM)")
    if non_primary == 0:
        raise AssertionError(
            f"multi-axis scenario never exercised a non-primary binding "
            f"axis: {ours_bind}")
    save_result("tpu_colocation", payload)
    return payload


if __name__ == "__main__":
    main()
