"""Beyond-paper: the paper's co-location scheduler applied to the TPU-jobs
universe — the assigned (arch x shape) cells as schedulable jobs on a
fleet of pods. The affine expert (our library extension) is what makes
these weight-dominated/SSM curves predictable.

Two scenarios:

* **single-axis** (the original): pods expose one memory budget
  (HBM-as-host_mem), admission inverts the calibrated curve alone.
* **multi-axis** (vector-resource admission): the calibrated curve
  budgets the pod's **hbm** axis while each job also pins **host
  staging RAM** (input/token buffers, ~0.5 GB per M-item) against a
  much smaller per-pod host_ram capacity.  Admission inverts along the
  binding axis — for large splits the host_ram axis runs out before
  HBM does, which the emitted ``binding_axes`` histogram shows.
* **net-axis** (live interconnect contention): each job streams
  ~2 Gbps of interconnect traffic per admitted M-item against a
  per-pod link budget.  The estimator PREDICTS the linear contention
  curve from aux probes (no declared curve reaches admission), and the
  link — not HBM — binds large splits: the scenario asserts
  ``binding_axis == "net"`` admissions occurred.

Side-car demand is *predicted* since the DemandEstimator redesign:
``aux_demand`` below declares the ground truth the estimators probe
(``AppProfile.measure_axis``), it is no longer read by admission.
"""
from __future__ import annotations

import os
from dataclasses import replace

import numpy as np

from benchmarks.common import (SMOKE, N_MIXES, emit, load_dryrun,
                               save_result)
from repro.core import MoEPredictor, OraclePredictor, tpu_jobs_suite
from repro.core.experts import MemoryFunction
from repro.core.metrics import run_scenario
from repro.core.simulator import (OraclePolicy, OursPolicy, PairwisePolicy,
                                  SimConfig)

# host staging demand per admitted M-item (GB): token queues + input
# buffers pinned in pod-host DRAM while the split is resident in HBM
HOST_STAGING_GB_PER_ITEM = 0.5
HOST_RAM_PER_POD_GB = 12.0
# interconnect traffic per admitted M-item (Gbps): gradient/activation
# streaming scales linearly with the split (the simple linear
# contention model) against a per-pod link budget
NET_GBPS_PER_ITEM = 2.0
NET_GBPS_PER_POD = 40.0

# The binding-axis assertions are calibrated for the default (moe)
# estimator.  Under an --estimator sweep (e.g. conservative, whose
# halved memory budgets push large splits below the quarter-chunk
# co-location threshold) the scenario still runs end-to-end but the
# histograms are report-only.
_SWEPT = os.environ.get("REPRO_ESTIMATOR", "") not in ("", "moe")


def _staged(jobs):
    """The multi-axis universe: same jobs, plus a ground-truth host_ram
    side-car curve (affine through ~0: staging scales with the split)
    the estimators probe and predict."""
    return [replace(j, aux_demand={"host_ram": MemoryFunction(
        "affine", 0.25, HOST_STAGING_GB_PER_ITEM)}) for j in jobs]


def _networked(jobs):
    """The net-axis universe: ground-truth linear interconnect demand
    per job, predicted by the estimators' affine contention fit."""
    return [replace(j, aux_demand={"net": MemoryFunction(
        "affine", 0.5, NET_GBPS_PER_ITEM)}) for j in jobs]


def main() -> dict:
    jobs = tpu_jobs_suite(load_dryrun())
    # "hosts" are pods: 256 chips x 16 GB HBM = 4 TB per pod; a 16-pod fleet
    n_mixes = 1 if SMOKE else max(N_MIXES // 2, 3)
    n_jobs = 6 if SMOKE else 12
    n_hosts = 4 if SMOKE else 16
    cfg = SimConfig(n_hosts=n_hosts, host_mem_gb=4096.0, min_alloc_gb=64.0)
    moe = MoEPredictor().fit(jobs[:16])  # half the cells train the selector
    factories = {
        "ours": lambda m: OursPolicy(moe),
        "oracle": lambda m: OraclePolicy(OraclePredictor()),
        "pairwise": lambda m: PairwisePolicy(),
    }
    payload = {}
    for name, factory in factories.items():
        r = run_scenario(jobs, factory, n_jobs=n_jobs,
                         n_mixes=n_mixes, cfg=cfg, seed=9)
        payload[name] = {"stp": r.stp_gmean,
                         "antt_reduction": r.antt_reduction_mean,
                         "oom": r.oom_total}
        emit(f"tpu_colocation_stp_{name}", round(r.stp_gmean, 3),
             f"oom={r.oom_total}")
    payload["derived"] = {
        "ours_frac_of_oracle": payload["ours"]["stp"]
        / payload["oracle"]["stp"]}
    emit("tpu_colocation_ours_frac_of_oracle",
         round(payload["derived"]["ours_frac_of_oracle"], 3))

    # --- multi-axis: HBM primary + host staging RAM ---------------------
    staged = _staged(jobs)
    cfg_vec = SimConfig(n_hosts=n_hosts, host_mem_gb=4096.0,
                        min_alloc_gb=64.0, primary_axis="hbm",
                        extra_capacity={"host_ram": HOST_RAM_PER_POD_GB})
    payload["multiaxis"] = {}
    for name, factory in (("ours", factories["ours"]),
                          ("oracle", factories["oracle"])):
        r = run_scenario(staged, factory, n_jobs=n_jobs,
                         n_mixes=n_mixes, cfg=cfg_vec, seed=9)
        payload["multiaxis"][name] = {
            "stp": r.stp_gmean,
            "antt_reduction": r.antt_reduction_mean,
            "oom": r.oom_total, "binding_axes": r.binding_axes}
        emit(f"tpu_colocation_multiaxis_stp_{name}", round(r.stp_gmean, 3),
             " ".join(f"{a}:{c}" for a, c in
                      sorted(r.binding_axes.items())))
    ours_bind = payload["multiaxis"]["ours"]["binding_axes"]
    non_primary = sum(c for a, c in ours_bind.items()
                      if a not in ("hbm", "cap"))
    emit("tpu_colocation_multiaxis_nonprimary_bound", non_primary,
         "admissions bound by a non-HBM axis (host staging RAM)")
    if non_primary == 0 and not _SWEPT:
        raise AssertionError(
            f"multi-axis scenario never exercised a non-primary binding "
            f"axis: {ours_bind}")

    # --- net-axis: live interconnect contention binds admission ---------
    networked = _networked(jobs)
    cfg_net = SimConfig(n_hosts=n_hosts, host_mem_gb=4096.0,
                        min_alloc_gb=64.0, primary_axis="hbm",
                        extra_capacity={"net": NET_GBPS_PER_POD})
    payload["netaxis"] = {}
    for name, factory in (("ours", factories["ours"]),
                          ("oracle", factories["oracle"])):
        r = run_scenario(networked, factory, n_jobs=n_jobs,
                         n_mixes=n_mixes, cfg=cfg_net, seed=9)
        payload["netaxis"][name] = {
            "stp": r.stp_gmean,
            "antt_reduction": r.antt_reduction_mean,
            "oom": r.oom_total, "binding_axes": r.binding_axes}
        emit(f"tpu_colocation_netaxis_stp_{name}", round(r.stp_gmean, 3),
             " ".join(f"{a}:{c}" for a, c in
                      sorted(r.binding_axes.items())))
    net_bound = payload["netaxis"]["ours"]["binding_axes"].get("net", 0)
    emit("tpu_colocation_netaxis_net_bound", net_bound,
         'admissions with binding_axis == "net" (predicted linear '
         'contention curve, per-pod link budget)')
    if net_bound == 0 and not _SWEPT:
        raise AssertionError(
            f"net-axis scenario never exercised a net binding axis: "
            f"{payload['netaxis']['ours']['binding_axes']}")
    save_result("tpu_colocation", payload)
    return payload


if __name__ == "__main__":
    main()
