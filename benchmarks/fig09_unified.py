"""Paper Fig. 9: mixture-of-experts vs unified single-model predictors
(one function family for everything + a monolithic ANN)."""
from __future__ import annotations

from benchmarks.common import N_MIXES, emit, get_suite, save_result
from repro.core.metrics import run_scenario
from repro.core.predictor import UnifiedFamilyPredictor
from repro.core.simulator import OursPolicy


def main() -> dict:
    apps, train, moe, ann = get_suite()
    predictors = {
        "ours_moe": moe,
        "unified_power": UnifiedFamilyPredictor("power"),
        "unified_exp": UnifiedFamilyPredictor("exp_saturation"),
        "unified_log": UnifiedFamilyPredictor("log"),
        "unified_ann": ann,
    }
    payload = {}
    for name, pred in predictors.items():
        r = run_scenario(apps, lambda mix, p=pred: OursPolicy(p),
                         n_jobs=13, n_mixes=N_MIXES, seed=1)
        payload[name] = {"stp": r.stp_gmean,
                         "antt_reduction": r.antt_reduction_mean,
                         "oom": r.oom_total}
        emit(f"fig09_stp_{name}", round(r.stp_gmean, 3),
             f"oom={r.oom_total};anttred={r.antt_reduction_mean:.3f}")
    # The paper's strongest unified baseline is the ANN; single-family
    # baselines that happen to over-provision (power) avoid OOMs but pay
    # on ANTT. We report STP vs ANN, ANTT vs all, and the OOM counts
    # (ours: zero).
    payload["derived"] = {
        "moe_over_ann_stp": payload["ours_moe"]["stp"]
        / payload["unified_ann"]["stp"],
        "moe_best_anttred": payload["ours_moe"]["antt_reduction"]
        >= max(v["antt_reduction"] for k, v in payload.items()
               if k.startswith("unified")),
        "moe_oom": payload["ours_moe"]["oom"],
        "unified_oom_total": sum(v["oom"] for k, v in payload.items()
                                 if k.startswith("unified")),
    }
    emit("fig09_moe_over_ann_stp",
         round(payload["derived"]["moe_over_ann_stp"], 3),
         "paper: MoE beats the ANN (its best unified model)")
    emit("fig09_moe_oom_vs_unified",
         f"{payload['derived']['moe_oom']} vs "
         f"{payload['derived']['unified_oom_total']}",
         "OOM-kills: ours vs all unified models combined")
    save_result("fig09", payload)
    return payload


if __name__ == "__main__":
    main()
