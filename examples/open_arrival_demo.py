"""Open-arrival serving of a live job stream, with online model refresh.

A continuously-fed cluster (Poisson arrivals) schedules a mix of known
Spark-sim applications and NOVEL applications from a feature cluster the
MoE predictor never trained on (affine memory curves — the SSM-style
footprint the paper's 3-family library must be extended with). Without
refresh, every novel arrival stays low-confidence forever and is
scheduled conservatively (half-sized executors). With
:class:`repro.sched.OnlineRefresher`, the first profiled novel arrivals
are folded back into the KNN selector, so the stream *learns the new
workload class while serving it*.

    PYTHONPATH=src python examples/open_arrival_demo.py
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import MoEPredictor, SimConfig, Simulator, spark_sim_suite, \
    training_apps
from repro.core.experts import MemoryFunction
from repro.core.metrics import windowed_metrics
from repro.core.simulator import OursPolicy
from repro.core.workloads import FEATURE_NAMES, AppProfile
from repro.sched import (ArrivalConfig, OnlineRefresher, get_estimator,
                         poisson_arrivals)


def novel_apps(n: int = 6, seed: int = 123):
    """Applications from an unseen feature cluster with affine memory
    curves (weight-dominated footprint: y = m + b*x)."""
    rng = np.random.default_rng(seed)
    center = rng.uniform(0.15, 0.85, len(FEATURE_NAMES)) + 1.5
    apps = []
    for i in range(n):
        fn = MemoryFunction("affine", float(rng.uniform(4.0, 9.0)),
                            float(rng.uniform(0.02, 0.05)))
        feat = np.clip(center + rng.normal(0, 0.015, len(FEATURE_NAMES)),
                       0, 3)
        apps.append(AppProfile(
            name=f"NV.job{i}", suite="NV", family="affine", true_fn=fn,
            cpu_load=float(rng.uniform(0.2, 0.4)),
            rate=float(rng.uniform(0.02, 0.12)), features=feat))
    return apps


def run_stream(apps, arrivals, moe, cfg, refresh: bool,
               placement: str = "fcfs"):
    # the refresher streams through the DemandEstimator registry handle
    # (partial_update), not into MoEPredictor internals
    est = get_estimator("moe", predictor=moe)
    ref = OnlineRefresher(est) if refresh else None
    sim = Simulator(None, OursPolicy(estimator=est, refresher=ref,
                                     placement=placement),
                    cfg, seed=0, arrivals=arrivals)
    out = sim.run()
    conservative = sum(j.conservative for j in sim.jobs
                       if j.app.suite == "NV")
    return out, conservative, ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=28)
    ap.add_argument("--rate", type=float, default=0.02,
                    help="Poisson arrival rate (jobs/s)")
    ap.add_argument("--hosts", type=int, default=16)
    ap.add_argument("--placement", default="fcfs",
                    help="queue/host-scan order: fcfs, sjf, best-fit, "
                         "or arrival-aware (ANTT-optimizing)")
    args = ap.parse_args()

    spark = spark_sim_suite()
    novel = novel_apps()
    universe = spark + novel
    # weight the stream so ~1/3 of arrivals are the novel class; skew
    # sizes to medium/large — tiny inputs probe a flat stretch of the
    # memory curve, which the refresher (correctly) rejects as
    # ambiguous, so an all-small stream would never teach the selector
    w = np.asarray([1.0] * len(spark)
                   + [len(spark) / (2 * len(novel))] * len(novel))
    acfg = ArrivalConfig(rate_per_s=args.rate, n_jobs=args.jobs,
                         app_weights=w,
                         size_weights={"small": 0.2, "medium": 0.4,
                                       "large": 0.4})
    arrivals = poisson_arrivals(universe, acfg, seed=3)
    n_novel = sum(a.app.suite == "NV" for a in arrivals)
    print(f"stream: {len(arrivals)} arrivals over "
          f"{arrivals[-1].t:.0f}s ({n_novel} from the novel class)")

    cfg = SimConfig(n_hosts=args.hosts)
    print(f"\n{'mode':24s} {'STP':>7s} {'ANTT':>8s} "
          f"{'conservative-NV':>16s}")
    for refresh in (False, True):
        moe = MoEPredictor().fit(training_apps(spark))
        out, conservative, ref = run_stream(
            universe, arrivals, moe, cfg, refresh, args.placement)
        label = "online refresh" if refresh else "static predictor"
        print(f"{label:24s} {out['stp']:7.2f} {out['antt']:8.2f} "
              f"{conservative:13d}/{n_novel}"
              + (f"   (folded in: {ref.accepted})" if ref else ""))

    print("\nwindowed view (online refresh), 1000s windows:")
    print(f"{'window':>12s} {'arrived':>8s} {'done':>6s} "
          f"{'in-flight':>9s} {'STP':>7s} {'ANTT':>7s}")
    for w_ in windowed_metrics(out, 1000.0):
        print(f"{int(w_['t0']):>5d}-{int(w_['t1']):<6d} "
              f"{w_['arrived']:>8d} {w_['completed']:>6d} "
              f"{w_['in_flight']:>9d} {w_['stp']:>7.2f} "
              f"{w_['antt']:>7.2f}")


if __name__ == "__main__":
    main()
