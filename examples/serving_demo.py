"""Memory-aware continuous batching: the paper's technique as a
first-class serving feature. The engine calibrates a memory function for
the model's serving footprint (weights + KV vs active requests), then
uses its INVERSE — re-evaluated at EVERY decode step — to keep the
largest request batch that fits the HBM budget: new prefills join as
soon as their KV fits, finished requests free their slots immediately,
and over-budget KV growth evicts the lowest-priority request (requeued,
recomputed later).  Exactly the paper's "how many data items under a
memory budget" loop, asked once per decode step instead of once per
wave.

    PYTHONPATH=src python examples/serving_demo.py --requests 12
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.sched import ModelTarget, ResourceVector, get_estimator
from repro.serve import Engine, JaxBackend, Request, ServingDemand


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--budget-gb", type=float, default=0.0004)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--mode", default="continuous",
                    choices=("continuous", "wave"))
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b", smoke=True)

    # --- the paper's runtime path, applied to serving capacity ---------
    # the kv-growth estimator two-point-calibrates footprint-vs-batch
    # (cached per (config, max_len) key — a second construction reuses
    # the fit)
    estimate = get_estimator("kv-growth").estimate(
        ModelTarget(cfg, args.max_len))
    fn = estimate.primary_fn
    demand = ServingDemand.from_estimate(estimate, args.max_len)
    print(f"footprint(batch) ~= {fn.m:.4f} + {fn.b:.5f} GB/slot "
          f"(calibrated at batch 2,4) -> {demand.kv_gb_per_token * 2**20:.2f} "
          f"KiB KV per token per request")
    whole = int(fn.inverse(args.budget_gb))
    print(f"HBM budget {args.budget_gb} GB -> {whole} full-length "
          f"requests fit; continuous mode packs more by admitting "
          f"against LIVE context lengths")

    # --- serve an open queue through the engine -------------------------
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt_len=int(rng.integers(8, 24)),
                    max_new_tokens=int(rng.integers(
                        max(args.decode_steps // 2, 1),
                        args.decode_steps + 1)),
                    arrival=0.0)
            for i in range(args.requests)]
    engine = Engine(reqs, demand, ResourceVector(hbm=args.budget_gb),
                    JaxBackend(cfg, max_len=args.max_len),
                    mode=args.mode, max_batch=16)
    summary = engine.run()
    print(engine.metrics.format_summary(summary))
    if summary["forced_steps"]:
        # the engine keeps making progress (min batch 1) even when the
        # weights alone exceed the budget — the decision says so
        print(f"note: {summary['forced_steps']} forced step(s) — a "
              f"single request exceeds the budget; serving anyway")
    joins = sum(1 for d in engine.metrics.steps if d.admitted)
    sample = next(r for r in reqs if r.tokens)
    print(f"served {summary['completed']} requests across "
          f"{summary['steps']} steps ({joins} join points, "
          f"{summary['preemptions']} preemptions); sample continuation "
          f"rid={sample.rid}: {sample.tokens[:6]}")


if __name__ == "__main__":
    main()
