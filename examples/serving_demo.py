"""Memory-aware batched serving: the paper's technique as a first-class
serving feature. The engine calibrates a memory function for the model's
serving footprint (weights + KV vs active requests), then uses its
INVERSE to admit the largest request batch that fits the HBM budget —
exactly the paper's "how many data items under a memory budget" loop.

    PYTHONPATH=src python examples/serving_demo.py --requests 12
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.sched import AdmissionController
from repro.utils.tree import tree_bytes


def measured_footprint_gb(cfg, batch: int, max_len: int) -> float:
    """'Profiling run': weights + allocated KV cache for ``batch`` slots."""
    w = tree_bytes(model.abstract(cfg))
    cache = model.init_cache(cfg, batch, max_len, abstract_only=True)
    return (w + tree_bytes(cache)) / 2 ** 30


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--budget-gb", type=float, default=0.35)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b", smoke=True)
    params = model.init(cfg, jax.random.key(0))

    # --- the paper's runtime path, applied to serving capacity ---------
    # two-point calibration of footprint-vs-batch (the affine expert: the
    # library extension DESIGN.md §4 motivates)
    ctrl = AdmissionController()
    x1, x2 = 2, 4
    y1 = measured_footprint_gb(cfg, x1, args.max_len)
    y2 = measured_footprint_gb(cfg, x2, args.max_len)
    fn = ctrl.calibrate("affine", [(x1, y1), (x2, y2)])
    dec = ctrl.admit_batch(fn, args.budget_gb)
    admit = int(dec.units)
    print(f"footprint(batch) ~= {fn.m:.4f} + {fn.b:.5f} GB/slot "
          f"(calibrated at batch {x1},{x2})")
    print(f"HBM budget {args.budget_gb} GB -> admit {admit} "
          f"concurrent requests")
    if dec.info["forced"]:
        # admit_batch keeps a server making progress (min_batch=1) even
        # when the weights alone exceed the budget — the decision says so
        print(f"note: forced admission — minimum batch exceeds the "
              f"budget (footprint(1) = {float(fn(1)):.4f} GB); "
              f"serving anyway")
    true_at_admit = measured_footprint_gb(cfg, admit, args.max_len)
    print(f"true footprint at admitted batch: {true_at_admit:.4f} GB "
          f"(err {abs(true_at_admit - float(fn(admit)))/true_at_admit*100:.2f}%)")

    # --- serve the queue in admitted waves ------------------------------
    rng = np.random.default_rng(0)
    queue = [rng.integers(3, cfg.vocab_size, size=rng.integers(8, 24))
             for _ in range(args.requests)]
    done = 0
    wave = 0
    while queue:
        batch_reqs, queue = queue[:admit], queue[admit:]
        B = len(batch_reqs)
        L = max(len(r) for r in batch_reqs)
        toks = np.zeros((B, L), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, L - len(r):] = r  # left-pad
        last, cache = model.prefill(params, cfg,
                                    {"tokens": jnp.asarray(toks)},
                                    max_len=args.max_len)
        out = [jnp.argmax(last, -1).astype(jnp.int32)]
        for _ in range(args.decode_steps - 1):
            lg, cache = model.decode_step(params, cfg, cache, out[-1])
            out.append(jnp.argmax(lg, -1).astype(jnp.int32))
        gen = jnp.concatenate(out, axis=1)
        done += B
        wave += 1
        print(f"wave {wave}: served {B} requests "
              f"(prefill {L} tokens, decoded {gen.shape[1]}); "
              f"sample continuation: {np.asarray(gen[0])[:6].tolist()}")
    print(f"served {done} requests in {wave} memory-budgeted waves")


if __name__ == "__main__":
    main()
