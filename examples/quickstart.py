"""Quickstart: train a ~100M-param LM end-to-end on synthetic data.

Exercises the full training substrate on CPU: model build, AdamW,
deterministic data pipeline, checkpointing (async), resume.

    PYTHONPATH=src python examples/quickstart.py --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step, \
    restore
from repro.configs import TrainConfig
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model
from repro.train import optim
from repro.train.step import build_train_step


def quickstart_config() -> ModelConfig:
    """~100M params: 12L, d=512, 8H (kv=4), ff=2048, 32k vocab."""
    return ModelConfig(
        name="quickstart-100m", family="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32_000, use_qk_norm=True,
        param_dtype="float32", compute_dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = quickstart_config()
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=20,
                     total_steps=args.steps, checkpoint_every=50,
                     checkpoint_dir=args.ckpt_dir)
    shape = ShapeConfig("quickstart", "train", args.seq, args.batch)
    dc = DataConfig(kind="lm_synthetic")

    params = model.init(cfg, jax.random.key(0))
    opt = optim.init_opt_state(params, tc)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tree, start = restore(args.ckpt_dir,
                              {"params": params, "m": opt.m, "v": opt.v,
                               "count": opt.count})
        params = tree["params"]
        opt = optim.OptState(m=tree["m"], v=tree["v"], count=tree["count"])
        print(f"resumed from step {start}")

    step_fn = jax.jit(build_train_step(cfg, tc), donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=tc.keep_checkpoints)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, dc, i).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            tps = (i - start + 1) * args.batch * args.seq \
                / max(time.time() - t0, 1e-9)
            print(f"step {i:4d}  loss={float(metrics['total_loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  tok/s={tps:,.0f}")
        if (i + 1) % tc.checkpoint_every == 0:
            ckpt.submit(i + 1, {"params": params, "m": opt.m, "v": opt.v,
                                "count": opt.count})
    ckpt.close()
    print(f"done in {time.time()-t0:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
