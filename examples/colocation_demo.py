"""The paper, end to end: learn the mixture-of-experts memory predictor
offline, then schedule a mixed batch of Spark-sim applications with every
co-location policy and compare STP / ANTT.

    PYTHONPATH=src python examples/colocation_demo.py --jobs 13 --mixes 5
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import (ANNPredictor, MoEPredictor, make_policies,
                        spark_sim_suite, training_apps)
from repro.core.metrics import run_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=13)
    ap.add_argument("--mixes", type=int, default=5)
    ap.add_argument("--hosts", type=int, default=40)
    args = ap.parse_args()

    apps = spark_sim_suite()
    train = training_apps(apps)
    print(f"suite: {len(apps)} applications "
          f"({len(train)} HiBench/BigDataBench training apps)")

    moe = MoEPredictor().fit(train)
    print("\nexpert selection (KNN over PCA'd runtime features):")
    fams = {}
    for app in apps:
        fam, dist, conf = moe.select_family(app.features)
        fams.setdefault(fam, []).append(app.name)
        assert conf
    for fam, names in fams.items():
        print(f"  {fam:16s}: {len(names)} apps (e.g. {names[:3]})")

    rng = np.random.default_rng(0)
    errs = []
    for app in apps:
        fn, _ = moe.predict_function(app, 1000.0, rng)
        t = app.true_fn(1000.0)
        errs.append(abs(float(fn(1000.0)) - t) / t)
    print(f"\nmemory prediction error: mean {np.mean(errs)*100:.1f}%  "
          f"max {np.max(errs)*100:.1f}%   (paper: ~5% mean)")

    ann = ANNPredictor().fit(train)
    pols = make_policies(moe, ann)
    from repro.core.simulator import SimConfig
    cfg = SimConfig(n_hosts=args.hosts)
    print(f"\nscheduling {args.jobs} jobs on {args.hosts} hosts "
          f"({args.mixes} random mixes):")
    print(f"{'policy':10s} {'STP':>7s} {'ANTT-red':>9s} {'OOM':>5s}")
    rows = {}
    for name, pol in pols.items():
        r = run_scenario(apps, lambda m, p=pol: p, n_jobs=args.jobs,
                         n_mixes=args.mixes, cfg=cfg, seed=0)
        rows[name] = r
        print(f"{name:10s} {r.stp_gmean:7.2f} "
              f"{r.antt_reduction_mean*100:8.1f}% {r.oom_total:5d}")
    frac = rows["ours"].stp_gmean / rows["oracle"].stp_gmean
    print(f"\nours = {frac*100:.1f}% of Oracle STP (paper: 83.9%)")


if __name__ == "__main__":
    main()
