"""Ad-hoc shakeout: every smoke arch through train fwd, prefill, decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_shape, concrete_inputs
from repro.models import model

failures = []
for arch in ARCH_IDS:
    cfg = get_config(arch, smoke=True)
    try:
        params = model.init(cfg, jax.random.key(0))
        # --- train forward
        batch = concrete_inputs(cfg, smoke_shape("train"))
        h, aux = model.forward_train(params, cfg, batch)
        logits = model.lm_logits(params, cfg, h)
        assert not bool(jnp.isnan(logits).any()), "NaN logits (train)"
        # --- prefill + decode
        pbatch = concrete_inputs(cfg, smoke_shape("prefill"))
        pbatch.pop("labels", None), pbatch.pop("loss_mask", None)
        last, cache = model.prefill(params, cfg, pbatch, max_len=48)
        assert not bool(jnp.isnan(last).any()), "NaN logits (prefill)"
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        lg, cache = model.decode_step(params, cfg, cache, tok)
        lg2, cache = model.decode_step(
            params, cfg, cache, jnp.argmax(lg, -1).astype(jnp.int32))
        assert not bool(jnp.isnan(lg2).any()), "NaN logits (decode)"
        print(f"OK   {arch}: train {h.shape}, prefill {last.shape}, "
              f"decode {lg2.shape}, len={int(cache['len'])}")
    except Exception as e:  # noqa: BLE001
        import traceback
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc()
        failures.append(arch)

sys.exit(1 if failures else 0)
