#!/usr/bin/env python
"""Summarize a Chrome/Perfetto trace emitted by --trace.

    PYTHONPATH=src python scripts/trace_report.py out.json
    python scripts/trace_report.py out.json --json   # machine-readable

Prints the queueing / prefill / decode / transfer time breakdown,
per-node and per-link occupancy, event rates, goodput and migration
count — plus per-tenant goodput when the run carried tenants (the
engine tags request lifecycle spans with their tenant) — all
reconstructed from the trace alone (see ``repro.obs.report``).  Open
the same file at https://ui.perfetto.dev for the interactive timeline.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.report import format_report, load, summarize  # noqa: E402
from repro.obs.trace import validate_chrome_trace  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace_event JSON file")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args()
    payload = load(args.trace)
    validate_chrome_trace(payload)
    rep = summarize(payload)
    if args.json:
        json.dump(rep, sys.stdout, indent=1)
        print()
    else:
        print(format_report(rep, title=args.trace))


if __name__ == "__main__":
    main()
