#!/usr/bin/env bash
# Fast tier-1 gate with a hard wall-clock timeout, so the red/slow-suite
# regression (hypothesis import killing collection; >2 min runs) cannot
# silently come back.
#
#   scripts/ci.sh            # fast selection, <= $CI_TIMEOUT_S (default 120)
#   CI_FULL=1 scripts/ci.sh  # full suite incl. @slow tier-2 (longer cap)
set -euo pipefail
cd "$(dirname "$0")/.."

CI_TIMEOUT_S="${CI_TIMEOUT_S:-120}"
PYTHON="${PYTHON:-python}"

# Deps: the image bakes in the jax/pallas toolchain; install only what's
# missing. A dep that is neither installed nor installable fails the
# gate loudly — tests can't run without it.
for pkg in pytest numpy jax; do
    if ! "$PYTHON" -c "import $pkg" >/dev/null 2>&1; then
        echo "ci: installing missing dep: $pkg"
        "$PYTHON" -m pip install -q "$pkg" || {
            echo "ci: FAILED to import or install $pkg" >&2; exit 1; }
    fi
done

MARK_ARGS=()
if [ "${CI_FULL:-0}" = "1" ]; then
    MARK_ARGS=(-m "")               # include @slow tier-2 tests
    CI_TIMEOUT_S="${CI_FULL_TIMEOUT_S:-600}"
fi

echo "ci: running tier-1 (timeout ${CI_TIMEOUT_S}s)"
rc=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    timeout --signal=TERM --kill-after=15 "$CI_TIMEOUT_S" \
    "$PYTHON" -m pytest -x -q "${MARK_ARGS[@]+"${MARK_ARGS[@]}"}" || rc=$?
if [ $rc -eq 124 ]; then
    echo "ci: FAILED — tier-1 exceeded the ${CI_TIMEOUT_S}s budget" >&2
fi
exit $rc
