#!/usr/bin/env bash
# Fast tier-1 gate with a hard wall-clock timeout, so the red/slow-suite
# regression (hypothesis import killing collection; >2 min runs) cannot
# silently come back.  After the fast pytest selection, a tiny --smoke
# benchmark pass exercises the bench plumbing end-to-end (including the
# multi-axis vector-admission scenario and the net-binding-axis
# scenario), once per demand estimator in $CI_SMOKE_ESTIMATORS
# (default: the default wrap + the conservative registry entry); then a
# replica-routing pass runs the continuous-vs-wave serving sweep
# (asserts continuous >= wave goodput AND routed > single-node goodput
# with 2 replicas net-aware) plus open_arrivals through the
# ClusterRuntime shim; finally a noisy-neighbor tenancy pass re-runs
# the serving bench under --router drf (asserts compliant tenants keep
# >= 0.9 SLO attainment within 10% of their isolated SLO-good tokens
# while aggregate goodput stays within 5% of the untenanted baseline)
# — all inside the SAME wall-clock cap.
#
#   scripts/ci.sh            # fast selection + smoke, <= $CI_TIMEOUT_S (120)
#   CI_FULL=1 scripts/ci.sh  # full suite incl. @slow tier-2 (longer cap)
#   CI_WALL_CAP=300 scripts/ci.sh  # raise the wall cap (slow container)
#   CI_SMOKE_BENCHES="..."   # override the smoke bench subset ("" skips)
#   CI_SMOKE_ESTIMATORS="..."  # override the --estimator sweep
set -euo pipefail
cd "$(dirname "$0")/.."

# CI_WALL_CAP is the coarse knob (whole-gate wall budget, default 120s
# kept); CI_TIMEOUT_S still wins when set explicitly
CI_TIMEOUT_S="${CI_TIMEOUT_S:-${CI_WALL_CAP:-120}}"
PYTHON="${PYTHON:-python}"
# serving_bench ignores --estimator (it builds ServingDemand directly),
# so it runs ONCE, in the replica-routing pass below, not per estimator
CI_SMOKE_BENCHES="${CI_SMOKE_BENCHES-open_arrivals tpu_colocation}"
START_S=$SECONDS

# Deps: the image bakes in the jax/pallas toolchain; install only what's
# missing. A dep that is neither installed nor installable fails the
# gate loudly — tests can't run without it.
for pkg in pytest numpy jax; do
    if ! "$PYTHON" -c "import $pkg" >/dev/null 2>&1; then
        echo "ci: installing missing dep: $pkg"
        "$PYTHON" -m pip install -q "$pkg" || {
            echo "ci: FAILED to import or install $pkg" >&2; exit 1; }
    fi
done

MARK_ARGS=()
if [ "${CI_FULL:-0}" = "1" ]; then
    MARK_ARGS=(-m "")               # include @slow tier-2 tests
    CI_TIMEOUT_S="${CI_FULL_TIMEOUT_S:-600}"
fi

echo "ci: running tier-1 (timeout ${CI_TIMEOUT_S}s)"
rc=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    timeout --signal=TERM --kill-after=15 "$CI_TIMEOUT_S" \
    "$PYTHON" -m pytest -x -q "${MARK_ARGS[@]+"${MARK_ARGS[@]}"}" || rc=$?
if [ $rc -eq 124 ]; then
    echo "ci: FAILED — tier-1 exceeded the ${CI_TIMEOUT_S}s budget" >&2
fi
[ $rc -ne 0 ] && exit $rc

# Smoke benchmarks ride the remaining budget of the same cap, swept
# across demand estimators (the moe pass IS the default wrap; the
# conservative pass drives OURS through the registry's no-selector
# fallback estimator end-to-end).
CI_SMOKE_ESTIMATORS="${CI_SMOKE_ESTIMATORS-moe conservative}"
if [ -n "$CI_SMOKE_BENCHES" ]; then
    for EST in $CI_SMOKE_ESTIMATORS; do
        REMAIN_S=$(( CI_TIMEOUT_S - (SECONDS - START_S) ))
        if [ "$REMAIN_S" -lt 10 ]; then
            echo "ci: FAILED — no budget left for smoke benchmarks" \
                 "(${REMAIN_S}s of ${CI_TIMEOUT_S}s)" >&2
            exit 1
        fi
        echo "ci: running smoke benchmarks (--estimator $EST," \
             "${REMAIN_S}s left): $CI_SMOKE_BENCHES"
        # shellcheck disable=SC2086
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
            timeout --signal=TERM --kill-after=15 "$REMAIN_S" \
            "$PYTHON" -m benchmarks.run --smoke --estimator "$EST" \
            --bench $CI_SMOKE_BENCHES || rc=$?
        if [ $rc -eq 124 ]; then
            echo "ci: FAILED — smoke benchmarks exceeded the remaining" \
                 "${REMAIN_S}s budget" >&2
        fi
        [ $rc -ne 0 ] && exit $rc
    done
fi

# Multi-replica routing smoke (repro.sched.cluster): the serving bench's
# net-contended cell with 2 replicas routed net-aware (asserts routed >
# single-node goodput) AND its network-topology cell (asserts topo-aware
# + KV migration strictly beats net-aware + local requeue on SLO goodput
# over the asymmetric two-rack fabric, emits BENCH_topology.json), plus
# an open_arrivals pass — which since the ClusterRuntime redesign runs
# the simulator through the event-driven runtime shim end-to-end.  The
# pass runs with --trace: the bench re-runs the two-rack cell traced,
# asserts the traced metrics are bit-identical to the untraced run,
# schema-validates the trace_event JSON, and reproduces the cell's
# goodput + migration count from the trace alone.  Same hard wall cap.
if [ -n "$CI_SMOKE_BENCHES" ]; then
    REMAIN_S=$(( CI_TIMEOUT_S - (SECONDS - START_S) ))
    if [ "$REMAIN_S" -lt 10 ]; then
        echo "ci: FAILED — no budget left for the replica-routing smoke" \
             "(${REMAIN_S}s of ${CI_TIMEOUT_S}s)" >&2
        exit 1
    fi
    mkdir -p results
    echo "ci: running replica-routing smoke (--replicas 2 --router" \
         "net-aware --trace results/ci_trace.json, ${REMAIN_S}s left)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        timeout --signal=TERM --kill-after=15 "$REMAIN_S" \
        "$PYTHON" -m benchmarks.run --smoke --replicas 2 \
        --router net-aware --trace results/ci_trace.json \
        --bench serving_bench open_arrivals || rc=$?
    if [ $rc -eq 124 ]; then
        echo "ci: FAILED — replica-routing smoke exceeded the remaining" \
             "${REMAIN_S}s budget" >&2
    fi
    [ $rc -ne 0 ] && exit $rc
    # the trace must summarize standalone too (validates schema again)
    "$PYTHON" scripts/trace_report.py results/ci_trace.json > /dev/null \
        || { echo "ci: FAILED — trace_report.py rejected the CI trace" >&2
             exit 1; }
fi

# Multi-tenant fairness smoke (repro.sched.tenancy): the serving bench's
# noisy-neighbor cell with the drf router — one tenant floods at 4x its
# fair rate and the bench asserts every compliant (high-credit) tenant
# keeps >= 0.9 SLO attainment with SLO-good tokens within 10% of its
# isolated run, while aggregate goodput stays within 5% of the
# untenanted least-loaded baseline (emits BENCH_tenancy.json).  Running
# the whole bench under --router drf also proves the drf router
# UNTENANTED degrades to least-loaded (the route_ratio > 1 assertion in
# the net-contended cell).  Same hard wall cap.
if [ -n "$CI_SMOKE_BENCHES" ]; then
    REMAIN_S=$(( CI_TIMEOUT_S - (SECONDS - START_S) ))
    if [ "$REMAIN_S" -lt 10 ]; then
        echo "ci: FAILED — no budget left for the tenancy smoke" \
             "(${REMAIN_S}s of ${CI_TIMEOUT_S}s)" >&2
        exit 1
    fi
    echo "ci: running noisy-neighbor tenancy smoke (--replicas 2" \
         "--router drf, ${REMAIN_S}s left)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        timeout --signal=TERM --kill-after=15 "$REMAIN_S" \
        "$PYTHON" -m benchmarks.run --smoke --replicas 2 \
        --router drf --bench serving_bench || rc=$?
    if [ $rc -eq 124 ]; then
        echo "ci: FAILED — the tenancy smoke exceeded the remaining" \
             "${REMAIN_S}s budget" >&2
    fi
    [ $rc -ne 0 ] && exit $rc
fi

# Elastic-runtime smoke (repro.sched.elastic): rigid vs elastic OURS
# under the same diurnal+failure stream on the simulator (strict: the
# spill-aware shrink admission beats binary admission on STP) and the
# same burst+failure request stream on the serving engine (strict:
# shallow shrunken joins + autoscale beat the rigid fleet on SLO
# goodput; the autoscaler must actually fire).  Emits
# BENCH_elastic.json.  Same hard wall cap.
if [ -n "$CI_SMOKE_BENCHES" ]; then
    REMAIN_S=$(( CI_TIMEOUT_S - (SECONDS - START_S) ))
    if [ "$REMAIN_S" -lt 10 ]; then
        echo "ci: FAILED — no budget left for the elastic smoke" \
             "(${REMAIN_S}s of ${CI_TIMEOUT_S}s)" >&2
        exit 1
    fi
    echo "ci: running elastic-runtime smoke (rigid vs elastic," \
         "${REMAIN_S}s left)"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        timeout --signal=TERM --kill-after=15 "$REMAIN_S" \
        "$PYTHON" -m benchmarks.run --smoke --bench elastic_bench \
        || rc=$?
    if [ $rc -eq 124 ]; then
        echo "ci: FAILED — the elastic smoke exceeded the remaining" \
             "${REMAIN_S}s budget" >&2
    fi
    [ $rc -ne 0 ] && exit $rc
fi
echo "ci: wall $((SECONDS - START_S))s of ${CI_TIMEOUT_S}s cap"
exit $rc
