"""Verify: prefill(t[:k]) + decode(t[k:]) logits == full forward logits."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model

failures = []
for arch in ["qwen3-14b", "gemma2-27b", "qwen3-moe-30b-a3b",
             "mamba2-780m", "zamba2-2.7b", "whisper-large-v3"]:
    cfg = get_config(arch, smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    params = model.init(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S, K = 2, 16, 10  # prefill first K, decode the rest
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        enc = jnp.asarray(rng.normal(0, 0.1, (B, 8, cfg.d_model)), jnp.float32)
        batch["enc_embeds"] = enc

    # full forward logits at each position
    h, _ = model.forward_train(params, cfg, batch)
    full_logits = model.lm_logits(params, cfg, h)  # [B, S, V]

    pb = dict(batch)
    pb["tokens"] = tokens[:, :K]
    last, cache = model.prefill(params, cfg, pb, max_len=S + 4)
    errs = [float(jnp.max(jnp.abs(last[:, 0] - full_logits[:, K - 1])))]
    for i in range(K, S):
        lg, cache = model.decode_step(params, cfg, cache, tokens[:, i:i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, i]))))
    worst = max(errs)
    ok = worst < 2e-3
    print(f"{'OK  ' if ok else 'FAIL'} {arch}: max |logit diff| = {worst:.2e}")
    if not ok:
        failures.append(arch)

sys.exit(1 if failures else 0)
