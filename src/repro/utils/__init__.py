from repro.utils.tree import (  # noqa: F401
    tree_size,
    tree_bytes,
    tree_map_with_path,
    flatten_with_paths,
)
