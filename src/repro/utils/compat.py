"""Version-tolerant wrappers over moving JAX APIs."""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only
    has ``jax.experimental.shard_map.shard_map(..., check_rep=...)``
    (same flag, earlier name)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
