"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn, tree):
    """Map ``fn(path_str, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: fn(_path_str(path), x), tree
    )


def flatten_with_paths(tree):
    """Return [(path_str, leaf), ...] for a pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), leaf) for path, leaf in flat]
