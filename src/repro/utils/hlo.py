"""HLO-text analysis: collective-bytes accounting for the roofline.

``cost_analysis()`` has no collective term, so we parse the
post-partitioning HLO: build a name->bytes map from instruction
definitions, then sum *operand* sizes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), per op kind.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# shape like  bf16[8,128,2048]{2,1,0:T(8,128)}  or  f32[] or pred[4]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction definition:  %name = <shape-or-tuple> opcode(...)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape literal in ``text`` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {"count": n, "bytes": operand bytes (per device)}.

    Returns {kind: {count, bytes}, "total": {count, bytes}}.
    """
    sizes: Dict[str, int] = {}
    pending = []  # (kind, [operand names])

    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # the shape(s) of this instruction = everything before the opcode;
        # cheapest robust approach: bytes of the first shape-literal run.
        # Definition lines always start with the result shape.
        opcode_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", rest)
        head = rest[: opcode_m.start()] if opcode_m else rest
        sizes[name] = _shape_bytes(head)
        if opcode_m:
            op = opcode_m.group(1)
            base = None
            for c in COLLECTIVE_OPS:
                if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                    base = c
                    break
            if base is not None and not op.endswith("-done"):
                args = rest[opcode_m.end() - 1:]
                operands = re.findall(r"%([\w.\-]+)", args)
                pending.append((base, operands))

    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0})
    for kind, operands in pending:
        b = sum(sizes.get(o, 0) for o in operands)
        out[kind]["count"] += 1
        out[kind]["bytes"] += float(b)
    total = {"count": sum(v["count"] for v in out.values()),
             "bytes": sum(v["bytes"] for v in out.values())}
    result = dict(out)
    result["total"] = total
    return result


def count_ops(hlo_text: str, opcodes=("dot", "fusion", "while", "scatter",
                                      "gather", "transpose", "reshape")
              ) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", m.group(2))
        if opcode_m and opcode_m.group(1) in opcodes:
            counts[opcode_m.group(1)] += 1
    return dict(counts)
