"""Loop-aware static analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
silently undercounts every scan-over-layers model by ~L x. This analyzer
walks the computation graph from ENTRY, multiplying costs by loop trip
counts (XLA annotates scans with ``known_trip_count`` in backend_config;
we fall back to s32 constants in the init tuple, then to 1):

  * flops            — 2 * prod(result dims) * prod(contracting dims)
                       per ``dot`` (matmul-dominated models; elementwise
                       flops are negligible and excluded, matching how
                       roofline compute terms are conventionally quoted)
  * hbm bytes        — per instruction: result + operand bytes
                       (fusions count their boundary only, like XLA)
  * collective bytes — operand bytes per collective op kind

All numbers are per-device (the HLO is the per-partition SPMD module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count\\?":?\s*\{\\?"?n\\?"?:\\?"?(\d+)')

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "call",
    "conditional", "iota", "add-dependency", "opt-barrier", "domain",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",")])) if dims else 1
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    operands: List[str]
    attrs: str
    raw_operands: str = ""
    is_root: bool = False


@dataclass
class Comp:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def _split_def(rest: str):
    """rest = everything after '%name = '. Returns (shape_str, opcode,
    operand_names, attrs)."""
    rest = _COMMENT_RE.sub("", rest)
    # result shape: tuple -> balanced parens; else dtype[dims]{layout}
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape_str = rest[: i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return rest, "", [], "", ""
    else:
        sp = rest.find(" ")
        if sp < 0:
            return rest, "", [], "", ""
        shape_str = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    m = re.match(r"([\w\-]+)\(", tail)
    if not m:
        return shape_str, "", [], "", ""
    opcode = m.group(1)
    # operand group: balanced parens starting at m.end()-1
    start = m.end() - 1
    depth = 0
    for i in range(start, len(tail)):
        if tail[i] == "(":
            depth += 1
        elif tail[i] == ")":
            depth -= 1
            if depth == 0:
                operand_str = tail[start + 1: i]
                attrs = tail[i + 1:]
                break
    else:
        operand_str, attrs = "", ""
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return shape_str, opcode, operands, attrs, operand_str


def parse_module(hlo_text: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Comp(m.group(2))
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape_str, opcode, operands, attrs, raw_ops = _split_def(rest)
        inst = Instr(name, shape_str, opcode, operands, attrs, raw_ops,
                     is_root="ROOT" in line.split("=")[0])
        cur.instrs.append(inst)
        cur.shapes[name] = shape_str
    if cur is not None:
        comps[cur.name] = cur
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(inst: Instr, comp: Comp) -> float:
    res_dims = _first_shape_dims(inst.shape_str) or []
    lhs_shape = comp.shapes.get(inst.operands[0], "") if inst.operands else ""
    lhs_dims = _first_shape_dims(lhs_shape) or []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * float(np.prod(res_dims) if res_dims else 1) * contract


def _trip_count(inst: Instr, comp: Comp) -> int:
    m = _TRIP_RE.search(inst.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest s32[] constant feeding the init tuple
    best = 1
    if inst.operands:
        init = inst.operands[0]
        tup = next((i for i in comp.instrs if i.name == init), None)
        if tup is not None and tup.opcode == "tuple":
            for op in tup.operands:
                d = next((i for i in comp.instrs if i.name == op), None)
                if d is not None and d.opcode == "constant" \
                        and d.shape_str.startswith("s32[]"):
                    mm = re.search(r"constant\((\d+)\)",
                                   d.attrs or "")
                    if mm:
                        best = max(best, int(mm.group(1)))
    return best


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    loops: List[Dict] = field(default_factory=list)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "collective_total_bytes": self.total_collective_bytes,
            "loops": self.loops,
        }


def analyze(hlo_text: str) -> HloCost:
    comps = parse_module(hlo_text)
    cost = HloCost()
    entry = comps.get("__entry__")
    if entry is None:
        return cost
    seen_stack = set()

    def op_bytes(inst: Instr, comp: Comp) -> float:
        """Effective HBM traffic of one instruction.

        Slicing/indexed ops only touch the slice, not the whole operand:
          dynamic-slice / slice    -> 2 x result               (read+write)
          dynamic-update-slice     -> 2 x update operand        (in-place)
          gather                   -> 2 x result + indices
          scatter                  -> 2 x updates + indices
        Everything else: result + all operands (XLA convention)."""
        res = float(_shape_bytes(inst.shape_str))
        ob = [float(_shape_bytes(comp.shapes.get(o, "")))
              for o in inst.operands]
        op = inst.opcode
        if op in ("dynamic-slice", "slice"):
            return 2.0 * res
        if op == "dynamic-update-slice":
            upd = ob[1] if len(ob) > 1 else 0.0
            return 2.0 * upd
        if op == "gather":
            idx = ob[1] if len(ob) > 1 else 0.0
            return 2.0 * res + idx
        if op == "scatter":
            upd = ob[2] if len(ob) > 2 else res
            idx = ob[1] if len(ob) > 1 else 0.0
            return 2.0 * upd + idx
        return res + sum(ob)

    def fusion_bytes(inst: Instr, comp: Comp, body: Optional[Comp]) -> float:
        """Fusion boundary traffic, with slice- and alias-aware parameter
        accounting:
          * a parameter consumed ONLY by (dynamic-)slice/gather ops
            contributes the sliced sizes, not the full array (XLA fuses
            KV-cache slicing into loop-body fusions);
          * a ROOT dynamic-update-slice / scatter writes in place: count
            the update bytes, not the full result, and the scattered-into
            parameter costs nothing (aliased).
        Without these, a scan-over-layers cache/buffer update is charged
        ~L x its true traffic."""
        res = float(_shape_bytes(inst.shape_str))
        if body is None:
            return res + sum(float(_shape_bytes(comp.shapes.get(o, "")))
                             for o in inst.operands)
        pidx: Dict[str, int] = {}
        for bi in body.instrs:
            if bi.opcode == "parameter":
                mm = re.search(r"^\s*(\d+)", bi.raw_operands or "")
                if mm:
                    pidx[bi.name] = int(mm.group(1))
        sliced_only: Dict[str, float] = {}
        full_needed = set()
        aliased = set()
        for bi in body.instrs:
            for o in bi.operands:
                if o in pidx:
                    if bi.opcode in ("dynamic-slice", "slice", "gather") \
                            and bi.operands and bi.operands[0] == o:
                        sliced_only[o] = sliced_only.get(o, 0.0) + float(
                            _shape_bytes(bi.shape_str))
                    else:
                        full_needed.add(o)

        root = next((bi for bi in body.instrs if bi.is_root),
                    body.instrs[-1] if body.instrs else None)

        def _inplace_root(r):
            """Follow converts/bitcasts up from the root to a DUS/scatter."""
            seen = 0
            while r is not None and seen < 4:
                if r.opcode in ("dynamic-update-slice", "scatter"):
                    return r
                if r.opcode in ("convert", "bitcast", "copy") and r.operands:
                    r = next((bi for bi in body.instrs
                              if bi.name == r.operands[0]), None)
                    seen += 1
                    continue
                return None
            return None

        ir = _inplace_root(root)
        if ir is not None:
            upd_idx = 1 if ir.opcode == "dynamic-update-slice" else 2
            if len(ir.operands) > upd_idx:
                upd = float(_shape_bytes(
                    body.shapes.get(ir.operands[upd_idx], "")))
                res = min(res, 2.0 * upd)
            # the updated-into operand is aliased (no read of the rest)
            if ir.operands and ir.operands[0] in pidx:
                aliased.add(ir.operands[0])
        total = res
        for pname, idx in pidx.items():
            if idx >= len(inst.operands):
                continue
            if pname in aliased and pname not in sliced_only:
                continue
            full = float(_shape_bytes(
                comp.shapes.get(inst.operands[idx], "")))
            if pname in full_needed and pname not in aliased:
                total += full
            elif pname in sliced_only:
                total += min(sliced_only[pname], full)
            elif pname not in aliased:
                total += full
        return total

    def visit(comp: Comp, mult: float, flops_only: bool = False):
        if comp.name in seen_stack:
            return  # defensive: no recursion in valid HLO
        seen_stack.add(comp.name)
        for inst in comp.instrs:
            op = inst.opcode
            if not op:
                continue
            if op == "while":
                trip = _trip_count(inst, comp)
                cost.loops.append({"name": inst.name, "trip": trip,
                                   "mult": mult})
                body = re.search(r"body=%([\w.\-]+)", inst.attrs)
                cond = re.search(r"condition=%([\w.\-]+)", inst.attrs)
                if body and body.group(1) in comps:
                    visit(comps[body.group(1)], mult * trip, flops_only)
                if cond and cond.group(1) in comps:
                    visit(comps[cond.group(1)], mult * trip, True)
                continue
            if op == "call":
                t = re.search(r"to_apply=%([\w.\-]+)", inst.attrs)
                if t and t.group(1) in comps:
                    visit(comps[t.group(1)], mult, flops_only)
                continue
            if op == "conditional":
                for cname in re.findall(r"%([\w.\-]+)", inst.attrs):
                    if cname in comps:
                        visit(comps[cname], mult, flops_only)
                continue
            base = None
            for c in COLLECTIVE_KINDS:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base is not None:
                if op.endswith("-done"):
                    continue
                ob = sum(float(_shape_bytes(comp.shapes.get(o, "")))
                         for o in inst.operands)
                cost.collective_bytes[base] = (
                    cost.collective_bytes.get(base, 0.0) + ob * mult)
                cost.collective_counts[base] = (
                    cost.collective_counts.get(base, 0.0) + mult)
                continue
            if op == "dot":
                cost.flops += _dot_flops(inst, comp) * mult
                if not flops_only:
                    b = op_bytes(inst, comp) * mult
                    cost.hbm_bytes += b
                    cost.bytes_by_op["dot"] = (
                        cost.bytes_by_op.get("dot", 0.0) + b)
                continue
            if op == "fusion":
                fc = re.search(r"calls=%([\w.\-]+)", inst.attrs)
                body = comps.get(fc.group(1)) if fc else None
                if body is not None:
                    visit(body, mult, True)  # count dots inside fusions
                if not flops_only:
                    b = fusion_bytes(inst, comp, body) * mult
                    cost.hbm_bytes += b
                    cost.bytes_by_op["fusion"] = (
                        cost.bytes_by_op.get("fusion", 0.0) + b)
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            if not flops_only:
                b = op_bytes(inst, comp) * mult
                cost.hbm_bytes += b
                cost.bytes_by_op[op] = cost.bytes_by_op.get(op, 0.0) + b
        seen_stack.discard(comp.name)

    visit(entry, 1.0)
    return cost
