"""Pallas TPU kernels (validated with interpret=True on CPU).

Each kernel ships three modules:
  kernel.py — pl.pallas_call + BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (layout, padding, backend dispatch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
