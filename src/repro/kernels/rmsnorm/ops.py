"""Jitted wrapper: arbitrary leading dims, padding, dispatch."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "blk", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            blk: int = 256, interpret: Optional[bool] = None) -> jnp.ndarray:
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    N = x2.shape[0]
    blk = min(blk, N)
    pad = (-N) % blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm_fwd(x2, w, eps=eps, blk=blk, interpret=interpret)
    if pad:
        out = out[:N]
    return out.reshape(shape)
