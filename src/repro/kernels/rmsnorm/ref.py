"""Oracle: the model's rms_norm (pure jnp)."""
from repro.models.layers import rms_norm


def rmsnorm_ref(x, w, eps=1e-6):
    return rms_norm(x, w, eps=eps, use_pallas=False)
