"""Pallas TPU fused RMSNorm: one pass over rows, fp32 accumulation.

Grid: (row_blocks,). Block (blk, d) in VMEM; weight broadcast block (d,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x * inv * w_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm_fwd(x2d: jnp.ndarray, w: jnp.ndarray, *, eps: float,
                blk: int = 256, interpret: bool = True) -> jnp.ndarray:
    N, d = x2d.shape
    blk = min(blk, N)
    assert N % blk == 0, (N, blk)
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x2d.dtype),
        interpret=interpret,
    )(x2d, w)
