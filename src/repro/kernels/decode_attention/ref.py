"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(q, k_cache, v_cache, lens, *, scale, window=0,
                         softcap=0.0):
    """q: [B, Hq, 1, D]; caches [B, S, Hkv, D]; lens [B]. -> [B, Hq, 1, D]."""
    B, Hq, _, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    kf = jnp.moveaxis(k_cache, 2, 1).astype(jnp.float32)  # [B,Hkv,S,D]
    vf = jnp.moveaxis(v_cache, 2, 1).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(S)[None, :]
    mask = k_pos < lens[:, None]
    if window > 0:
        mask = mask & (k_pos > (lens[:, None] - 1 - window))
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return o.reshape(B, Hq, 1, D).astype(q.dtype)
