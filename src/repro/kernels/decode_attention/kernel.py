"""Pallas TPU flash-decode: one query token vs a chunked KV cache.

Grid: (batch, q_heads, kv_chunks) — chunks sequential, (acc, m, l) in VMEM
scratch. The same (max, sum)-LSE combination is what the sequence-parallel
decode path psums across shards, so this kernel is the single-shard body
of distributed decode.

Cache layout: [B, S, Hkv, D] (model layout, no transpose needed for
decode: S is the second axis and blocks tile it directly). Valid-length
masking comes from a per-batch ``lens`` s32 array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, window: int, softcap: float, blk_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]             # valid entries incl. current token
    k_start = ik * blk_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # [1, D]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [blk_k, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [1, blk_k]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, blk_k), 1)
        mask = k_pos < cache_len
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > cache_len - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # skip chunks entirely past the valid length (or below the window)
    needed = k_start < cache_len
    if window > 0:
        needed = jnp.logical_and(
            needed, k_start + blk_k - 1 > cache_len - 1 - window)
    pl.when(needed)(_body)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jnp.ndarray,        # [B, Hq, 1, D]
    k_cache: jnp.ndarray,  # [B, S, Hkv, D]
    v_cache: jnp.ndarray,
    lens: jnp.ndarray,     # [B] int32: valid entries (incl. current token)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    blk_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, _, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert S % blk_k == 0, (S, blk_k)
    group = Hq // Hkv
    grid = (B, Hq, S // blk_k)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap,
        blk_k=blk_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_k, 1, D),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, blk_k, 1, D),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lens)
