"""Jitted wrapper for flash-decode, model layout in/out."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("window", "attn_softcap", "scale", "blk_k",
                     "interpret"))
def decode_attention(
    q: jnp.ndarray,        # [B, 1, Hq, D] (model layout)
    k_cache: jnp.ndarray,  # [B, S, Hkv, D]
    v_cache: jnp.ndarray,
    cache_len,             # scalar or [B]: index of current token
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    blk_k: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, _, Hq, D = q.shape
    S = k_cache.shape[1]
    scale = D ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    blk_k = min(blk_k, S)
    pad = (-S) % blk_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32) + 1, (B,))
    out = decode_attention_fwd(
        jnp.moveaxis(q, 2, 1), k_cache, v_cache, lens, scale=scale,
        window=window, softcap=attn_softcap, blk_k=blk_k,
        interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
