"""Jitted wrapper: model layout [B, S, H, D] <-> kernel layout, padding,
backend dispatch (compiled on TPU, interpret=True elsewhere)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "attn_softcap", "scale",
                     "blk_q", "blk_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, S, Hq, D] (model layout)
    k: jnp.ndarray,  # [B, S, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, S, Hq, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    pad = (-S) % max(blk_q, blk_k)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention_fwd(
        qt, kt, vt, scale=scale, causal=causal, window=window,
        softcap=attn_softcap, blk_q=blk_q, blk_k=blk_k, seq_len=S,
        interpret=interpret)
    if pad:
        out = out[:, :, :S]
    return jnp.moveaxis(out, 1, 2)
