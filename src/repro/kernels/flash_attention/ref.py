"""Pure-jnp oracle for flash attention (fp32 softmax, same semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q, k, v, *, scale, causal=True, window=0, softcap=0.0,
                  seq_len=0):
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D]. Returns [B, Hq, S, D]."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    true_len = seq_len or S
    qg = q.reshape(B, Hkv, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = k_pos < true_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, D).astype(q.dtype)
