"""Pallas TPU flash attention (prefill): blockwise online softmax.

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost/sequential, so
the fp32 (acc, m, l) state lives in VMEM scratch across kv iterations.
BlockSpec tiles: q/o (1,1,blk_q,D), k/v (1,1,blk_k,D) — MXU-aligned when
blk_* are multiples of 128 and D is 64/128.

Supports: causal masking, GQA (kv-head index_map = h * Hkv // Hq), logit
softcap (gemma2), sliding window (gemma2 local layers), padded seq tails.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  blk_q: int, blk_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * blk_q
    k_start = ik * blk_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # [blk_q, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [blk_k, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [blk_q, blk_k]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # [blk_q, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # [blk_q, blk_k]
        alpha = jnp.exp(m_prev - m_new)                # [blk_q, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # block-level skip: fully-masked kv blocks do no work
    conds = []
    if causal:
        conds.append(k_start <= q_start + blk_q - 1)
    if window > 0:  # kv block entirely left of every query's window
        conds.append(k_start + blk_k - 1 > q_start - window)
    if conds:
        needed = conds[0]
        for c in conds[1:]:
            needed = jnp.logical_and(needed, c)
        pl.when(needed)(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, D]
    v: jnp.ndarray,
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    blk_q: int = 128,
    blk_k: int = 128,
    seq_len: int = 0,   # true (unpadded) length; 0 -> padded length
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    true_len = seq_len or S
    grid = (B, Hq, S // blk_q, S // blk_k)
    group = Hq // Hkv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k, seq_len=true_len)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
