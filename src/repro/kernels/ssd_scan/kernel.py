"""Pallas TPU Mamba2 SSD chunked scan.

Grid: (batch, heads, chunks) — chunks sequential; the inter-chunk SSM
state [P, N] lives in VMEM scratch across chunk iterations (reset at
chunk 0). Each iteration does the intra-chunk quadratic term (two MXU
matmuls over [Q, Q]) plus the state update — the same math as
``repro.models.ssm.ssd_chunked`` (the oracle), chunk-at-a-time.

Inputs are pre-arranged per head: xb (dt-weighted x), a (log-decay),
B/C expanded to per-head [B, S, H, N] (group broadcast happens in ops.py
— a gather-free repeat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xb_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xb = xb_ref[0, :, 0].astype(jnp.float32)     # [Q, P]
    a = a_ref[0, :, 0].astype(jnp.float32)       # [Q]
    Bm = b_ref[0, :, 0].astype(jnp.float32)      # [Q, N]
    Cm = c_ref[0, :, 0].astype(jnp.float32)      # [Q, N]

    cum = jnp.cumsum(a)                          # [Q]
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * (i >= j)
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    y_intra = jax.lax.dot_general(cb * L, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cum_i) * C_i . state^T   (state: [P, N])
    prev = state_ref[...]                        # [P, N]
    y_inter = jax.lax.dot_general(Cm, prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + y_inter * jnp.exp(cum)[:, None]
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: S' = S * exp(cum_last) + sum_j exp(cum_last - cum_j)
    #                                             * xb_j (x) B_j
    a_last = cum[chunk - 1]
    decay = jnp.exp(a_last - cum)                # [Q]
    contrib = jax.lax.dot_general(
        xb * decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [P, N]
    state_ref[...] = prev * jnp.exp(a_last) + contrib

    @pl.when(ic == nc - 1)
    def _final():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan_fwd(
    xb: jnp.ndarray,   # [B, S, H, P] dt-weighted inputs
    a: jnp.ndarray,    # [B, S, H] log decay
    Bh: jnp.ndarray,   # [B, S, H, N] (already head-expanded)
    Ch: jnp.ndarray,   # [B, S, H, N]
    *,
    chunk: int,
    interpret: bool = True,
):
    B, S, H, P = xb.shape
    N = Bh.shape[-1]
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), xb.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xb, a, Bh, Ch)
    return y, state
