"""Oracle: the chunked SSD in repro.models.ssm (pure jnp)."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(xb, a, B_mat, C_mat, *, chunk, initial_state=None):
    """xb: [B,S,H,P]; a: [B,S,H]; B/C: [B,S,G,N] (grouped, like the model)."""
    return ssd_chunked(xb, a, B_mat, C_mat, chunk=chunk,
                       initial_state=initial_state, use_pallas=False)
