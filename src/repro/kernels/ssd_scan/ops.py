"""Jitted wrapper: grouped B/C -> per-head, padding, dispatch."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xb: jnp.ndarray,      # [B, S, H, P]
    a: jnp.ndarray,       # [B, S, H]
    B_mat: jnp.ndarray,   # [B, S, G, N]
    C_mat: jnp.ndarray,   # [B, S, G, N]
    *,
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, P = xb.shape
    G = B_mat.shape[2]
    rep = H // G
    Bh = jnp.repeat(B_mat, rep, axis=2)
    Ch = jnp.repeat(C_mat, rep, axis=2)
    pad = (-S) % chunk
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad decay with zeros -> exp(0)=1, but padded xb=0 contributes 0
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_scan_fwd(xb, a.astype(jnp.float32), Bh, Ch, chunk=chunk,
                            interpret=interpret)
    if initial_state is not None:
        # fold an initial state in linearly: y += C . (decay * s0)
        cuma = jnp.cumsum(a.astype(jnp.float32), axis=1)  # [B,Sp,H]
        Chf = Ch.astype(jnp.float32)
        extra = jnp.einsum("bshn,bhpn->bshp", Chf,
                           initial_state.astype(jnp.float32))
        y = y + (extra * jnp.exp(cuma)[..., None]).astype(y.dtype)
        state = state + initial_state * jnp.exp(cuma[:, -1])[..., None, None]
    if pad:
        y = y[:, :S]
    return y, state
