"""Pure-jnp oracle for paged decode attention.

Gathers each sequence's pages into a dense per-request view and runs the
same masked-softmax math as the dense flash-decode oracle — the golden
the Pallas page-table kernel is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """[P, page, Hkv, D] pool + [B, maxp] table -> dense [B, maxp*page,
    Hkv, D] view (junk beyond each sequence's length; callers mask)."""
    B, maxp = page_table.shape
    page, Hkv, D = pool.shape[1:]
    out = pool[page_table]                     # [B, maxp, page, Hkv, D]
    return out.reshape(B, maxp * page, Hkv, D)


def paged_attention_ref(q, k_pool, v_pool, page_table, lens, *, scale,
                        window=0, softcap=0.0):
    """q: [B, Hq, 1, D]; pools [P, page, Hkv, D]; page_table [B, maxp]
    int32; lens [B] int32 (valid tokens incl. the current one).
    -> [B, Hq, 1, D]."""
    B, Hq, _, D = q.shape
    k = gather_pages(k_pool, page_table)       # [B, S, Hkv, D]
    v = gather_pages(v_pool, page_table)
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    kf = jnp.moveaxis(k, 2, 1).astype(jnp.float32)   # [B, Hkv, S, D]
    vf = jnp.moveaxis(v, 2, 1).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(S)[None, :]
    mask = k_pos < lens[:, None]
    if window > 0:
        mask = mask & (k_pos > (lens[:, None] - 1 - window))
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return o.reshape(B, Hq, 1, D).astype(q.dtype)
