"""Pallas TPU paged flash-decode: one query token vs a page-table KV pool.

The KV cache lives in a shared page pool ``[num_pages, page, Hkv, D]``;
each sequence owns a row of ``page_table`` naming its pages in order.
The page table and the per-sequence lengths ride in as **scalar-prefetch
operands** (:class:`pltpu.PrefetchScalarGridSpec`), so each grid step's
``BlockSpec`` index map can look its page id up *before* the body runs —
the gather is a DMA of exactly one page, never a dense copy of the pool.

Grid: (batch, q_heads, pages) — pages sequential per (b, h) with the
same (acc, m, l) online-softmax carry as the dense flash-decode kernel;
pages entirely past a sequence's length (or below its window) are
skipped.  Unused ``page_table`` slots must still hold a *valid* page id
(the allocator parks them on page 0): their DMA runs even when the body
is skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, window: int, softcap: float, page: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]               # valid tokens incl. current one
    k_start = ip * page

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # [1, D]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [1, page]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = k_pos < seq_len
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > seq_len - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    needed = k_start < seq_len
    if window > 0:
        needed = jnp.logical_and(
            needed, k_start + page - 1 > seq_len - 1 - window)
    pl.when(needed)(_body)

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_fwd(
    q: jnp.ndarray,            # [B, Hq, 1, D]
    k_pool: jnp.ndarray,       # [P, page, Hkv, D]
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # [B, maxp] int32 (unused slots -> page 0)
    lens: jnp.ndarray,         # [B] int32: valid tokens incl. current
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, _, D = q.shape
    page, Hkv = k_pool.shape[1], k_pool.shape[2]
    maxp = page_table.shape[1]
    assert page_table.shape[0] == B, (page_table.shape, B)
    group = Hq // Hkv
    grid = (B, Hq, maxp)

    kernel = functools.partial(
        _paged_kernel, scale=scale, window=window, softcap=softcap,
        page=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # page_table, lens
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ip, pt, ln:
                         (b, h, 0, 0)),
            # the paged gather: this block's page id comes from the
            # prefetched table, so the DMA fetches exactly one page
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, pt, ln, g=group:
                         (pt[b, ip], 0, h // g, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, pt, ln, g=group:
                         (pt[b, ip], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ip, pt, ln:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lens.astype(jnp.int32),
      q, k_pool, v_pool)
