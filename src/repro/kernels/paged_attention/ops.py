"""Jitted wrapper for paged flash-decode, model layout in/out."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("window", "attn_softcap", "scale", "interpret"))
def paged_attention(
    q: jnp.ndarray,            # [B, 1, Hq, D] (model layout)
    k_pool: jnp.ndarray,       # [P, page, Hkv, D] shared page pool
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # [B, maxp] int32 (unused slots -> 0)
    lens,                      # [B] int32: valid tokens incl. current
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, _, Hq, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (B,))
    out = paged_attention_fwd(
        jnp.moveaxis(q, 2, 1), k_pool, v_pool, page_table, lens,
        scale=scale, window=window, softcap=attn_softcap,
        interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
