"""Paged decode attention: page-table KV pool + scalar-prefetch gather."""
from repro.kernels.paged_attention.ops import paged_attention  # noqa: F401
from repro.kernels.paged_attention.ref import (  # noqa: F401
    gather_pages,
    paged_attention_ref,
)
