"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "gemma2-27b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36_864, vocab_size=256_000,
        local_global=True, sliding_window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        use_post_norm=True, embed_scale=True, attn_scale_dim=144,
        tie_embeddings=True, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, sliding_window=8, attn_scale_dim=16,
    )
