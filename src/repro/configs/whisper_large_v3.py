"""whisper-large-v3 [audio] — 32L (per stack) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866; enc-dec; conv/mel frontend STUBBED — input_specs()
provides precomputed frame embeddings. [arXiv:2212.04356; unverified]

Note: decode shapes use the assigned 32k self-KV length (exceeds real
whisper's 448-token decoder ctx; exercises the backbone as instructed)
plus a fixed 1500-frame cross-attention KV."""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-large-v3"
CROSS_LEN = 1500


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        head_dim=64, d_ff=5120, vocab_size=51_866,
        is_encdec=True, act="gelu", tie_embeddings=True,
        frontend="audio_stub",
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=256,
    )
