"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) moe_d_ff=2048
vocab=163840, MoE 384 experts top-8. Trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ModelConfig

ARCH_ID = "kimi-k2-1t-a32b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=112, d_ff=0, vocab_size=163_840,
        num_experts=384, experts_per_token=8, moe_d_ff=2048,
        rope_theta=50_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=256, num_experts=8, experts_per_token=2, moe_d_ff=96,
    )
