"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-14b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=17_408, vocab_size=151_936,
        use_qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    )
