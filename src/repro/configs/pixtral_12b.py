"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend STUBBED (input_specs() provides patch
embeddings) + mistral-nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.configs.base import ModelConfig

ARCH_ID = "pixtral-12b"
PATCH_FRACTION = 4  # 1/4 of the sequence is image patches (stub convention)


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14_336, vocab_size=131_072,
        rope_theta=1_000_000.0, frontend="vision_stub",
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    )
