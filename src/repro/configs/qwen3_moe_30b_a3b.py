"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) moe_d_ff=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=0, vocab_size=151_936,
        num_experts=128, experts_per_token=8, moe_d_ff=768,
        use_qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=256, num_experts=8, experts_per_token=2, moe_d_ff=96,
    )
