"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 blocks + one SHARED attention+MLP block
applied every 6 blocks (9 applications, per-application KV cache).
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "zamba2-2.7b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=10_240, vocab_size=32_000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        ssm_chunk=128, conv_width=4, attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, attn_every=2,
    )
