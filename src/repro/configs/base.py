"""Model/run configuration dataclasses.

One ``ModelConfig`` describes any architecture in the assigned pool:
dense / MoE / SSM / hybrid / enc-dec / vlm. Per-arch files under
``repro/configs/`` instantiate the exact published configs plus a reduced
smoke config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention details ---
    use_qk_norm: bool = False
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    sliding_window: int = 0        # gemma2 local layers: 4096
    local_global: bool = False     # gemma2 alternating local/global
    rope_theta: float = 10_000.0
    use_post_norm: bool = False    # gemma2 sandwich norms
    embed_scale: bool = False      # gemma2: multiply embeddings by sqrt(d)
    attn_scale_dim: int = 0        # 0 -> head_dim; gemma2-27b: d/H = 144
    # perf levers (EXPERIMENTS.md §Perf): f32 attention logits are the
    # numerically-safe default; bf16 (with max-subtraction) halves the
    # S^2 softmax traffic and kills materialized bf16->f32 dot converts
    attn_f32_logits: bool = True

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- hybrid (zamba2): shared attention block every N ssm blocks ---
    attn_every: int = 0

    # --- enc-dec (whisper) ---
    is_encdec: bool = False
    # vlm/audio frontends are stubs: input_specs() provides embeddings.
    frontend: str = "none"  # "none" | "audio_stub" | "vision_stub"

    # --- generic ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"  # "silu" | "gelu"
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" | "dots"
    use_pallas: bool = False  # swap in Pallas kernels (TPU target)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports the long_500k decode shape (SSM/hybrid only)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule / runtime knobs for the training driver."""
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    adam_dtype: str = "float32"   # "bfloat16" for the 1T-class archs
    microbatch: Optional[int] = None  # gradient accumulation microbatch
    seed: int = 0
    # fault tolerance
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    keep_checkpoints: int = 3
    # distributed-optimization tricks
    grad_compression: str = "none"  # "none" | "int8_ef" (error feedback)
