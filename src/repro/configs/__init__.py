from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    all_cells,
    applicable_shapes,
    concrete_inputs,
    get_config,
    input_specs,
    smoke_shape,
)
