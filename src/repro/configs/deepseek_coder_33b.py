"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch. [arXiv:2401.14196; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-coder-33b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=19_200, vocab_size=32_256,
        rope_theta=100_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    )
