"""mamba2-780m [ssm] — 48L d_model=1536, attention-free, vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

ARCH_ID = "mamba2-780m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=50_280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        ssm_chunk=128, conv_width=4,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    )
