"""Architecture registry: ``--arch <id>`` resolution, shape applicability,
and ``input_specs()`` (ShapeDtypeStruct stand-ins, no allocation)."""
from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

WHISPER_CROSS_LEN = 1500


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.smoke_config() if smoke else mod.full_config()


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """The assigned shape cells this arch participates in.

    long_500k only for sub-quadratic archs (SSM/hybrid); all archs here have
    a decoder, so decode shapes apply everywhere (see DESIGN.md §4)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


def all_cells() -> List[tuple]:
    """All assigned (arch_id, shape_name) cells (40 total)."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in applicable_shapes(cfg):
            cells.append((a, s))
    return cells


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                max_decode_len: int = 0) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    * train:   batch dict for train_step
    * prefill: batch dict for prefill_step
    * decode:  {"token", "cache"} for decode_step (cache holds seq_len KV)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    cdt = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        if cfg.family == "encdec":
            half = S // 2
            return {
                "enc_embeds": sds((B, half, cfg.d_model), cdt),
                "tokens": sds((B, half), i32),
                "labels": sds((B, half), i32),
                "loss_mask": sds((B, half), f32),
            }
        if cfg.family == "vlm":
            s_img = S // 4
            s_text = S - s_img
            return {
                "patch_embeds": sds((B, s_img, cfg.d_model), cdt),
                "tokens": sds((B, s_text), i32),
                "labels": sds((B, S), i32),
                "loss_mask": sds((B, S), f32),
            }
        return {
            "tokens": sds((B, S), i32),
            "labels": sds((B, S), i32),
            "loss_mask": sds((B, S), f32),
        }

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            half = S // 2
            return {"enc_embeds": sds((B, half, cfg.d_model), cdt),
                    "tokens": sds((B, half), i32)}
        if cfg.family == "vlm":
            s_img = S // 4
            return {"patch_embeds": sds((B, s_img, cfg.d_model), cdt),
                    "tokens": sds((B, S - s_img), i32)}
        return {"tokens": sds((B, S), i32)}

    # decode: one new token against a seq_len-deep cache
    from repro.models import model as model_lib
    cache = model_lib.init_cache(cfg, B, max_decode_len or S,
                                 abstract_only=True,
                                 cross_len=WHISPER_CROSS_LEN)
    return {"token": sds((B, 1), i32), "cache": cache}


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, rng=None):
    """Small-scale *allocated* inputs matching input_specs (smoke tests)."""
    import numpy as np
    rng = np.random.default_rng(0 if rng is None else rng)
    specs = input_specs(cfg, shape)

    def make(path, s):
        if s.dtype == jnp.int32:
            return jnp.asarray(
                rng.integers(0, max(cfg.vocab_size - 1, 2), s.shape),
                jnp.int32)
        if "mask" in str(path):
            return jnp.ones(s.shape, s.dtype)
        return jnp.asarray(rng.normal(0, 0.02, s.shape), s.dtype)

    out = {}
    for k, v in specs.items():
        if k == "cache":
            from repro.models import model as model_lib
            out[k] = model_lib.init_cache(cfg, shape.global_batch,
                                          shape.seq_len,
                                          cross_len=WHISPER_CROSS_LEN)
        else:
            out[k] = make(k, v)
    return out


def smoke_shape(kind: str) -> ShapeConfig:
    """Tiny shape cells for CPU smoke tests."""
    return {
        "train": ShapeConfig("smoke_train", "train", 32, 2),
        "prefill": ShapeConfig("smoke_prefill", "prefill", 32, 2),
        "decode": ShapeConfig("smoke_decode", "decode", 32, 2),
    }[kind]
