"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA, tied embeddings. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-0.6b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=3072, vocab_size=151_936,
        use_qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return full_config().replace(
        name=ARCH_ID + "-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    )
