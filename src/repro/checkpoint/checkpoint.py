"""Checkpointing: atomic, async-capable, elastic-restore.

* Atomic: write to ``<dir>/.tmp.<step>`` then ``os.replace`` — a killed
  writer never corrupts the latest checkpoint (fault tolerance).
* Async: a single background thread drains a queue of (step, host-copy)
  snapshots so the train loop never blocks on disk.
* Elastic: ``restore(..., shardings=...)`` device_puts each leaf with the
  *target* sharding — resuming on a different mesh shape re-shards
  transparently (tested on fake multi-device meshes).

Format: one ``.npz`` per checkpoint with flattened path->array entries,
plus a tiny JSON manifest (step, leaf paths, dtypes).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import flatten_with_paths


def _to_numpy_tree(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            out["bf16::" + path] = arr.view(np.uint16)
        else:
            out[path] = arr
    return out


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Blocking atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _to_numpy_tree(tree)
    tmp = os.path.join(ckpt_dir, f".tmp.{step}.npz")
    final = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)
    manifest = {"step": step, "path": final}
    mtmp = os.path.join(ckpt_dir, ".manifest.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, "manifest.json"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir)
        if f.startswith("ckpt_") and f.endswith(".npz"))
    for f in ckpts[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f))
        except OSError:
            pass


def latest_step(ckpt_dir: str) -> Optional[int]:
    mf = os.path.join(ckpt_dir, "manifest.json")
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``template`` (arrays or SDS).

    ``shardings``: optional pytree (or single sharding) applied at
    device_put time — the elastic-resume path."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    by_path = {}
    for k in data.files:
        if k.startswith("bf16::"):
            by_path[k[len("bf16::"):]] = data[k].view(jnp.bfloat16)
        else:
            by_path[k] = data[k]

    flat_t = flatten_with_paths(template)
    shard_list = None
    if shardings is not None:
        if isinstance(shardings, jax.sharding.Sharding):
            shard_list = [shardings] * len(flat_t)
        else:
            shard_list = [s for _, s in flatten_with_paths(shardings)]

    leaves = []
    for i, (p, tmpl) in enumerate(flat_t):
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = by_path[p]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {p}: ckpt {arr.shape} vs "
                f"template {tmpl.shape}")
        if shard_list is not None:
            leaves.append(jax.device_put(
                arr.astype(tmpl.dtype), shard_list[i]))
        else:
            leaves.append(jnp.asarray(arr.astype(tmpl.dtype)))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer with bounded queue."""

    def __init__(self, ckpt_dir: str, keep: int = 3, max_pending: int = 2):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree):
        """Snapshot to host memory now; write in background."""
        if self._err is not None:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
