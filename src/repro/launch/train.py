"""Production training driver: mesh + sharding rules + data + checkpoint
+ fault tolerance, for any registered arch.

Smoke-scale on this CPU container:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 30 --batch 8 --seq 64

On a real fleet the same driver runs under a multi-host mesh; the
``--mesh`` flag picks the debug/production topologies. The paper's
co-location layer sits above this driver (launch-level jobs are what
``core.simulator`` schedules).
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step, \
    restore
from repro.configs import TrainConfig, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import sharding as shd
from repro.models import model as model_lib
from repro.train import optim
from repro.train.step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="none",
                    help="none | dxm spec like 2x4 (axes data,model)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ep-moe", action="store_true",
                    help="shard_map expert-parallel MoE path")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                     total_steps=args.steps, checkpoint_every=args.ckpt_every,
                     checkpoint_dir=args.ckpt_dir)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    dc = DataConfig()

    params = model_lib.init(cfg, jax.random.key(0))
    opt = optim.init_opt_state(params, tc)
    step_fn = build_train_step(cfg, tc)

    import contextlib
    ctx = contextlib.nullcontext()
    if args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        abst = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        ps = shd.param_specs(cfg, abst, mesh, kind="train")
        zs = shd.zero1_opt_specs(ps, abst, mesh)
        from jax.sharding import PartitionSpec as P
        opt_spec = optim.OptState(m=zs, v=zs, count=P())
        dummy = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, dc, 0).items()}
        bs = shd.batch_specs(dummy, mesh)
        step_fn = jax.jit(
            step_fn,
            in_shardings=(shd.to_named(ps, mesh),
                          shd.to_named(opt_spec, mesh),
                          shd.to_named(bs, mesh)),
            out_shardings=(shd.to_named(ps, mesh),
                           shd.to_named(opt_spec, mesh), None),
            donate_argnums=(0, 1))
        ctx = mesh
        if args.ep_moe and cfg.family == "moe":
            from repro.models.moe_ep import ep_mesh_context
            ctx2 = ep_mesh_context(mesh)
        else:
            ctx2 = contextlib.nullcontext()
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        ctx2 = contextlib.nullcontext()

    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tree, start = restore(
            args.ckpt_dir,
            {"params": params, "m": opt.m, "v": opt.v, "count": opt.count})
        params, opt = tree["params"], optim.OptState(
            m=tree["m"], v=tree["v"], count=tree["count"])
        print(f"resumed from step {start}")

    stop = {"flag": False}
    signal.signal(signal.SIGTERM,
                  lambda *_: stop.__setitem__("flag", True))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=tc.keep_checkpoints)
    t0 = time.time()
    with ctx, ctx2:
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, shape, dc, i).items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(metrics['total_loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e}", flush=True)
            if (i + 1) % tc.checkpoint_every == 0 or stop["flag"]:
                ckpt.submit(i + 1, {"params": params, "m": opt.m,
                                    "v": opt.v, "count": opt.count})
            if stop["flag"]:
                print(f"preemption signal: checkpointed at {i + 1}")
                break
    ckpt.close()
    print(f"trained {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
