"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its first
jax import, and nothing else should.
"""
from __future__ import annotations

import jax

# TPU v5e-class hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess multi-device tests (8 fake devices)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes a global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def num_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
