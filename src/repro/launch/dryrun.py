import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we ``jit(step).lower(...).compile()`` against the production
mesh with ShapeDtypeStruct inputs (no allocation), then record:
  * memory_analysis()  — per-device argument/temp/output/peak bytes
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * collective stats   — parsed from the post-SPMD HLO (operand bytes per
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-
    permute)
  * roofline terms     — compute / memory / collective seconds (v5e consts)

Results append incrementally to a JSON file consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import SHAPES, TrainConfig, get_config, input_specs
from repro.configs.registry import all_cells
from repro.launch import sharding as shd
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh, num_chips)
from repro.models import model as model_lib
from repro.train import optim
from repro.train.step import build_serve_step, build_train_step
from repro.utils.hlo import count_ops
from repro.utils.hlo_analyzer import analyze
from repro.utils.tree import flatten_with_paths


def arch_train_config(arch: str) -> TrainConfig:
    """Per-arch training knobs: the 1T-class arch uses bf16 Adam moments."""
    if arch.startswith("kimi"):
        return TrainConfig(adam_dtype="bfloat16")
    return TrainConfig()


def count_params(cfg, abstract_params) -> Dict[str, float]:
    total = 0
    expert = 0
    for path, leaf in flatten_with_paths(abstract_params):
        n = int(np.prod(leaf.shape))
        total += n
        if any(k in path for k in ("w_gate", "w_up", "w_down")):
            expert += n
    active = total - expert
    if cfg.num_experts:
        active += expert * cfg.experts_per_token / cfg.num_experts
    return {"params_total": float(total), "params_active": float(active)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               opts: Optional[set] = None):
    """Build + lower + compile one cell. Returns (record, compiled).

    ``opts``: named optimizations measured in EXPERIMENTS.md §Perf —
      ep_moe     shard_map expert-parallel MoE dispatch
      (config-level levers go through ``overrides``.)
    """
    import contextlib
    opts = opts or set()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**{k: v for k, v in overrides.items()
                             if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    abstract_params = model_lib.abstract(cfg)
    pcounts = count_params(cfg, abstract_params)

    ctx = contextlib.nullcontext()
    if ("ep_moe" in opts or "ep_moe_tp" in opts) and cfg.family == "moe":
        from repro.models.moe_ep import ep_mesh_context
        ctx = ep_mesh_context(
            mesh, extra_batch_axes=("pod",) if multi_pod else (),
            tp_dispatch="ep_moe_tp" in opts)

    t0 = time.time()
    with mesh, ctx:
        if shape.kind == "train":
            tc = arch_train_config(arch)
            step = build_train_step(cfg, tc)
            abstract_opt = optim.abstract_opt_state(abstract_params, tc)
            sh = shd.train_shardings(cfg, mesh, abstract_params,
                                     abstract_opt, specs, tc)
            fn = jax.jit(
                step,
                in_shardings=(sh["params"], sh["opt"], sh["batch"]),
                out_shardings=(sh["params"], sh["opt"], None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(abstract_params, abstract_opt, specs)
        elif shape.kind == "prefill":
            from repro.train.step import build_prefill_step
            pstep = build_prefill_step(cfg, max_len=shape.seq_len)
            sh_p = shd.param_specs(cfg, abstract_params, mesh, kind="serve")
            abstract_cache = model_lib.init_cache(
                cfg, shape.global_batch, shape.seq_len, abstract_only=True)
            cache_sp = shd.cache_specs(cfg, abstract_cache, mesh)
            bs = shd.batch_specs(specs, mesh)
            fn = jax.jit(
                pstep,
                in_shardings=(shd.to_named(sh_p, mesh),
                              shd.to_named(bs, mesh)),
                out_shardings=(None, shd.to_named(cache_sp, mesh)),
            )
            lowered = fn.lower(abstract_params, specs)
        else:  # decode
            sstep = build_serve_step(cfg)
            sh = shd.serve_shardings(cfg, mesh, abstract_params,
                                     specs["cache"], shape.global_batch)
            fn = jax.jit(
                sstep,
                in_shardings=(sh["params"], sh["token"], sh["cache"]),
                out_shardings=(sh["token"], sh["cache"]),
                donate_argnums=(2,),
            )
            lowered = fn.lower(abstract_params, specs["token"],
                               specs["cache"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware static analysis (XLA's cost_analysis counts while bodies
    # once — useless for scan-over-layers models; see utils/hlo_analyzer)
    hc = analyze(hlo)
    ops = count_ops(hlo)

    chips = num_chips(mesh)
    flops = hc.flops
    hbm_bytes = hc.hbm_bytes
    coll_bytes = hc.total_collective_bytes
    # all analyses are per-device (the HLO is the SPMD per-partition module)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "opts": sorted(opts),
        "overrides": dict(overrides or {}),
        "ok": True,
        **pcounts,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
        },
        "cost": {
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm_bytes,
            "xla_flops_raw": float(ca.get("flops", 0.0)),
            "xla_bytes_raw": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "bytes": hc.collective_bytes,
            "counts": hc.collective_counts,
            "total_bytes": coll_bytes,
        },
        "bytes_by_op": {k: v for k, v in sorted(
            hc.bytes_by_op.items(), key=lambda kv: -kv[1])[:10]},
        "loops": hc.loops,
        "hlo_ops": ops,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": hbm_bytes / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        },
        "timing": {"lower_s": round(t_lower, 2),
                   "compile_s": round(t_compile, 2)},
        "tokens": SHAPES[shape_name].global_batch * (
            SHAPES[shape_name].seq_len if shape.kind == "train" else 1),
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: record["roofline"][k])
    record["roofline"]["dominant"] = dom
    return record, compiled


def run_cell_safe(arch, shape_name, multi_pod, overrides=None, opts=None):
    try:
        rec, _ = lower_cell(arch, shape_name, multi_pod, overrides, opts)
        return rec
    except Exception as e:  # noqa: BLE001
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: Dict[str, Any]):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def cell_key(arch, shape, multi_pod):
    return f"{arch}|{shape}|{'multi' if multi_pod else 'single'}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in --out")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--opts", default="",
                    help="comma-separated optimizations (e.g. ep_moe)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (repeatable)")
    args = ap.parse_args()

    opts = set(o for o in args.opts.split(",") if o)
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = load_results(args.out)
    for arch, shape in cells:
        for mp in meshes:
            key = cell_key(arch, shape, mp)
            if key in results and results[key].get("ok") and not args.force:
                print(f"skip {key} (cached)")
                continue
            print(f"=== {key} ===", flush=True)
            rec = run_cell_safe(arch, shape, mp, overrides or None,
                                opts or None)
            results[key] = rec
            save_results(args.out, results)
            if rec["ok"]:
                r = rec["roofline"]
                print(f"  ok: compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"collective={r['collective_s']:.4f}s "
                      f"dominant={r['dominant']} "
                      f"(compile {rec['timing']['compile_s']}s)", flush=True)
            else:
                print(f"  FAIL: {rec['error']}", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
