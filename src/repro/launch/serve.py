"""Production serving driver: a thin CLI over the continuous-batching
engine (``repro.serve``).

Admission routes through ``repro.sched.AdmissionController`` — the SAME
predict -> two-point-calibrate -> budget-inverse controller the cluster
simulator's policies use — with requests as the work unit and the
serving footprint on the **hbm axis** of a
:class:`~repro.sched.resources.ResourceVector` budget.  The default
``--mode continuous`` re-decides admission **every decode step**: new
prefills join the running batch when the binding-axis inverse says their
KV fits, finished requests retire immediately, and lowest-priority
requests are evicted-and-requeued (with recompute) when decode growth
would breach the budget.  ``--mode wave`` keeps the pre-engine
behaviour — one admission per wave against the worst-case footprint —
for comparison.

The serving footprint comes from the ``repro.sched.estimator`` registry
(``--estimator kv-growth|conservative``): the ``kv-growth`` estimator
owns the per-``(config, max_len)`` two-point affine calibration cache;
``conservative`` pads the KV slope.  Passing ``--host-ram-gb`` adds a
second budgeted axis (pinned host staging memory per request), and
``--net-gbps`` a third (egress bandwidth per in-flight request — the
live ``net`` axis); the metrics report which axis bound each join.
Forced over-budget progress (a single request that does not fit) is
flagged on the decision and logged, never booked silently.

Queue order and preemption priority are pluggable via the
``repro.sched.placement`` registry (``--placement
fcfs|sjf|best-fit|arrival-aware``): ``sjf`` serves short requests first,
shrinking padding and mean TTFT.

``--backend paged`` (default) serves over the page-granular KV backend:
fixed ``--page-size`` token blocks from a shared pool, per-request page
tables, and ``--prefill-chunk``-token prefill slices interleaved with
decode steps — requests join at any step, and admission books
page-quantized KV demand (the estimator carries ``page_size`` through
``ServingDemand``).  ``--backend dense`` keeps the deprecated
slot-compacted cache (shared position, full-prompt prefill stalls) for
comparison.

``--replicas N`` serves over N replica Nodes on the shared
``repro.sched.cluster`` runtime — each replica gets its own backend and
the full per-replica budget (``--replica-hbm 8,8,4`` makes the cell
heterogeneous), and arriving requests are routed by the ``--router``
registry entry (``single`` / ``least-loaded`` / ``net-aware`` /
``topo-aware``; the deprecated net-aware router spreads load over the
replicas' ``net``-axis headroom when ``--net-gbps`` budgets it).

``--tenants gold:2,bronze:1`` runs multi-tenant fairness
(``repro.sched.tenancy``): requests cycle over the named tenants,
admission/eviction run the credit-scored weighted-DRF knapsack, and
``--router drf`` routes each request to the node where its tenant's
weighted dominant share stays lowest; a per-tenant summary table
(credit, goodput, SLO attainment, dominant share, rejects) prints at
exit.

``--topology two-rack`` binds a ``repro.sched.topology`` preset: prompt
payloads ride real ingress :class:`Transmission` events
(``--ingress-gb-per-token``), the ``topo-aware`` router scores
bottleneck-link path headroom, ``--migrate`` lets preempted requests
move their KV to another replica when the modeled transfer beats local
recompute, and observed transmissions feed the estimator's measured net
curve after the run.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.sched import (Autoscaler, ElasticController,
                         FailureSchedule, ModelTarget, ResourceVector,
                         Tenant, TenantRegistry, available_placements,
                         available_routers, available_topologies,
                         get_estimator, get_topology)
from repro.serve import (Engine, JaxBackend, PagedJaxBackend, Request,
                         ServingDemand, pages_for)

#: estimators that make sense for a serving deployment (job-side ones
#: like moe/oracle need an AppProfile target)
SERVE_ESTIMATORS = ("kv-growth", "conservative")


def build_requests(args, rng: np.random.Generator, tenants=None):
    """Heterogeneous prompt/decode lengths make step-level membership
    churn real: short requests retire early (continuous mode backfills
    their slots), long prompts dominate padding (sjf shrinks it).
    With ``--tenants``, requests cycle round-robin over the tenant
    names so every tenant sees the same workload mix."""
    reqs = []
    names = [t.name for t in tenants] if tenants else None
    for i in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1),
                                args.prompt_len + 1))
        new = int(rng.integers(max(args.decode_steps // 2, 1),
                               args.decode_steps + 1))
        arrival = float(i) / args.rate if args.rate > 0 else 0.0
        reqs.append(Request(rid=i, prompt_len=plen, max_new_tokens=new,
                            arrival=arrival,
                            tenant=names[i % len(names)]
                            if names else None))
    return reqs


def parse_tenants(spec: str):
    """``name:weight,name:weight,...`` (weight optional, default 1.0)
    into a Tenant list for the registry."""
    tenants = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, weight = part.partition(":")
        tenants.append(Tenant(name=name.strip(),
                              weight=float(weight) if weight else 1.0))
    return tenants


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="continuous",
                    choices=("continuous", "wave"),
                    help="step-level admission vs legacy per-wave")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--budget-gb", type=float, default=1.0,
                    help="HBM budget for weights + KV")
    ap.add_argument("--host-ram-gb", type=float, default=0.0,
                    help="host staging budget (0 = unconstrained)")
    ap.add_argument("--host-ram-per-req-gb", type=float, default=0.05,
                    help="pinned host memory per in-flight request")
    ap.add_argument("--net-gbps", type=float, default=0.0,
                    help="egress bandwidth budget (0 = unconstrained)")
    ap.add_argument("--net-gbps-per-req", type=float, default=0.1,
                    help="egress bandwidth per in-flight request")
    ap.add_argument("--estimator", default="kv-growth",
                    choices=SERVE_ESTIMATORS,
                    help="demand estimator (repro.sched.estimator "
                         "registry); conservative pads the KV slope")
    ap.add_argument("--placement", default="fcfs",
                    choices=available_placements(),
                    help="queue order + preemption priority "
                         "(sjf = short requests first)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="request arrival rate /s (0 = all at t=0)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--backend", default="paged",
                    choices=("paged", "dense"),
                    help="paged = block-granular KV + chunked prefill "
                         "(joins any step); dense = deprecated "
                         "slot-compacted shim (shared position)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens (paged backend); "
                         "demand books page-quantized KV")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prefill chunk in tokens (paged backend): "
                         "prompts prefill in chunks interleaved with "
                         "decode steps")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas (each gets its own backend "
                         "and the full per-replica budget)")
    ap.add_argument("--router", default="single",
                    choices=available_routers(),
                    help="how arriving requests are routed to replicas "
                         "(repro.sched.cluster registry)")
    ap.add_argument("--topology", default="",
                    choices=("",) + available_topologies(),
                    help="bind a network preset (repro.sched.topology): "
                         "prompts ride real ingress Transmissions and "
                         "the topo-aware router scores path headroom; "
                         "'' = no fabric (bit-identical legacy "
                         "schedules)")
    ap.add_argument("--migrate", action="store_true",
                    help="preempted requests may migrate their KV to "
                         "another replica when the modeled transfer "
                         "beats local recompute (needs --topology; "
                         "real-cache jax backends cannot adopt foreign "
                         "KV, so they always recompute)")
    ap.add_argument("--ingress-gb-per-token", type=float, default=0.0,
                    help="prompt payload GB per token staged from the "
                         "topology ingress (0 = prompts appear "
                         "instantly, pre-topology behaviour)")
    ap.add_argument("--replica-hbm", default="",
                    help="comma-separated per-replica HBM capacities in "
                         "GB, e.g. '8,8,4' — a heterogeneous cell "
                         "(must list exactly --replicas values; "
                         "overrides --budget-gb per node)")
    ap.add_argument("--tenants", default="",
                    help="comma-separated 'name:weight' tenant specs "
                         "(weight optional, default 1.0), e.g. "
                         "'gold:2,bronze:1' — requests cycle over the "
                         "tenants round-robin, the engine runs "
                         "credit-scored weighted-DRF fairness (pair "
                         "with --router drf), and a per-tenant summary "
                         "table prints at exit; '' = untenanted "
                         "(bit-identical legacy schedules)")
    ap.add_argument("--elastic", action="store_true",
                    help="spill-aware shrunken joins: a request that "
                         "does not fit may be admitted at a memory "
                         "fraction its demand-vs-slowdown curve prices "
                         "under --elastic-max-slowdown (the spilled "
                         "remainder is paid as decode-step slowdown); "
                         "off = bit-identical legacy admission")
    ap.add_argument("--elastic-max-slowdown", type=float, default=2.5,
                    help="largest modeled slowdown a shrunken "
                         "admission may pay (the ElasticController "
                         "cap)")
    ap.add_argument("--failures", type=float, default=0.0,
                    help="inject deterministic replica failures with "
                         "this mean-time-between-failures in virtual "
                         "seconds (0 = off); failed replicas drain "
                         "through migrate-vs-recompute and repair "
                         "after --repair-s")
    ap.add_argument("--repair-s", type=float, default=1.0,
                    help="virtual seconds a failed replica stays down")
    ap.add_argument("--failure-horizon-s", type=float, default=30.0,
                    help="failures are drawn on [0, horizon) virtual "
                         "seconds")
    ap.add_argument("--autoscale", type=int, default=0,
                    help="autoscale the fleet up to this many replicas "
                         "from queue-depth and SLO-attainment trends "
                         "(0 = off; spares above --replicas are "
                         "pre-provisioned down and spawned "
                         "topology-aware)")
    ap.add_argument("--autoscale-interval-s", type=float, default=0.25,
                    help="autoscaler observation cadence in virtual "
                         "seconds")
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto trace_event JSON of "
                         "the run to this path (virtual-clock spans: "
                         "steps, prefill/decode, transfers, request "
                         "lifecycles; open at https://ui.perfetto.dev "
                         "or summarize with scripts/trace_report.py)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    max_len = args.prompt_len + args.decode_steps + 1

    page_size = args.page_size if args.backend == "paged" else 1
    estimator = get_estimator(args.estimator)
    estimate = estimator.estimate(ModelTarget(
        cfg, max_len,
        host_ram_per_req_gb=args.host_ram_per_req_gb
        if args.host_ram_gb > 0.0 else 0.0,
        net_gbps_per_req=args.net_gbps_per_req
        if args.net_gbps > 0.0 else 0.0,
        page_size=page_size))
    if estimate.conservative:
        print(f"estimator {args.estimator!r}: conservative estimate "
              f"(KV slope padded x{estimate.info.get('pad')})")
    demand = ServingDemand.from_estimate(estimate, max_len)
    budget_axes = {"hbm": float(args.budget_gb)}
    if args.host_ram_gb > 0.0:
        budget_axes["host_ram"] = float(args.host_ram_gb)
    if args.net_gbps > 0.0:
        budget_axes["net"] = float(args.net_gbps)
    budget = ResourceVector(**budget_axes)

    elastic = ElasticController(
        max_slowdown=args.elastic_max_slowdown) if args.elastic \
        else None
    failures = None
    if args.failures > 0.0:
        if args.mode != "continuous":
            ap.error("--failures needs --mode continuous")
        failures = FailureSchedule.poisson(
            seed=args.seed, mtbf_s=args.failures,
            n_targets=args.replicas,
            horizon_s=args.failure_horizon_s,
            repair_s=args.repair_s)
    autoscaler = None
    fleet = args.replicas
    if args.autoscale > 0:
        if args.mode != "continuous":
            ap.error("--autoscale needs --mode continuous")
        if args.autoscale < args.replicas:
            ap.error(f"--autoscale {args.autoscale} is below "
                     f"--replicas {args.replicas}")
        autoscaler = Autoscaler(max_replicas=args.autoscale,
                                min_replicas=args.replicas,
                                interval_s=args.autoscale_interval_s)
        # the whole elastic fleet is pre-provisioned: spares idle as
        # down Nodes (and topology racks) until a scale-up flips one
        fleet = args.autoscale

    budgets = None
    if args.replica_hbm:
        hbm = [float(v) for v in args.replica_hbm.split(",")]
        if len(hbm) != fleet:
            ap.error(f"--replica-hbm lists {len(hbm)} values for a "
                     f"fleet of {fleet} (--replicas, or --autoscale "
                     f"when set)")
        budgets = [ResourceVector(**{**budget_axes, "hbm": h})
                   for h in hbm]

    topology = None
    if args.topology:
        topology = get_topology(args.topology, nodes=fleet)
    elif args.migrate:
        ap.error("--migrate needs --topology")

    tenancy = None
    tenant_list = None
    if args.tenants:
        tenant_list = parse_tenants(args.tenants)
        if not tenant_list:
            ap.error("--tenants given but no tenant specs parsed")
        tenancy = TenantRegistry(tenant_list)

    rng = np.random.default_rng(args.seed)
    requests = build_requests(args, rng, tenants=tenant_list)
    if args.backend == "paged":
        # pool sized so max_batch worst-case requests can reserve, +1
        # for the scratch page
        num_pages = 1 + args.max_batch * pages_for(max_len, page_size)
        backends = [PagedJaxBackend(cfg, num_pages=num_pages,
                                    page_size=page_size,
                                    prefill_chunk=args.prefill_chunk,
                                    seed=args.seed + r)
                    for r in range(fleet)]
    else:
        backends = [JaxBackend(cfg, max_len=max_len, seed=args.seed + r)
                    for r in range(fleet)]
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    engine = Engine(requests, demand, budget, mode=args.mode,
                    placement=args.placement, max_batch=args.max_batch,
                    replicas=args.replicas, router=args.router,
                    backends=backends, topology=topology,
                    migrate=args.migrate,
                    ingress_gb_per_token=args.ingress_gb_per_token,
                    budgets=budgets, tracer=tracer, tenants=tenancy,
                    elastic=elastic, failures=failures,
                    autoscaler=autoscaler)

    axes = ", ".join(
        f"{a}={v:.3g}" + ("Gbps" if a == "net" else "GB")
        for a, v in budget.items())
    kind = (f"paged (page={page_size}, chunk={args.prefill_chunk})"
            if args.backend == "paged" else "dense (deprecated shim)")
    print(f"serving {args.requests} requests, mode={args.mode}, "
          f"backend={kind}, placement={args.placement}, "
          f"replicas={args.replicas} (router={args.router}), "
          f"budget/replica [{axes}]")
    if budgets is not None:
        caps = " ".join(f"n{i}:{b['hbm']:.3g}GB"
                        for i, b in enumerate(budgets))
        print(f"heterogeneous cell [{caps}]")
    if topology is not None:
        print(f"topology {args.topology!r} bound "
              f"(migrate={'on' if args.migrate else 'off'}, "
              f"ingress {args.ingress_gb_per_token:.3g} GB/token)")
    if tenancy is not None:
        specs = " ".join(f"{t.name}:{t.weight:g}" for t in tenant_list)
        print(f"tenancy [{specs}] (credit-scored weighted-DRF; "
              f"router={args.router!r})")
    if elastic is not None or failures is not None \
            or autoscaler is not None:
        bits = []
        if elastic is not None:
            bits.append(f"shrink cap x{args.elastic_max_slowdown:g}")
        if failures is not None:
            bits.append(f"failures mtbf={args.failures:g}s "
                        f"({len(failures.failures)} drawn, "
                        f"repair {args.repair_s:g}s)")
        if autoscaler is not None:
            bits.append(f"autoscale {args.replicas}->{args.autoscale} "
                        f"(every {args.autoscale_interval_s:g}s)")
        print(f"elastic runtime: {', '.join(bits)}")
    t0 = time.time()
    summary = engine.run()
    wall = time.time() - t0
    print(engine.metrics.format_summary(summary))
    if args.replicas > 1:
        spread = " ".join(f"n{n}:{c}" for n, c in
                          sorted(summary["node_steps"].items()))
        print(f"router {args.router!r} step spread [{spread}]")
    if summary["forced_steps"]:
        # forced progress is observable, not silent: some step ran a
        # single request whose footprint alone exceeds the budget
        print(f"WARNING: {summary['forced_steps']} step(s) forced over "
              f"budget (single-request floor); expect paging/"
              f"preemption risk")
    tot = summary["good_tokens"]
    print(f"served {summary['completed']} requests / {tot} tokens in "
          f"{wall:.1f}s wall ({tot / max(wall, 1e-9):.1f} tok/s wall, "
          f"{summary['goodput_tok_s']:.1f} tok/s virtual)")
    if tenancy is not None and summary["tenants"]:
        print(f"{'tenant':<12} {'weight':>6} {'credit':>6} "
              f"{'done':>6} {'goodput':>9} {'slo':>6} "
              f"{'share':>7} {'rejects':>8}")
        for name, st in summary["tenants"].items():
            t = tenancy.get(name)
            rej = sum(st["rejects"].values())
            print(f"{name:<12} {t.weight:>6g} "
                  f"{tenancy.credit(name):>6.2f} "
                  f"{st['completed']:>3}/{st['requests']:<3}"
                  f"{st['goodput_tok_s']:>8.1f} "
                  f"{st['slo_attainment']:>6.2f} "
                  f"{st['dominant_share_mean']:>7.3f} {rej:>8}")
    if summary.get("elastic"):
        el = summary["elastic"]
        ev = " ".join(f"{k}:{n}" for k, n in
                      sorted(el["replica_events"].items())) or "-"
        print(f"elastic: {el['shrunk_joins']} shrunken join(s), "
              f"replica events [{ev}]")
    if tracer is not None:
        tracer.dump(args.trace)
        print(f"trace: {len(tracer)} events -> {args.trace} "
              f"(summarize: python scripts/trace_report.py "
              f"{args.trace})")
    if args.backend == "paged":
        waste = np.mean([be.waste_ratio() for be in backends])
        print(f"paged KV: {waste:.1%} of resident page slots held no "
              f"live token (dense shim would hold the full "
              f"batch*max_len grid)")
    if topology is not None:
        print(f"network: {summary['migrations']} KV migration(s), "
              f"{len(topology.completed())} transmission(s) completed")
        probes = topology.net_probes()
        if len(probes) >= 2:
            # feed observed (GB, duration) pairs back through the
            # estimator: the measured net curve replaces the declared
            # per-request constant on the next estimate
            measured = estimator.estimate(ModelTarget(
                cfg, max_len,
                net_gbps_per_req=args.net_gbps_per_req
                if args.net_gbps > 0.0 else 0.0,
                page_size=page_size, net_probes=probes))
            info = measured.info.get("net_measured")
            if info:
                print(f"measured net curve from {info['n_probes']} "
                      f"probe(s): {info['gbps_per_req']:.3g} Gbps/req "
                      f"({info['family']}, conf="
                      f"{measured.confidence.get('net', 0.0):.2f}) vs "
                      f"declared {args.net_gbps_per_req:.3g}")


if __name__ == "__main__":
    main()
