"""Production serving driver: prefill + decode loop with the paper's
memory-budgeted admission (the serving-side co-location hook).

Admission routes through ``repro.sched.AdmissionController`` — the SAME
predict -> two-point-calibrate -> budget-inverse controller the cluster
simulator's policies use, with requests as the work unit and HBM as the
host budget.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.sched import AdmissionController
from repro.train.step import build_decode_step, build_prefill_step
from repro.utils.tree import tree_bytes


def admission_batch(cfg, max_len: int, budget_gb: float,
                    controller: AdmissionController = None) -> int:
    """Paper-style: calibrate footprint(batch) at two small batches, admit
    via the inverse under the HBM budget."""
    controller = controller or AdmissionController()

    def fp(b):
        w = tree_bytes(model_lib.abstract(cfg))
        c = model_lib.init_cache(cfg, b, max_len, abstract_only=True)
        return (w + tree_bytes(c)) / 2 ** 30
    fn = controller.calibrate("affine", [(2, fp(2)), (4, fp(4))])
    return controller.admit_batch(fn, budget_gb, min_batch=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--budget-gb", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    max_len = args.prompt_len + args.decode_steps + 1
    admit = min(admission_batch(cfg, max_len, args.budget_gb),
                args.requests)
    print(f"admitting {admit} concurrent requests under "
          f"{args.budget_gb} GB")

    params = model_lib.init(cfg, jax.random.key(0))
    prefill = jax.jit(build_prefill_step(cfg, max_len))
    decode = jax.jit(build_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    served, t0 = 0, time.time()
    pending = args.requests
    while pending > 0:
        B = min(admit, pending)
        toks = jnp.asarray(rng.integers(
            3, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)
        batch = {"tokens": toks}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, 8, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, 4, cfg.d_model)), jnp.float32)
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        for _ in range(args.decode_steps - 1):
            lg, cache = decode(params, cache, outs[-1])
            outs.append(jnp.argmax(lg, -1).astype(jnp.int32))
        gen = jnp.concatenate(outs, axis=1)
        served += B
        pending -= B
        print(f"wave: {B} requests, {gen.shape[1]} tokens each "
              f"(sample: {np.asarray(gen[0])[:8].tolist()})", flush=True)
    dt = time.time() - t0
    tot = served * args.decode_steps
    print(f"served {served} requests / {tot} tokens in {dt:.1f}s "
          f"({tot/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
