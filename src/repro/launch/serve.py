"""Production serving driver: prefill + decode loop with the paper's
memory-budgeted admission (the serving-side co-location hook).

Admission routes through ``repro.sched.AdmissionController`` — the SAME
predict -> two-point-calibrate -> budget-inverse controller the cluster
simulator's policies use — with requests as the work unit and the
serving footprint on the **hbm axis** of a
:class:`~repro.sched.resources.ResourceVector` budget.  Passing
``--host-ram-gb`` adds a second budgeted axis (pinned host staging
memory per request), and the admitted wave size becomes the min over
per-axis inverses; the log reports which axis bound it.  When even a
single request exceeds the budget the controller forces progress and
flags the decision ``forced`` — logged here instead of booked silently.

Queue order is pluggable via the ``repro.sched.placement`` registry
(``--placement fcfs|sjf|best-fit|arrival-aware``): ``sjf`` serves short
prompts first, shrinking per-wave padding.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.sched import (AdmissionController, AdmissionDecision,
                         DemandModel, ResourceVector, available_placements,
                         get_placement)
from repro.core.experts import MemoryFunction
from repro.train.step import build_decode_step, build_prefill_step
from repro.utils.tree import tree_bytes


def admission_batch(cfg, max_len: int, budget_gb: float,
                    controller: AdmissionController = None,
                    host_ram_gb: float = 0.0,
                    host_ram_per_req_gb: float = 0.0
                    ) -> AdmissionDecision:
    """Paper-style: calibrate footprint(batch) at two small batches, admit
    via the binding-axis inverse under an HBM (+ optional host RAM)
    budget vector."""
    controller = controller or AdmissionController()

    def fp(b):
        w = tree_bytes(model_lib.abstract(cfg))
        c = model_lib.init_cache(cfg, b, max_len, abstract_only=True)
        return (w + tree_bytes(c)) / 2 ** 30
    fn = controller.calibrate("affine", [(2, fp(2)), (4, fp(4))])
    curves = {"hbm": fn}
    budget_axes = {"hbm": float(budget_gb)}
    if host_ram_gb > 0.0:
        # pinned host staging per in-flight request (I/O buffers, token
        # queues) — a second budgeted axis that can bind before HBM
        curves["host_ram"] = MemoryFunction(
            "affine", 0.0, float(host_ram_per_req_gb))
        budget_axes["host_ram"] = float(host_ram_gb)
    demand = DemandModel(curves, primary_axis="hbm")
    return controller.admit_batch(demand, ResourceVector(**budget_axes),
                                  min_batch=1)


@dataclass
class _Request:
    """Duck-typed for the placement registry's ordering hooks."""
    rid: int
    prompt_len: int
    arrival: float = 0.0

    @property
    def c_iso(self) -> float:
        return float(self.prompt_len)

    @property
    def items(self) -> float:
        return float(self.prompt_len)

    @property
    def unassigned(self) -> float:
        return float(self.prompt_len)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--budget-gb", type=float, default=1.0,
                    help="HBM budget for weights + KV")
    ap.add_argument("--host-ram-gb", type=float, default=0.0,
                    help="host staging budget (0 = unconstrained)")
    ap.add_argument("--host-ram-per-req-gb", type=float, default=0.05,
                    help="pinned host memory per in-flight request")
    ap.add_argument("--placement", default="fcfs",
                    choices=available_placements(),
                    help="pending-queue order (sjf = short prompts first)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    max_len = args.prompt_len + args.decode_steps + 1
    dec = admission_batch(cfg, max_len, args.budget_gb,
                          host_ram_gb=args.host_ram_gb,
                          host_ram_per_req_gb=args.host_ram_per_req_gb)
    admit = min(int(dec.units), args.requests)
    axes = ", ".join(f"{a}={v:.3g}GB" for a, v in dec.budget.items())
    print(f"admitting {admit} concurrent requests under [{axes}] "
          f"(binding axis: {dec.binding_axis or 'request count'})")
    if dec.info.get("forced"):
        # admit_batch guarantees progress even when one request is over
        # budget — observable, not silent, naming the violated axes
        viol = "; ".join(
            f"{a}: need {dec.info['demand'][a]:.3g} GB > "
            f"{dec.budget[a]:.3g} GB" for a in dec.info["forced_axes"])
        print(f"WARNING: forced admission of {int(dec.units)} "
              f"request(s) over budget ({viol}); expect paging/"
              f"preemption risk")

    params = model_lib.init(cfg, jax.random.key(0))
    prefill = jax.jit(build_prefill_step(cfg, max_len))
    decode = jax.jit(build_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    # heterogeneous prompt lengths make queue order meaningful: sjf packs
    # short prompts together, shrinking per-wave padding
    queue = [_Request(i, int(rng.integers(max(args.prompt_len // 2, 1),
                                          args.prompt_len + 1)),
                      arrival=float(i))
             for i in range(args.requests)]
    queue = get_placement(args.placement).order_jobs(queue, now=0.0)

    served, t0 = 0, time.time()
    while queue:
        wave, queue = queue[:admit], queue[admit:]
        B, L = len(wave), max(r.prompt_len for r in wave)
        toks = np.full((B, L), 3, np.int32)
        for i, r in enumerate(wave):
            toks[i, L - r.prompt_len:] = rng.integers(
                3, cfg.vocab_size, r.prompt_len)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, 8, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, 4, cfg.d_model)), jnp.float32)
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        for _ in range(args.decode_steps - 1):
            lg, cache = decode(params, cache, outs[-1])
            outs.append(jnp.argmax(lg, -1).astype(jnp.int32))
        gen = jnp.concatenate(outs, axis=1)
        served += B
        print(f"wave: {B} requests (prompts <= {L}), {gen.shape[1]} "
              f"tokens each (sample: {np.asarray(gen[0])[:8].tolist()})",
              flush=True)
    dt = time.time() - t0
    tot = served * args.decode_steps
    print(f"served {served} requests / {tot} tokens in {dt:.1f}s "
          f"({tot/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
