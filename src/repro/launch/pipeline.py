"""GPipe-style microbatch pipeline parallelism over a mesh axis.

For the multi-pod topology the natural PP mapping is stages over the
``pod`` axis (layers split across pods, activations ppermute over the
inter-pod links once per microbatch — bytes = microbatch activations,
far below the FSDP-style alternatives for cross-pod traffic).

Implementation: shard_map over the pipe axis; each rank holds its stage's
parameters; a fori_loop runs the (n_micro + n_stages - 1)-tick schedule,
ppermuting activations downstream each tick; the last stage scatters its
finished microbatch into the output buffer (psum'd at the end since only
one rank writes each slot).

Demonstrated + verified vs sequential execution in
tests/test_distributed.py (8 fake devices).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def pipeline_apply(stage_fn: Callable, mesh, axis: str,
                   stage_params, x_micro: jnp.ndarray) -> jnp.ndarray:
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a microbatch pipeline.

    stage_fn(params_slice, x) -> x'   (same shape, one pipeline stage)
    stage_params: pytree with leading dim = n_stages (sharded over axis)
    x_micro: [n_micro, mb, ...] microbatched input (replicated)
    Returns [n_micro, mb, ...] outputs.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def body(params_local, xm):
        # params_local leaves: [1, ...] (this rank's stage)
        rank = jax.lax.axis_index(axis)
        pl = jax.tree.map(lambda a: a[0], params_local)
        act = jnp.zeros_like(xm[0])
        out = jnp.zeros_like(xm)

        def tick(t, carry):
            act, out = carry
            # stage 0 ingests microbatch t (if any remain)
            inject = jnp.clip(t, 0, n_micro - 1)
            act = jnp.where(rank == 0,
                            jax.lax.dynamic_index_in_dim(
                                xm, inject, 0, keepdims=False), act)
            mb_idx = t - rank              # microbatch this rank holds
            valid = jnp.logical_and(mb_idx >= 0, mb_idx < n_micro)
            y = stage_fn(pl, act)
            y = jnp.where(valid, y, act)
            # the last stage retires its finished microbatch
            done = jnp.logical_and(rank == n_stages - 1, valid)
            slot = jnp.clip(mb_idx, 0, n_micro - 1)
            upd = jnp.where(done, y, jax.lax.dynamic_index_in_dim(
                out, slot, 0, keepdims=False))
            out = jax.lax.dynamic_update_index_in_dim(out, upd, slot, 0)
            # shift activations downstream
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            act = jax.lax.ppermute(y, axis, perm)
            return act, out

        _, out = jax.lax.fori_loop(0, ticks, tick, (act, out))
        # only the last rank has real outputs; psum replicates them
        out = jnp.where(rank == n_stages - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)
