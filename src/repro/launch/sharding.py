"""Rule-based PartitionSpec assignment: DP / TP / EP / SP / FSDP.

One rule table covers every architecture because param-leaf *names* encode
their role (wq/wk/wv/wo, wi_*/w_gate/w_up/w_down, in_proj/out_proj, embed,
lm_head, ...). Stacked (scan-over-layers) leaves get their leading layer
dim padded with None automatically.

Adaptive choices:
  * KV caches: head-sharded over 'model' when Hkv divides the model axis,
    otherwise sequence-sharded (SP) — small-GQA archs (kv=4/8) would waste
    up to 4x KV HBM on padding otherwise.
  * FSDP: when (param+optimizer) bytes per chip exceed the HBM budget with
    TP alone, large leaves additionally shard over the data axes
    (ZeRO-3-style; the scan body all-gathers one layer at a time).
  * Batch: sharded over ('pod','data') when divisible, 'data' when only
    that divides, replicated otherwise (long_500k has B=1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.launch.mesh import data_axes, model_axis_size
from repro.utils.tree import flatten_with_paths

HBM_BYTES = 16 * 2 ** 30          # v5e chip
FSDP_MIN_LEAF_BYTES = 16 * 2 ** 20


# --- per-leaf base rules: map last path component -> spec (trailing dims) --

_PARAM_RULES = {
    "embed": P("model", None),          # [V, d] vocab-sharded
    "lm_head": P(None, "model"),        # [d, V]
    "wq": P(None, "model"),
    "wk": P(None, "model"),
    "wv": P(None, "model"),
    "wo": P("model", None),             # attn out AND mlp down: [big, d]
    "wi_gate": P(None, "model"),
    "wi_up": P(None, "model"),
    "w_router": P(None, None),
    "w_gate": P("model", None, None),   # [E, d, f] expert-parallel
    "w_up": P("model", None, None),
    "w_down": P("model", None, None),
    "in_proj": P(None, "model"),
    "out_proj": P("model", None),
    "conv_w": P(None, "model"),
    "conv_b": P("model"),
    "dt_bias": P("model"),
    "A_log": P("model"),
    "D": P("model"),
    "norm_w": P("model"),
}
_REPLICATED_SUFFIXES = ("ln_w", "q_norm", "k_norm")

# Expert weights: EP over 'data' (E), Megatron-style TP over 'model' (f).
# Never FSDP-gathered (the scan-stacked all-gather-inside-loop pathology);
# DP gradient reduction becomes a reduce-scatter over experts for free.
_EXPERT_RULES = {
    "w_gate": P("data", None, "model"),   # [E, d, f]
    "w_up": P("data", None, "model"),
    "w_down": P("data", "model", None),   # [E, f, d]
}


def _leaf_spec(path: str, ndim: int) -> P:
    name = path.rsplit("/", 1)[-1]
    if any(name.endswith(s) for s in _REPLICATED_SUFFIXES):
        return P()
    rule = _EXPERT_RULES.get(name) or _PARAM_RULES.get(name)
    if rule is None:
        return P()
    pad = ndim - len(rule)
    assert pad >= 0, (path, ndim, rule)
    return P(*([None] * pad + list(rule)))


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def fix_spec(spec: P, shape, mesh) -> P:
    """pjit *input* shardings must divide exactly — drop axes that don't.
    (GSPMD pads intermediates, but argument shardings are strict.)"""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, parts):
        out.append(axis if axis is not None
                   and dim % _axis_size(mesh, axis) == 0 else None)
    return P(*out)


def _spec_axes(spec: P) -> set:
    used = set()
    for part in spec:
        if part is None:
            continue
        if isinstance(part, (tuple, list)):
            used.update(part)
        else:
            used.add(part)
    return used


def _add_fsdp(spec: P, shape, fsdp_axes, model_shards: int,
              itemsize: int) -> P:
    """Add the (not-yet-used) data axes to the largest unsharded dim of a
    big leaf. Leaves already sharded over an fsdp axis (EP expert weights)
    only receive the remaining axes."""
    used = _spec_axes(spec)
    free = tuple(a for a in fsdp_axes if a not in used)
    if not free:
        return spec
    local_bytes = int(np.prod(shape)) * itemsize
    for a in used:
        local_bytes //= max(model_shards if a == "model" else 1, 1)
    if local_bytes < FSDP_MIN_LEAF_BYTES:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    cand = [(shape[i], i) for i in range(len(shape)) if parts[i] is None]
    if not cand:
        return spec
    _, axis = max(cand)
    parts[axis] = free if len(free) > 1 else free[0]
    return P(*parts)


def param_bytes_estimate(abstract_params) -> int:
    from repro.utils.tree import tree_bytes
    return tree_bytes(abstract_params)


def decide_fsdp(cfg: ModelConfig, abstract_params, mesh, kind: str,
                tc: Optional[TrainConfig] = None) -> bool:
    """FSDP when TP-only param (+opt) state would blow per-chip HBM/2."""
    pb = param_bytes_estimate(abstract_params)
    per_chip = pb / model_axis_size(mesh)
    if kind == "train":
        adam_mult = (2.0 if (tc and tc.adam_dtype == "bfloat16") else 4.0)
        per_chip *= (1.0 + adam_mult)
    return per_chip > HBM_BYTES / 2


def param_specs(cfg: ModelConfig, abstract_params, mesh, *,
                fsdp: Optional[bool] = None, kind: str = "train",
                tc: Optional[TrainConfig] = None):
    """PartitionSpec tree matching the params tree.

    FSDP (weight sharding over data) applies only for *serving* of models
    whose TP-sharded weights exceed HBM (kimi-class): in training, FSDP on
    scan-stacked params makes GSPMD all-gather the full stacked array per
    loop iteration (measured: 250s collective term on qwen3-14b). Training
    memory relief comes from ZeRO-1 sharded optimizer state instead
    (see train_shardings)."""
    if fsdp is None:
        fsdp = kind != "train" and decide_fsdp(
            cfg, abstract_params, mesh, kind, tc)
    ms = model_axis_size(mesh)
    daxes = data_axes(mesh)
    flat = flatten_with_paths(abstract_params)
    specs = []
    for path, leaf in flat:
        spec = _leaf_spec(path, leaf.ndim)
        if fsdp:
            spec = _add_fsdp(spec, leaf.shape, daxes, ms,
                             jax.numpy.dtype(leaf.dtype).itemsize)
        spec = fix_spec(spec, leaf.shape, mesh)
        specs.append(spec)
    treedef = jax.tree.structure(abstract_params)
    return jax.tree.unflatten(treedef, specs)


def zero1_opt_specs(param_spec_tree, abstract_params, mesh):
    """ZeRO-1: optimizer moments additionally sharded over the data axes
    (one gather of params + one reduce-scatter of grads per step, OUTSIDE
    the layer loop — unlike scan-FSDP)."""
    daxes = data_axes(mesh)
    ms = model_axis_size(mesh)
    flat_p = flatten_with_paths(abstract_params)
    flat_s = [s for _, s in flatten_with_paths(
        jax.tree.map(lambda x: x, param_spec_tree,
                     is_leaf=lambda x: isinstance(x, P)))]
    out = []
    for (path, leaf), spec in zip(flat_p, flat_s):
        s = _add_fsdp(spec, leaf.shape, daxes, ms, 4)
        out.append(fix_spec(s, leaf.shape, mesh))
    return jax.tree.unflatten(jax.tree.structure(abstract_params), out)


def batch_axes(mesh, batch_size: int):
    daxes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    if daxes and batch_size % total == 0:
        return daxes if len(daxes) > 1 else daxes[0]
    if "data" in daxes and batch_size % mesh.shape["data"] == 0:
        return "data"
    return None


def batch_specs(batch_tree, mesh):
    """Batch dict: leading dim is always global batch."""
    def spec(path, leaf):
        B = leaf.shape[0] if leaf.ndim else 1
        ba = batch_axes(mesh, B)
        return P(*([ba] + [None] * (leaf.ndim - 1))) if leaf.ndim else P()
    flat = flatten_with_paths(batch_tree)
    specs = [spec(p, l) for p, l in flat]
    return jax.tree.unflatten(jax.tree.structure(batch_tree), specs)


def cache_specs(cfg: ModelConfig, cache_tree, mesh):
    """KV/SSM cache sharding (see module docstring for the SP rule)."""
    ms = model_axis_size(mesh)

    def spec(path, leaf):
        name = path.rsplit("/", 1)[-1]
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "cross_k", "cross_v") or name.endswith(
                ("_k", "_v")):
            # [L, B, S, Hkv, hd]
            B = leaf.shape[1]
            ba = batch_axes(mesh, B)
            if cfg.num_kv_heads and cfg.num_kv_heads % ms == 0:
                spec = P(None, ba, None, "model", None)
            else:
                spec = P(None, ba, "model", None, None)  # seq-parallel KV
        elif name == "ssm":
            B = leaf.shape[1]
            ba = batch_axes(mesh, B)
            H = leaf.shape[2]
            hax = "model" if H % ms == 0 else None
            spec = P(None, ba, hax, None, None)
        elif name == "conv":
            B = leaf.shape[1]
            ba = batch_axes(mesh, B)
            spec = P(None, ba, None, "model")
        else:
            return P()
        return fix_spec(spec, leaf.shape, mesh)

    flat = flatten_with_paths(cache_tree)
    specs = [spec(p, l) for p, l in flat]
    return jax.tree.unflatten(jax.tree.structure(cache_tree), specs)


def to_named(tree_of_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Assembled sharding plans per step kind
# ---------------------------------------------------------------------------

def train_shardings(cfg: ModelConfig, mesh, abstract_params, abstract_opt,
                    abstract_batch, tc: Optional[TrainConfig] = None,
                    fsdp: Optional[bool] = None) -> Dict[str, Any]:
    ps = param_specs(cfg, abstract_params, mesh, fsdp=fsdp, kind="train",
                     tc=tc)
    # ZeRO-1: moments sharded over data axes on top of the param TP spec;
    # step counter replicated
    zs = zero1_opt_specs(ps, abstract_params, mesh)
    opt_spec = type(abstract_opt)(m=zs, v=zs, count=P())
    bs = batch_specs(abstract_batch, mesh)
    return {
        "params": to_named(ps, mesh),
        "opt": to_named(opt_spec, mesh),
        "batch": to_named(bs, mesh),
        "metrics": NamedSharding(mesh, P()),
    }


def serve_shardings(cfg: ModelConfig, mesh, abstract_params, abstract_cache,
                    token_batch: int, fsdp: Optional[bool] = None
                    ) -> Dict[str, Any]:
    ps = param_specs(cfg, abstract_params, mesh, fsdp=fsdp, kind="serve")
    cs = cache_specs(cfg, abstract_cache, mesh)
    ba = batch_axes(mesh, token_batch)
    return {
        "params": to_named(ps, mesh),
        "cache": to_named(cs, mesh),
        "token": NamedSharding(mesh, P(ba, None)),
        "logits": NamedSharding(mesh, P(ba, None, "model")),
    }
