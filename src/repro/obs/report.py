"""Rebuild a run's story from its trace alone.

:func:`summarize` reads a Chrome/Perfetto ``trace_event`` JSON file (as
emitted by :class:`~repro.obs.trace.Tracer` through the engine /
simulator / topology hooks) and reconstructs:

* the **time breakdown** — queueing (request routed -> first join),
  prefill, decode, and network transfer seconds;
* **per-node occupancy** — each replica's step-span busy time over the
  run's elapsed virtual time;
* **per-link occupancy** — busy fraction and peak concurrent flows,
  integrated from the ``link:*`` flow counter samples;
* **event rates** — runtime events dispatched per kind (and stale
  drops) per virtual second;
* **goodput and migrations** — finished requests' token sum over
  elapsed time, and completed KV-migration transfers — plus the same
  goodput broken down **per tenant** from the tenant tag on request
  lifecycle spans (tenancy runs).  These reproduce
  the serving bench's numbers from the trace alone (`benchmarks/
  serving_bench.py` asserts bit-equality), which is the acceptance bar
  for the trace being a faithful record rather than a pretty picture.

Used by ``scripts/trace_report.py``; stdlib only.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple


def load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def _span_bounds(ev: Dict) -> Tuple[float, float]:
    """(t0, t1) seconds of a complete span — exact when the emitter
    stamped raw seconds into args (the engine's step spans do), the
    µs round-trip otherwise."""
    args = ev.get("args") or {}
    t0 = args.get("t0", ev["ts"] / 1e6)
    t1 = args.get("t1", (ev["ts"] + ev.get("dur", 0.0)) / 1e6)
    return float(t0), float(t1)


def summarize(trace) -> Dict:
    """``trace`` is a path or an already-loaded payload dict."""
    if isinstance(trace, str):
        trace = load(trace)
    events = trace.get("traceEvents", [])

    processes: Dict[int, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            processes[ev["pid"]] = ev["args"]["name"]

    # two elapsed candidates: exact raw-seconds stamps (step/xfer span
    # args, request-end args) and the µs round-trip fallback.  Exact
    # wins when any emitter stamped one — the µs round-trip can drift
    # by ~1e-10 relative, which breaks the bit-identical goodput check.
    elapsed_exact: Optional[float] = None
    elapsed_us = 0.0
    prefill_s = decode_s = 0.0
    transfer_s: Dict[str, float] = {}
    node_busy: Dict[str, float] = {}
    node_steps: Dict[str, int] = {}
    events_by_kind: Dict[str, int] = {}
    stale_by_kind: Dict[str, int] = {}
    req_begin: Dict[str, float] = {}
    req_join: Dict[str, float] = {}
    good_tokens = 0
    completed = 0
    migrations = 0
    # per-tenant goodput, rebuilt from the tenant tag the engine stamps
    # on request lifecycle spans (absent on untenanted runs)
    tenant_tokens: Dict[str, int] = {}
    tenant_completed: Dict[str, int] = {}
    link_samples: Dict[str, List[Tuple[float, float]]] = {}

    for ev in events:
        ph, name = ev.get("ph"), ev.get("name", "")
        if ph == "X":
            t0, t1 = _span_bounds(ev)
            if "t1" in (ev.get("args") or {}):
                elapsed_exact = t1 if elapsed_exact is None \
                    else max(elapsed_exact, t1)
            else:
                elapsed_us = max(elapsed_us, t1)
            proc = processes.get(ev["pid"], str(ev["pid"]))
            if name == "step":
                node_busy[proc] = node_busy.get(proc, 0.0) + (t1 - t0)
                node_steps[proc] = node_steps.get(proc, 0) + 1
            elif name == "prefill":
                prefill_s += t1 - t0
            elif name == "decode":
                decode_s += t1 - t0
            elif name.startswith("xfer:"):
                tag = name[len("xfer:"):]
                transfer_s[tag] = transfer_s.get(tag, 0.0) + (t1 - t0)
                if tag == "kv-migration":
                    migrations += 1
            elif name.startswith("event:"):
                kind = name[len("event:"):]
                events_by_kind[kind] = events_by_kind.get(kind, 0) + 1
        elif ph == "i":
            if name.startswith("stale:"):
                kind = name[len("stale:"):]
                stale_by_kind[kind] = stale_by_kind.get(kind, 0) + 1
            elif name == "join":
                rid = str((ev.get("args") or {}).get("rid"))
                req_join.setdefault(rid, ev["ts"] / 1e6)
        elif ph == "b" and name == "req":
            req_begin.setdefault(ev["id"], ev["ts"] / 1e6)
        elif ph == "e" and name == "req":
            args = ev.get("args") or {}
            good_tokens += int(args.get("tokens", 0))
            completed += 1
            if "tenant" in args:
                tn = str(args["tenant"])
                tenant_tokens[tn] = tenant_tokens.get(tn, 0) \
                    + int(args.get("tokens", 0))
                tenant_completed[tn] = tenant_completed.get(tn, 0) + 1
            if "t1" in args:
                t1 = float(args["t1"])
                elapsed_exact = t1 if elapsed_exact is None \
                    else max(elapsed_exact, t1)
            else:
                elapsed_us = max(elapsed_us, ev["ts"] / 1e6)
        elif ph == "C" and name.startswith("link:"):
            link_samples.setdefault(name[len("link:"):], []).append(
                (ev["ts"] / 1e6, float(ev["args"].get("flows", 0.0))))

    elapsed = elapsed_exact if elapsed_exact is not None else elapsed_us

    # queueing: routed -> first join, per request that ever joined
    queueing = [req_join[r] - t for r, t in req_begin.items()
                if r in req_join]

    per_link: Dict[str, Dict] = {}
    for lname, samples in sorted(link_samples.items()):
        busy = 0.0
        peak = 0.0
        for (t0, flows), (t1, _) in zip(samples, samples[1:]):
            peak = max(peak, flows)
            if flows > 0:
                busy += t1 - t0
        if samples:
            peak = max(peak, samples[-1][1])
            if samples[-1][1] > 0:               # busy through the end
                busy += max(elapsed - samples[-1][0], 0.0)
        per_link[lname] = {
            "busy_s": busy,
            "busy_frac": busy / elapsed if elapsed > 0 else 0.0,
            "peak_flows": int(peak)}

    n_dispatched = sum(events_by_kind.values())
    return {
        "elapsed_s": elapsed,
        "breakdown": {
            "queueing_s": sum(queueing),
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "transfer_s": sum(transfer_s.values()),
        },
        "transfer_by_tag_s": transfer_s,
        "per_node": {
            proc: {"steps": node_steps.get(proc, 0), "busy_s": busy,
                   "occupancy": busy / elapsed if elapsed > 0 else 0.0}
            for proc, busy in sorted(node_busy.items())},
        "per_link": per_link,
        "events_by_kind": events_by_kind,
        "stale_by_kind": stale_by_kind,
        "events_per_virtual_s": n_dispatched / elapsed
        if elapsed > 0 else 0.0,
        "requests": len(req_begin),
        "completed": completed,
        "good_tokens": good_tokens,
        # EXACTLY ServingMetrics.summary's goodput formula, so a traced
        # bench reproduces its goodput bit-identically from the trace
        "goodput_tok_s": good_tokens / max(elapsed, 1e-12),
        "migrations": migrations,
        # per-tenant breakdown, same goodput formula per tenant ({} on
        # untenanted traces)
        "tenants": {
            tn: {"completed": tenant_completed.get(tn, 0),
                 "good_tokens": tenant_tokens.get(tn, 0),
                 "goodput_tok_s": tenant_tokens.get(tn, 0)
                 / max(elapsed, 1e-12)}
            for tn in sorted(tenant_tokens)},
    }


def format_report(rep: Dict, title: Optional[str] = None) -> str:
    b = rep["breakdown"]
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append(
        f"elapsed {rep['elapsed_s']:.3f}s virtual | "
        f"{rep['completed']}/{rep['requests']} requests | goodput "
        f"{rep['goodput_tok_s']:.1f} tok/s | migrations "
        f"{rep['migrations']}")
    lines.append(
        f"breakdown: queueing {b['queueing_s']:.3f}s | prefill "
        f"{b['prefill_s']:.3f}s | decode {b['decode_s']:.3f}s | "
        f"transfer {b['transfer_s']:.3f}s")
    for proc, st in rep["per_node"].items():
        lines.append(f"node {proc}: {st['steps']} steps, busy "
                     f"{st['busy_s']:.3f}s ({st['occupancy']:.1%})")
    for lname, st in rep["per_link"].items():
        lines.append(f"link {lname}: busy {st['busy_frac']:.1%}, peak "
                     f"{st['peak_flows']} flows")
    for tn, st in rep.get("tenants", {}).items():
        lines.append(f"tenant {tn}: {st['completed']} completed, "
                     f"goodput {st['goodput_tok_s']:.1f} tok/s")
    kinds = " ".join(f"{k}:{n}" for k, n in
                     sorted(rep["events_by_kind"].items()))
    stale = sum(rep["stale_by_kind"].values())
    lines.append(f"events [{kinds}] ({rep['events_per_virtual_s']:.0f}"
                 f"/virtual-s, {stale} stale)")
    return "\n".join(lines)
