"""Telemetry registry: counters, gauges, and sampled timelines.

One :class:`Telemetry` instance rides on every
:class:`~repro.sched.cluster.ClusterRuntime`; consumers increment
counters and sample timelines, benchmarks read ``summary()``.

The split is deliberate and load-bearing:

* ``counters``  — DETERMINISTIC accumulators (events dispatched per
  kind, stale drops, migrations).  Safe to surface in seed-pinned
  outputs: identical seeds give identical counters.
* ``gauges``    — point-in-time values that may come from the WALL
  clock (events/sec of real time).  These must never be copied into an
  engine/simulator summary dict — the traced-vs-untraced bit-identical
  acceptance check (and every golden) would break on machine speed.
* ``timelines`` — ``(t, value)`` samples on the virtual clock (per-axis
  node utilization, per-link flow counts); ``summary()`` reduces them
  to n/mean/max/last so a bench line stays one line.

Stdlib only, imports nothing from the rest of ``repro``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


class Telemetry:
    """Plain counter / gauge / timeline registry (no locking — the
    runtime is single-threaded over a virtual clock)."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.timelines: Dict[str, List[Tuple[float, float]]] = {}

    def inc(self, name: str, by: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + by

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def sample(self, name: str, t: float, value: float) -> None:
        """Append one virtual-time sample to the ``name`` timeline."""
        self.timelines.setdefault(name, []).append(
            (float(t), float(value)))

    # --- reading ----------------------------------------------------------
    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def counters_with_prefix(self, prefix: str) -> Dict[str, float]:
        return {k: v for k, v in self.counters.items()
                if k.startswith(prefix)}

    def summary(self) -> Dict:
        """Counters and gauges verbatim; timelines reduced to
        ``{n, mean, max, last}`` (time-unweighted over the samples)."""
        lines = {}
        for name, pts in self.timelines.items():
            vals = [v for _, v in pts]
            lines[name] = {
                "n": len(vals),
                "mean": sum(vals) / len(vals) if vals else 0.0,
                "max": max(vals) if vals else 0.0,
                "last": vals[-1] if vals else 0.0,
            }
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "timelines": lines}


def sample_node(telemetry: Telemetry, node, t: float) -> None:
    """Sample every capacitated axis of a
    :class:`~repro.sched.cluster.Node`'s booked-claim ledger into
    ``node<nid>.util.<axis>`` timelines (booked fraction of capacity)."""
    for axis in node.capacity.axes:
        telemetry.sample(f"node{node.nid}.util.{axis}", t,
                         node.utilization(axis))


def sample_links(telemetry: Telemetry, topology, t: float) -> None:
    """Sample every :class:`~repro.sched.topology.Link`'s in-flight
    ledger into ``link.<name>.flows`` timelines."""
    for link in topology.links():
        telemetry.sample(f"link.{link.name}.flows", t, link.n_flows)
