"""Chrome/Perfetto ``trace_event`` tracing on the virtual clock.

The :class:`Tracer` collects events in the JSON Object Format the
Chrome tracing tools and Perfetto ingest (``{"traceEvents": [...]}``,
https://ui.perfetto.dev): complete spans (``ph: "X"``), begin/end stacks
(``"B"``/``"E"``), instants (``"i"``), counters (``"C"``) and async
spans (``"b"``/``"e"``).  Timestamps are **virtual seconds converted to
microseconds** (``ts = t * 1e6``) — no wall-clock value ever enters a
trace, so a seeded run emits a byte-identical trace on any machine (the
determinism golden in ``tests/test_obs.py`` pins this).

Tracks are named, not numbered: callers pass ``process=``/``thread=``
strings ("replica0" / "steps") and the tracer lazily assigns stable
integer pids/tids in first-use order, emitting the ``"M"``
``process_name`` / ``thread_name`` metadata events Perfetto uses for
labels.

:func:`validate_chrome_trace` is the schema gate the benchmarks and CI
run before a trace file is accepted: per-event field checks plus the
B/E stack-balance invariant per track.

Stdlib only — importable from ``repro.sched.cluster`` without cycles.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

#: phases this tracer emits (a subset of the trace_event spec)
_PHASES = ("X", "B", "E", "i", "C", "b", "e", "M")


class Tracer:
    """Collects ``trace_event`` records; ``chrome()`` / ``dump()`` emit
    the JSON Object Format.  All timestamps are virtual seconds (the
    runtime's clock); the tracer converts to µs."""

    enabled = True

    def __init__(self):
        self.events: List[Dict] = []
        self._pids: Dict[str, int] = {}
        self._tids: Dict[Tuple[str, str], int] = {}
        #: per-track B/E stack (span-nesting invariant enforced live)
        self._stacks: Dict[Tuple[int, int], List[str]] = {}

    # --- track registry ---------------------------------------------------
    def _track(self, process: str, thread: str) -> Tuple[int, int]:
        pid = self._pids.get(process)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[process] = pid
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0,
                                "args": {"name": process}})
        key = (process, thread)
        tid = self._tids.get(key)
        if tid is None:
            tid = sum(1 for p, _ in self._tids if p == process) + 1
            self._tids[key] = tid
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": thread}})
        return pid, self._tids[key]

    @staticmethod
    def _us(t: float) -> float:
        return float(t) * 1e6

    def _emit(self, ev: Dict, args: Optional[Dict]) -> None:
        if args:
            ev["args"] = args
        self.events.append(ev)

    # --- event kinds ------------------------------------------------------
    def complete(self, name: str, t0: float, t1: float, *,
                 process: str = "runtime", thread: str = "main",
                 cat: str = "", args: Optional[Dict] = None) -> None:
        """One complete span (``ph: "X"``) from ``t0`` to ``t1``
        virtual seconds."""
        pid, tid = self._track(process, thread)
        ev = {"ph": "X", "name": name, "ts": self._us(t0),
              "dur": self._us(max(float(t1) - float(t0), 0.0)),
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        self._emit(ev, args)

    def begin(self, name: str, t: float, *,
              process: str = "runtime", thread: str = "main",
              args: Optional[Dict] = None) -> None:
        """Open a stack span (``ph: "B"``); must be closed by
        :meth:`end` on the SAME track, innermost first."""
        pid, tid = self._track(process, thread)
        self._stacks.setdefault((pid, tid), []).append(name)
        self._emit({"ph": "B", "name": name, "ts": self._us(t),
                    "pid": pid, "tid": tid}, args)

    def end(self, t: float, *, process: str = "runtime",
            thread: str = "main", name: Optional[str] = None,
            args: Optional[Dict] = None) -> None:
        """Close the innermost open span on the track (``ph: "E"``).
        Passing ``name`` asserts it matches — the nesting invariant."""
        pid, tid = self._track(process, thread)
        stack = self._stacks.get((pid, tid), [])
        if not stack:
            raise ValueError(f"end() with no open span on track "
                             f"{process!r}/{thread!r}")
        top = stack.pop()
        if name is not None and name != top:
            stack.append(top)
            raise ValueError(f"end({name!r}) does not match open span "
                             f"{top!r} on track {process!r}/{thread!r}")
        self._emit({"ph": "E", "name": top, "ts": self._us(t),
                    "pid": pid, "tid": tid}, args)

    def instant(self, name: str, t: float, *,
                process: str = "runtime", thread: str = "main",
                cat: str = "", args: Optional[Dict] = None) -> None:
        pid, tid = self._track(process, thread)
        ev = {"ph": "i", "name": name, "ts": self._us(t), "s": "t",
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        self._emit(ev, args)

    def counter(self, name: str, t: float, values: Dict[str, float], *,
                process: str = "runtime") -> None:
        """One sample of a multi-series counter track (``ph: "C"``)."""
        pid, tid = self._track(process, "counters")
        self.events.append({"ph": "C", "name": name, "ts": self._us(t),
                            "pid": pid, "tid": tid,
                            "args": {k: float(v)
                                     for k, v in values.items()}})

    def async_begin(self, name: str, t: float, ident, *, cat: str,
                    process: str = "runtime", thread: str = "main",
                    args: Optional[Dict] = None) -> None:
        """Open an async span (``ph: "b"``) — overlapping lifecycles
        (requests, jobs, transfers) keyed by ``(cat, ident)``."""
        pid, tid = self._track(process, thread)
        self._emit({"ph": "b", "name": name, "ts": self._us(t),
                    "id": str(ident), "cat": cat, "pid": pid,
                    "tid": tid}, args)

    def async_end(self, name: str, t: float, ident, *, cat: str,
                  process: str = "runtime", thread: str = "main",
                  args: Optional[Dict] = None) -> None:
        pid, tid = self._track(process, thread)
        self._emit({"ph": "e", "name": name, "ts": self._us(t),
                    "id": str(ident), "cat": cat, "pid": pid,
                    "tid": tid}, args)

    # --- output -----------------------------------------------------------
    def chrome(self) -> Dict:
        """The JSON Object Format payload Perfetto/chrome://tracing
        open directly."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def dump(self, path: str) -> Dict:
        """Validate, then write the trace to ``path``.  Returns the
        payload (handy for immediate summarizing)."""
        payload = self.chrome()
        validate_chrome_trace(payload)
        with open(path, "w") as f:
            json.dump(payload, f, separators=(",", ":"),
                      sort_keys=True)
        return payload

    def __len__(self) -> int:
        return len(self.events)


class NullTracer:
    """The disabled default: every method is a no-op, so instrumented
    code can call unconditionally.  Hot paths that would build argument
    dicts should still guard on ``tracer.enabled``."""

    enabled = False
    events: List[Dict] = []

    def _noop(self, *a, **k) -> None:
        return None

    complete = begin = end = instant = counter = _noop
    async_begin = async_end = _noop

    def chrome(self) -> Dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def __len__(self) -> int:
        return 0


def validate_chrome_trace(obj) -> None:
    """Raise ``ValueError`` unless ``obj`` is a schema-valid
    ``trace_event`` JSON Object Format payload.

    Checks per event: known phase, non-empty name, integer pid/tid,
    finite non-negative ``ts`` (metadata events excepted), ``dur`` on
    complete spans, ``id``+``cat`` on async events, numeric ``args`` on
    counters — plus the cross-event B/E stack-balance invariant per
    ``(pid, tid)`` track (every begin closed, innermost first)."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    stacks: Dict[Tuple[int, int], List[str]] = {}
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: event must be an object")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"{where}: unknown phase {ph!r}")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            raise ValueError(f"{where}: missing/empty name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                raise ValueError(f"{where}: {k} must be an integer")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts != ts \
                    or ts in (float("inf"), float("-inf")) or ts < 0:
                raise ValueError(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: complete span needs "
                                 f"dur >= 0, got {dur!r}")
        if ph in ("b", "e"):
            if "id" not in ev or not ev.get("cat"):
                raise ValueError(f"{where}: async event needs id + cat")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float))
                    for v in args.values()):
                raise ValueError(f"{where}: counter needs numeric args")
        if ph == "M":
            if name not in ("process_name", "thread_name") or \
                    not isinstance(ev.get("args", {}).get("name"), str):
                raise ValueError(f"{where}: bad metadata event")
        if ph == "B":
            stacks.setdefault((ev["pid"], ev["tid"]), []).append(name)
        elif ph == "E":
            stack = stacks.get((ev["pid"], ev["tid"]), [])
            if not stack:
                raise ValueError(f"{where}: E with no open B on track "
                                 f"({ev['pid']}, {ev['tid']})")
            top = stack.pop()
            if top != name:
                raise ValueError(f"{where}: E({name!r}) does not close "
                                 f"B({top!r})")
    open_tracks = {k: v for k, v in stacks.items() if v}
    if open_tracks:
        raise ValueError(f"unclosed B spans at end of trace: "
                         f"{open_tracks}")
