"""Observability: tracing + telemetry over the virtual-clock runtime.

Two small, dependency-free primitives the whole stack hooks into:

* ``trace``     — :class:`Tracer`: Chrome/Perfetto ``trace_event``
  JSON spans, instants, counters and async spans, stamped from the
  VIRTUAL clock (``ts = t * 1e6`` µs), so a seeded run emits a
  byte-identical trace on any machine.  :class:`NullTracer` is the
  disabled default; :func:`validate_chrome_trace` checks schema and
  span-nesting invariants before a trace is written.
* ``telemetry`` — :class:`Telemetry`: a plain counter / gauge /
  timeline registry.  Deterministic counts (events per kind, stale
  drops) live in ``counters``; wall-clock rates (events/sec) live ONLY
  in ``gauges`` so they can never leak into seed-pinned summaries.
* ``report``    — :func:`~repro.obs.report.summarize`: rebuild the
  run's story from the trace alone (queueing / prefill / decode /
  transfer breakdown, per-node and per-link occupancy, goodput,
  migrations) — the library behind ``scripts/trace_report.py``.

Like ``repro.sched.cluster``, this package imports nothing from
``repro.core`` or ``repro.serve`` (stdlib only), so the runtime can
import it without cycles.
"""
from repro.obs.telemetry import Telemetry  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    NullTracer,
    Tracer,
    validate_chrome_trace,
)
