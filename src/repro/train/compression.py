"""Int8 error-feedback gradient compression.

Models the accuracy path of compressed DP all-reduce: gradients are
quantized to int8 with a per-tensor scale before the (conceptual) reduce
and dequantized after; the quantization residual is carried in an error
buffer and added back next step (error feedback keeps SGD/Adam unbiased
in the long run). On a real fleet the int8 payload is what crosses ICI/
DCN — a 4x collective-bytes reduction on the DP all-reduce, recorded as a
collective-roofline lever in EXPERIMENTS.md.

``tests/test_distributed.py`` additionally demonstrates the explicit
shard_map + psum(int32) variant on 8 fake devices.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_buffer(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads_ef(grads, error_buf):
    """Apply int8 EF compression to a gradient pytree.

    Returns (decompressed_grads, new_error_buf, bytes_ratio)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e
