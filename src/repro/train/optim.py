"""AdamW + LR schedules, from scratch (no optax on the box).

State layout mirrors params exactly (pytree of {m, v}) so the sharding
rules that apply to a parameter apply verbatim to its optimizer moments —
this is what lets ZeRO-style sharded optimizer state fall out of the
PartitionSpec rules for free.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    m: Any           # first moment, pytree like params
    v: Any           # second moment, pytree like params
    count: jnp.ndarray  # step counter, int32 scalar


def init_opt_state(params, tc: TrainConfig) -> OptState:
    dt = jnp.dtype(tc.adam_dtype)
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, dt), p)
    return OptState(m=zeros(params), v=zeros(params),
                    count=jnp.zeros((), jnp.int32))


def abstract_opt_state(params, tc: TrainConfig) -> OptState:
    dt = jnp.dtype(tc.adam_dtype)
    mk = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jax.ShapeDtypeStruct(x.shape, dt), p)
    return OptState(m=mk(params), v=mk(params),
                    count=jax.ShapeDtypeStruct((), jnp.int32))


def cosine_schedule(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to 10% of peak."""
    stepf = step.astype(jnp.float32)
    warm = jnp.minimum(stepf / jnp.maximum(tc.warmup_steps, 1), 1.0)
    frac = jnp.clip((stepf - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: OptState, tc: TrainConfig
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    count = state.count + 1
    lr = cosine_schedule(tc, count)
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    sdt = jnp.dtype(tc.adam_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + tc.weight_decay * (
            p.astype(jnp.float32))
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics
