"""Cross-entropy over (possibly vocab-sharded) logits.

The logits einsum keeps the vocab dimension shardable over the 'model'
axis; logsumexp reduces over vocab (GSPMD inserts the small all-reduce).
Optional sequence chunking bounds the fp32 logits working set — a
memory-roofline lever recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib


def _ce_from_hidden(params, cfg, hidden, labels, mask):
    logits = model_lib.lm_logits(params, cfg, hidden)  # [B,S,V] fp32
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def lm_loss(params, cfg, hidden: jnp.ndarray, labels: jnp.ndarray,
            loss_mask: Optional[jnp.ndarray] = None,
            seq_chunks: int = 1) -> Tuple[jnp.ndarray, Dict]:
    """Next-token CE. hidden: [B,S,d]; labels: [B,S] (already shifted by
    the data pipeline: labels[t] = target for position t)."""
    B, S, _ = hidden.shape
    mask = (jnp.ones((B, S), jnp.float32) if loss_mask is None
            else loss_mask.astype(jnp.float32))
    if seq_chunks > 1 and S % seq_chunks == 0:
        c = S // seq_chunks
        tot = jnp.zeros((), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for i in range(seq_chunks):
            t, n = _ce_from_hidden(params, cfg,
                                   hidden[:, i * c:(i + 1) * c],
                                   labels[:, i * c:(i + 1) * c],
                                   mask[:, i * c:(i + 1) * c])
            tot, cnt = tot + t, cnt + n
    else:
        tot, cnt = _ce_from_hidden(params, cfg, hidden, labels, mask)
    denom = jnp.maximum(cnt, 1.0)
    loss = tot / denom
    return loss, {"ce_loss": loss, "tokens": cnt}
