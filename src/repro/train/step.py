"""Step builders: train_step / prefill_step / decode_step.

Pure function factories — the returned callables close over static configs
only, so they jit/lower cleanly with pjit shardings for the dry-run and
the real drivers alike.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as model_lib
from repro.train import compression as comp
from repro.train import optim
from repro.train.loss import lm_loss


def build_loss_fn(cfg: ModelConfig, seq_chunks: int = 1) -> Callable:
    def loss_fn(params, batch):
        hidden, aux = model_lib.forward_train(params, cfg, batch)
        loss, metrics = lm_loss(params, cfg, hidden, batch["labels"],
                                batch.get("loss_mask"),
                                seq_chunks=seq_chunks)
        total = loss + cfg.router_aux_weight * aux
        metrics = dict(metrics, aux_loss=aux, total_loss=total)
        return total, metrics
    return loss_fn


def build_train_step(cfg: ModelConfig, tc: TrainConfig,
                     seq_chunks: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With tc.microbatch set, the global batch is split into
    B/microbatch accumulation steps via lax.scan (remat-friendly).
    With tc.grad_compression == 'int8_ef', opt_state carries an error
    buffer inside metrics-free aux (see build_train_step_compressed).
    """
    loss_fn = build_loss_fn(cfg, seq_chunks)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatch:
            B = batch["tokens"].shape[0]
            n = B // tc.microbatch
            assert n * tc.microbatch == B, (B, tc.microbatch)
            reshaped = jax.tree.map(
                lambda x: x.reshape((n, tc.microbatch) + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                g_acc, l_acc = acc
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), m

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), ms = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), reshaped)
            grads = jax.tree.map(lambda g: g / n, g_sum)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
            metrics["total_loss"] = l_sum / n
            return grads, metrics
        (_, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        params, opt_state, opt_metrics = optim.adamw_update(
            params, grads, opt_state, tc)
        return params, opt_state, dict(metrics, **opt_metrics)

    return train_step


def build_train_step_compressed(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Variant with int8 error-feedback gradient compression:
    (params, opt_state, error_buf, batch) -> (params, opt_state, error_buf,
    metrics)."""
    loss_fn = build_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, error_buf, batch):
        (_, metrics), grads = grad_fn(params, batch)
        grads, error_buf = comp.compress_grads_ef(grads, error_buf)
        params, opt_state, opt_metrics = optim.adamw_update(
            params, grads, opt_state, tc)
        return params, opt_state, error_buf, dict(metrics, **opt_metrics)

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch, max_len=max_len)
    return prefill_step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token):
        return model_lib.decode_step(params, cfg, cache, token)
    return decode_step


def build_paged_decode_step(cfg: ModelConfig) -> Callable:
    """One-token decode over the page-pool cache; per-row positions.

    (params, cache, token [B,1], active [B] bool) -> (logits, cache)."""
    def paged_decode_step(params, cache, token, active):
        return model_lib.decode_step_paged(params, cfg, cache, token,
                                           active)
    return paged_decode_step


def build_prefill_chunk_step(cfg: ModelConfig) -> Callable:
    """One prompt chunk per row into the page-pool cache.

    (params, cache, tokens [B,C], start [B], chunk_lens [B],
    active [B] bool) -> (last-valid-token logits [B,1,V], cache)."""
    def prefill_chunk_step(params, cache, tokens, start, chunk_lens,
                           active):
        return model_lib.prefill_chunk(params, cfg, cache, tokens,
                                       start, chunk_lens, active)
    return prefill_chunk_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """The dry-run's decode entry: one new token, greedy sample.

    (params, {"token", "cache"}) -> (next_token [B,1], cache)."""
    def serve_step(params, token, cache):
        logits, cache = model_lib.decode_step(params, cfg, cache, token)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache
    return serve_step
