from repro.train.step import (  # noqa: F401
    build_decode_step,
    build_loss_fn,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    build_train_step_compressed,
)
