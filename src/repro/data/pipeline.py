"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — workers on
different hosts slice disjoint shards of the same logical batch with no
coordination, and a restarted job regenerates exactly the batch it would
have seen (checkpoint/restart determinism, tested).

Two generators:
  * ``lm_synthetic``  — structured pseudo-text (Zipfian unigrams + local
    bigram structure) so cross-entropy has learnable signal.
  * ``copy_task``     — [BOS, payload..., SEP, payload...]; loss on the
    second half. A ~100M model learns this quickly — the quickstart's
    convergence check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    kind: str = "lm_synthetic"   # lm_synthetic | copy_task
    seed: int = 1234
    zipf_a: float = 1.3


def _rng_for(dc: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, shard]))


def _zipf_tokens(rng, shape, vocab, a):
    # rejection-free bounded zipf via inverse-CDF on a truncated support
    ranks = rng.zipf(a, size=shape)
    return np.minimum(ranks, vocab - 1).astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
               step: int, shard: int = 0, num_shards: int = 1
               ) -> Dict[str, np.ndarray]:
    """One (shard of a) global batch for `train` kind shapes."""
    B = shape.global_batch // num_shards
    S = shape.seq_len
    rng = _rng_for(dc, step, shard)

    if dc.kind == "copy_task":
        half = S // 2
        payload = rng.integers(3, cfg.vocab_size, size=(B, half - 1),
                               dtype=np.int32)
        seq = np.concatenate(
            [np.full((B, 1), 1, np.int32), payload,
             np.full((B, 1), 2, np.int32), payload], axis=1)[:, :S]
        tokens = seq[:, :-1]
        labels = seq[:, 1:]
        mask = np.zeros_like(labels, np.float32)
        mask[:, half - 1:] = 1.0
        tokens = np.pad(tokens, ((0, 0), (0, S - tokens.shape[1])))
        labels = np.pad(labels, ((0, 0), (0, S - labels.shape[1])))
        mask = np.pad(mask, ((0, 0), (0, S - mask.shape[1])))
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}

    # lm_synthetic: zipf unigrams with injected bigram structure
    toks = _zipf_tokens(rng, (B, S + 1), cfg.vocab_size, dc.zipf_a)
    # bigram structure: with p=0.5, next token = (tok*7+3) % vocab
    follow = (toks[:, :-1] * 7 + 3) % cfg.vocab_size
    coin = rng.random((B, S)) < 0.5
    toks[:, 1:] = np.where(coin, follow, toks[:, 1:])
    batch = {"tokens": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32),
             "loss_mask": np.ones((B, S), np.float32)}

    if cfg.family == "vlm":
        s_img = S // 4
        batch["tokens"] = batch["tokens"][:, : S - s_img]
        batch["patch_embeds"] = rng.normal(
            0, 0.02, (B, s_img, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        half = S // 2
        batch = {"tokens": batch["tokens"][:, :half],
                 "labels": batch["labels"][:, :half],
                 "loss_mask": batch["loss_mask"][:, :half],
                 "enc_embeds": rng.normal(
                     0, 0.02, (B, half, cfg.d_model)).astype(np.float32)}
    return batch


def batch_iterator(cfg: ModelConfig, shape: ShapeConfig,
                   dc: Optional[DataConfig] = None, start_step: int = 0,
                   shard: int = 0, num_shards: int = 1
                   ) -> Iterator[Dict[str, np.ndarray]]:
    dc = dc or DataConfig()
    step = start_step
    while True:
        yield make_batch(cfg, shape, dc, step, shard, num_shards)
        step += 1
