"""Online predictor refresh: fold profiled arrivals back into the model.

The MoE predictor is trained offline on 16 programs; an open arrival
stream keeps surfacing workloads the selector has never seen (KNN
distance beyond the confidence threshold -> conservative scheduling,
half-sized executors). But every such arrival *is profiled anyway* —
the feature probe plus the 5%/10% calibration runs trace out a small
memory curve. :class:`OnlineRefresher` turns that by-product into
training signal: when the curve is cleanly explained by one expert
family, the (features, family) pair is appended to the KNN selector via
:meth:`repro.core.predictor.MoEPredictor.partial_update` — no PCA refit,
no re-profiling of the original training programs.

The refresher only folds in arrivals the selector was NOT confident
about (confident ones add no information and would bloat the KNN table)
and only when the best family fit is unambiguous, so a noisy probe
cannot poison the selector.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import experts


@dataclass
class OnlineRefresher:
    """Streams (features, probe curve) observations into an estimator.

    ``predictor`` is duck-typed against the
    :class:`~repro.sched.estimator.DemandEstimator` protocol surface
    (``families``, ``select_family``, ``partial_update``) — pass the
    registry handle (e.g. ``get_estimator("moe", predictor=moe)``)
    rather than reaching into ``MoEPredictor`` internals; a bare fitted
    ``MoEPredictor`` still works.  Estimators that do not learn online
    return ``False`` from ``partial_update`` and the offer is counted
    as rejected."""
    predictor: object                  # DemandEstimator / MoEPredictor
    max_error: float = 0.05            # accept only clean family fits
    ambiguity_ratio: float = 2.0       # runner-up must be this much worse
    min_probes: int = 3
    only_unconfident: bool = True
    max_updates: int = 256             # bound the KNN table growth
    accepted: int = 0
    rejected: int = 0
    table_full: int = 0                # offers dropped after max_updates
    history: list = field(default_factory=list)

    def observe(self, features: np.ndarray, xs: Sequence[float],
                ys: Sequence[float],
                confident: Optional[bool] = None) -> Optional[str]:
        """Offer one profiled arrival. Returns the family folded in, or
        None when the observation was rejected (already confident,
        ambiguous fit, or table full).

        Callers that already ran the selector (the scheduler computes
        confidence for every prediction anyway) pass ``confident`` to
        skip a duplicate KNN query on the per-job hot path."""
        if self.accepted >= self.max_updates:
            self.table_full += 1
            return None
        xs = np.asarray(xs, float)
        ys = np.asarray(ys, float)
        if len(xs) < self.min_probes:
            self.rejected += 1
            return None
        features = np.asarray(features, float)
        if self.only_unconfident:
            if confident is None:
                sel = getattr(self.predictor, "select_family", None)
                # estimators without a selector have no confidence
                # signal — treat the arrival as unconfident (offer it)
                confident = sel(features)[2] if sel is not None else False
            if confident:
                self.rejected += 1
                return None
        fn, errs = experts.best_family(xs, ys, self.predictor.families)
        if errs[fn.family] > self.max_error:
            self.rejected += 1
            return None
        # unambiguous means the winner BEATS the field, not merely fits:
        # on a flat probe curve every family fits within tolerance and
        # the argmin is noise — folding that in would permanently label
        # the cluster with an arbitrary family
        others = [e for fam, e in errs.items() if fam != fn.family]
        if others and min(others) < max(
                errs[fn.family] * self.ambiguity_ratio, 1e-3):
            self.rejected += 1
            return None
        # the predictor may still drop the row as a near-duplicate of an
        # existing same-family row (table hygiene) — count that as a
        # rejection, not a fold
        if self.predictor.partial_update(features, fn.family) is False:
            self.rejected += 1
            return None
        self.accepted += 1
        self.history.append(fn.family)
        return fn.family

    def stats(self) -> Dict[str, int]:
        return {"accepted": self.accepted, "rejected": self.rejected,
                "table_full": self.table_full}
