"""Event-driven ClusterRuntime: ONE node/route/clock substrate under the
batch simulator and the serving engine.

Before this module the paper's online decision loop — admit work against
per-node headroom, advance a virtual clock, react to completions — was
implemented twice: once as the wave-advance heap in
``core/simulator.py::Simulator.run`` (batch jobs over ``Host``s) and
once as the step loop in ``serve/engine.py::Engine`` (requests over a
single implicit replica).  Every cluster-level follow-on (multi-replica
routing over the ``net`` axis, SLO-goodput, axis-shaded budgets) would
have had to be built twice.  This module factors the shared substrate
out, in the event-driven replay style of the related schedulers
(Firmament's ``ReplaySimulation()``):

* :class:`EventLoop`   — a virtual-clock event heap (arrival /
  completion / step / refresh events, FIFO-stable within a timestamp);
  no fixed-quantum wave advance — time moves exactly to the next event.
* :class:`Node`        — booked per-axis capacity accounting for one
  schedulable node (a simulator ``Host`` or a serving replica): a
  :class:`~repro.sched.resources.ResourceVector` capacity, a keyed
  ledger of booked claim vectors, headroom queries, and the binding-axis
  decision counters that used to live on the consumers.
* :class:`ClusterState` — N nodes with cluster-wide headroom /
  binding-axis aggregation.
* a ``Router`` registry mirroring ``sched/placement.py``
  (``register_router`` / ``get_router`` / ``available_routers``):
  ``single``, ``least-loaded``, ``net-aware`` — routes each admitted
  job/request to a node using the *estimator's multi-axis demand
  vector* against per-node headroom (the ``net-aware`` router is what
  makes multi-replica serving routing over the ``net`` axis real).
* :class:`ClusterRuntime` — ties them together: push events, register
  handlers per event kind, ``run()`` the clock, ``route()`` demands.

Consumers: ``core/simulator.py`` registers its arrive/profiled/finish/
fail handlers on a runtime and ``Simulator.run`` is now a thin shim
(results pinned bit-identical by ``tests/test_cluster.py`` goldens);
``serve/engine.py`` runs continuous batching as ``step`` events over
1..N replica Nodes (``launch/serve.py --replicas N --router
net-aware``).

Like ``placement``/``resources``, this module imports nothing from
``repro.core`` — it is import-cycle-free and loadable first.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Type, Union)

from repro.obs.telemetry import Telemetry
from repro.sched.resources import ResourceVector

_EPS = 1e-12


# ---------------------------------------------------------------------------
# EventLoop
# ---------------------------------------------------------------------------

class EventLoop:
    """Virtual-clock event heap.

    Events are ``(t, seq, kind, payload)`` tuples ordered by time with a
    monotone sequence number breaking ties, so two events at the same
    timestamp dispatch in push order (FIFO) and payloads are never
    compared — exactly the discipline the simulator's inline heap used,
    which is what keeps the legacy goldens bit-identical.

    The loop does not advance ``t`` itself: whoever drains it (normally
    :meth:`ClusterRuntime.run`) sets the clock, because policies differ
    on whether an over-horizon event moves time before the run stops.
    """

    __slots__ = ("_heap", "_seq", "t")

    def __init__(self):
        self._heap: List[Tuple] = []
        self._seq = itertools.count()
        self.t = 0.0

    def push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def pop(self) -> Tuple[float, int, str, object]:
        return heapq.heappop(self._heap)

    def peek_t(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# ---------------------------------------------------------------------------
# Node / ClusterState
# ---------------------------------------------------------------------------

class Node:
    """Booked per-axis capacity accounting for one schedulable node.

    A node is where admitted work lands: a simulator host's executor
    claims or a serving replica's in-flight request footprints.  Claims
    are keyed (executor id, request id, ...) so release/rebook are exact,
    and the headroom computation mirrors the pre-refactor
    ``Host.free_vector`` float-for-float: per axis, sum the claims in
    insertion order and subtract from capacity.
    """

    __slots__ = ("nid", "capacity", "_claims", "binding_axes", "up")

    def __init__(self, nid: int, capacity: ResourceVector):
        self.nid = int(nid)
        self.capacity = capacity
        self._claims: Dict[object, ResourceVector] = {}
        #: axis -> count of admission decisions it bound on this node
        self.binding_axes: Dict[str, int] = {}
        self.up = True

    # --- the claim ledger -------------------------------------------------
    def book(self, key, vec: ResourceVector) -> None:
        if key in self._claims:
            raise KeyError(f"claim {key!r} already booked on node "
                           f"{self.nid} — use rebook()")
        self._claims[key] = vec

    def rebook(self, key, vec: ResourceVector) -> None:
        """Replace a live claim (a serving request's KV grows every
        step) without changing its ledger position."""
        if key not in self._claims:
            raise KeyError(f"claim {key!r} not booked on node {self.nid}")
        self._claims[key] = vec

    def release(self, key) -> ResourceVector:
        return self._claims.pop(key)

    def claim(self, key) -> Optional[ResourceVector]:
        return self._claims.get(key)

    def keys(self) -> List[object]:
        """Live claim keys, in booking order (a snapshot — safe to
        release() while iterating it)."""
        return list(self._claims)

    def __contains__(self, key) -> bool:
        return key in self._claims

    @property
    def n_claims(self) -> int:
        return len(self._claims)

    # --- queries ----------------------------------------------------------
    @property
    def booked(self) -> ResourceVector:
        """Total booked demand over every axis any claim carries."""
        total = ResourceVector()
        for v in self._claims.values():
            total = total + v
        return total

    def headroom(self) -> ResourceVector:
        """Unbooked capacity per capacity axis.  Bit-identical to the
        legacy ``Host.free_vector``: per-axis sums over claims in
        insertion order (missing axes contribute 0.0)."""
        used = {a: sum(v.get(a, 0.0) for v in self._claims.values())
                for a in self.capacity.axes}
        return self.capacity.headroom(ResourceVector(**used))

    def utilization(self, axis: str) -> float:
        """Booked fraction of ``axis`` (0.0 when the axis is not
        capacitated — an unconstrained axis is never 'loaded')."""
        cap = self.capacity.get(axis, 0.0)
        if cap <= _EPS:
            return 0.0
        return sum(v.get(axis, 0.0)
                   for v in self._claims.values()) / cap

    def record_binding(self, axis: str) -> None:
        self.binding_axes[axis] = self.binding_axes.get(axis, 0) + 1

    def __repr__(self) -> str:
        return (f"Node({self.nid}, claims={len(self._claims)}, "
                f"capacity={self.capacity!r})")


class ClusterState:
    """N nodes with cluster-wide aggregation queries."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes = list(nodes)

    @classmethod
    def homogeneous(cls, n: int, capacity: ResourceVector
                    ) -> "ClusterState":
        return cls([Node(i, capacity) for i in range(n)])

    def __iter__(self) -> Iterator[Node]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def __getitem__(self, i: int) -> Node:
        return self.nodes[i]

    def up_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.up]

    def headroom(self) -> List[ResourceVector]:
        return [n.headroom() for n in self.nodes]

    def binding_axes(self) -> Dict[str, int]:
        """Cluster-wide binding-axis histogram (sum over nodes)."""
        out: Dict[str, int] = {}
        for n in self.nodes:
            for a, c in n.binding_axes.items():
                out[a] = out.get(a, 0) + c
        return out


# ---------------------------------------------------------------------------
# Router registry (mirrors repro.sched.placement)
# ---------------------------------------------------------------------------

class Router:
    """Routing protocol: pick the node an admitted unit of work lands
    on, given its predicted multi-axis demand vector.  Subclass +
    ``@register_router(name)``.

    ``route`` must be a *pure deterministic choice* (no RNG, no
    mutation): it sees per-node headroom and the demand and returns one
    of the nodes — ties must resolve to the lowest node id so seeded
    runs stay reproducible.  Admission (does it actually fit?) stays
    with the consumer's controller; a router only says *where to try*.
    """

    name = "base"
    #: routers that score on link state set this True; the runtime then
    #: binds its Topology (if any) onto ``self.topology`` before routing
    uses_topology = False
    topology = None
    #: routers that score per-tenant fair shares set this True; the
    #: runtime then binds its TenantRegistry (if any) onto
    #: ``self.tenancy`` and the requesting tenant onto ``self.tenant``
    #: before routing (see repro.sched.tenancy)
    uses_tenancy = False
    tenancy = None
    tenant = None

    def route(self, demand: Optional[ResourceVector],
              nodes: Sequence[Node], now: float = 0.0) -> Node:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[Router]] = {}


def register_router(name: str):
    """Class decorator adding a router to the registry under ``name``."""
    def deco(cls: Type[Router]) -> Type[Router]:
        if not issubclass(cls, Router):
            raise TypeError(f"{cls!r} is not a Router")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_router(name: str) -> Router:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown router {name!r} "
                       f"(available: {available_routers()})") from None


def available_routers() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def _fit_score(node: Node, demand: Optional[ResourceVector]) -> float:
    """How comfortably ``demand`` fits ``node``: the min over demanded,
    capacitated axes of the headroom fraction (worst-axis view, so a
    node choked on ANY needed axis scores low).  With no overlapping
    axes (or no demand) the same worst-axis view runs over ALL
    capacitated axes instead."""
    head = node.headroom()
    fracs = []
    axes = (demand.axes if demand is not None else ())
    for a in axes:
        cap = node.capacity.get(a, 0.0)
        if cap > _EPS:
            fracs.append(head.get(a, 0.0) / cap)
    if not fracs:
        fracs = [head.get(a, 0.0) / node.capacity[a]
                 for a in node.capacity.axes
                 if node.capacity[a] > _EPS] or [0.0]
    return min(fracs)


@register_router("single")
class SingleRouter(Router):
    """Everything lands on the first up node — the implicit pre-runtime
    behaviour of the one-replica serving engine, kept as the routing
    baseline the multi-replica sweeps compare against."""

    def route(self, demand, nodes, now=0.0):
        for n in nodes:
            if n.up:
                return n
        return nodes[0]


@register_router("least-loaded")
class LeastLoadedRouter(Router):
    """Best worst-axis headroom fraction for THIS demand vector (stable
    argmax: ties go to the lowest node id)."""

    def route(self, demand, nodes, now=0.0):
        cands = [n for n in nodes if n.up] or list(nodes)
        return max(cands, key=lambda n: (_fit_score(n, demand), -n.nid))


@register_router("net-aware")
class NetAwareRouter(Router):
    """Route on the ``net`` axis first: the node with the most free
    egress/interconnect bandwidth fraction wins; the generic fit score
    breaks ties and covers clusters that do not budget ``net`` at all
    (where this router degrades to ``least-loaded``).

    DEPRECATED-but-pinned: this is the per-node-counter view of the
    network — it cannot see shared links.  New topology-bound clusters
    should use ``topo-aware`` (``repro.sched.topology``), which scores
    by bottleneck-link residual bandwidth along the actual route; this
    shim stays byte-identical, golden-pinned."""

    def route(self, demand, nodes, now=0.0):
        cands = [n for n in nodes if n.up] or list(nodes)

        def key(n: Node):
            cap = n.capacity.get("net", 0.0)
            net = n.headroom().get("net", 0.0) / cap if cap > _EPS \
                else -1.0
            return (net, _fit_score(n, demand), -n.nid)
        return max(cands, key=key)


# ---------------------------------------------------------------------------
# ClusterRuntime
# ---------------------------------------------------------------------------

class ClusterRuntime:
    """The event-driven substrate: a virtual clock over cluster state.

    Consumers register one handler per event kind (``on``), push timed
    events, and ``run()`` the loop; the runtime owns the clock and the
    node ledger, and ``route()`` asks the configured router where a
    demand vector should land.  The runtime is deliberately free of
    workload semantics — jobs, requests, profiling, preemption all live
    in the consumers' handlers — which is what lets ONE loop serve both
    the batch simulator and the serving engine.
    """

    def __init__(self, cluster: ClusterState,
                 router: Union[str, Router, None] = None,
                 topology=None, tracer=None,
                 telemetry: Optional[Telemetry] = None,
                 tenancy=None):
        self.loop = EventLoop()
        self.cluster = cluster
        self.router = get_router(router) if isinstance(router, str) \
            else router
        self._handlers: Dict[str, Callable[[float, object], None]] = {}
        #: optional repro.obs.trace.Tracer — None (the default) means
        #: no trace is collected and dispatch pays only a None check,
        #: so untraced runs stay bit-identical to the pre-obs runtime
        self.tracer = tracer
        #: always-on counter/gauge registry: deterministic per-kind
        #: event counts live in counters, wall-clock rates ONLY in
        #: gauges (never surfaced in seed-pinned summaries)
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry()
        #: optional repro.sched.topology.Topology; when set, its
        #: transmission events run on this loop and topology-aware
        #: routers see it (default None keeps every schedule identical)
        self.topology = None
        if topology is not None:
            self.topology = topology.attach(self)
        #: optional repro.sched.tenancy.TenantRegistry; tenancy-aware
        #: routers (``uses_tenancy``) see it at route time, exactly the
        #: late-binding pattern topology uses (default None keeps every
        #: schedule identical — drf degrades to least-loaded)
        self.tenancy = tenancy

    # --- clock / events ---------------------------------------------------
    @property
    def t(self) -> float:
        return self.loop.t

    def push(self, t: float, kind: str, payload=None) -> None:
        self.loop.push(t, kind, payload)

    def on(self, kind: str,
           handler: Callable[[float, object], None]) -> None:
        """Register ``handler(t, payload)`` for event ``kind`` (one per
        kind; re-registering replaces).  A handler may return ``False``
        to mark the event stale (an executor already gone, a re-timed
        completion superseded): stale events advance the clock but skip
        the post-event ``tick``/``until`` hooks, exactly like the
        legacy loops' ``continue``."""
        self._handlers[kind] = handler

    # --- routing ----------------------------------------------------------
    def route(self, demand: Optional[ResourceVector] = None,
              now: Optional[float] = None,
              tenant: Optional[str] = None) -> Node:
        if self.router is None:
            raise RuntimeError("this ClusterRuntime has no router — "
                               "construct it with router=<name or "
                               "Router instance>")
        if getattr(self.router, "uses_topology", False):
            self.router.topology = self.topology
        if getattr(self.router, "uses_tenancy", False):
            self.router.tenancy = self.tenancy
            self.router.tenant = tenant
        return self.router.route(demand, self.cluster.nodes,
                                 now=self.t if now is None else now)

    # --- the loop ---------------------------------------------------------
    def run(self, *, max_time: float = float("inf"),
            until: Optional[Callable[[], bool]] = None,
            tick: Optional[Callable[[float], None]] = None) -> float:
        """Drain events in time order until the heap empties, an event
        lands past ``max_time`` (the clock does NOT advance to it —
        legacy horizon semantics), or ``until()`` returns True after an
        event.  ``tick(t)`` runs after every dispatched event (trace
        collection).  Returns the final clock.

        Every dispatched event counts into ``telemetry.counters``
        (``events.<kind>``, ``events.stale.<kind>``) and, with a tracer
        bound, emits one zero-duration slice per event kind on the
        ``runtime`` track — the span-per-event-kind view of the loop.
        Wall-clock throughput (events/sec of REAL time) lands only in
        ``telemetry.gauges`` so it can never leak into seed-pinned
        summaries."""
        tracer, tm = self.tracer, self.telemetry
        dispatched = 0
        wall0 = time.perf_counter()
        while self.loop:
            t, _, kind, payload = self.loop.pop()
            if t > max_time:
                break
            self.loop.t = t
            try:
                handler = self._handlers[kind]
            except KeyError:
                raise KeyError(f"no handler registered for event kind "
                               f"{kind!r}") from None
            tm.inc(f"events.{kind}")
            dispatched += 1
            if handler(t, payload) is False:
                tm.inc(f"events.stale.{kind}")
                if tracer is not None:
                    tracer.instant(f"stale:{kind}", t,
                                   process="runtime", thread=kind)
                continue                       # stale event (see on())
            if tracer is not None:
                tracer.complete(f"event:{kind}", t, t,
                                process="runtime", thread=kind)
            if tick is not None:
                tick(t)
            if until is not None and until():
                break
        wall = time.perf_counter() - wall0
        tm.inc("events.dispatched", dispatched)
        tm.set_gauge("wall_s", tm.gauges.get("wall_s", 0.0) + wall)
        if wall > 0.0:
            tm.set_gauge("events_per_s_wall", dispatched / wall)
        return self.loop.t
