"""Vector-resource budgets for admission control.

The paper's co-location scheme reasons about memory *and* CPU jointly
(Sections 2.2/6.8), and the TPU-fleet adaptation adds device HBM and
interconnect on top of host RAM. This module gives the scheduler a small
algebra over named resource axes so admission can invert the demand
curve along the *binding* axis (the axis whose budget runs out first)
instead of treating memory as the only first-class resource:

* :class:`ResourceVector` — an immutable point in resource space over
  the named axes ``host_ram`` / ``cpu`` / ``hbm`` / ``net``, with
  ``+``/``-``/scalar ``*`` algebra, ``fits`` (componentwise admission
  test) and ``headroom`` (remaining capacity).  Axis *presence* is
  meaningful: an axis absent from a budget vector is unconstrained,
  an axis absent from a demand vector demands nothing.
* :class:`DemandModel` — per-axis demand as a function of admitted work
  units: monotone curves (the calibrated
  :class:`~repro.core.experts.MemoryFunction` for memory-like axes) plus
  per-placement constants (an executor's average CPU load does not scale
  with its input split).  ``inverse(budget)`` returns the largest unit
  count that fits every budgeted axis and *which axis bound it*.

Curves are duck-typed (``fn(x) -> amount``, ``fn.inverse(amount) -> x``)
so this module has no import-time dependency on ``repro.core`` — it can
be loaded first without creating an import cycle.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Iterator, Mapping, Optional, Tuple

import numpy as np

if TYPE_CHECKING:  # duck-typed at runtime (anything callable w/ .inverse)
    from repro.core.experts import MemoryFunction

#: The recognised resource axes.  ``host_ram`` is the paper's budget
#: (executor heap vs free host memory); ``cpu`` is the co-location slack
#: check of Section 6.8; ``hbm``/``net`` are the TPU-fleet extensions
#: (device memory, interconnect bandwidth).
AXES = ("host_ram", "cpu", "hbm", "net")

#: Axes shaded by the scheduler's memory-risk rules (safety margin,
#: conservative fallback, OOM backoff).  CPU slack and link bandwidth
#: are average-rate resources — transient overshoot time-shares instead
#: of OOM-killing — so they are offered unshaded.
MEMORY_AXES = ("host_ram", "hbm")


class ResourceVector:
    """An immutable, sparse point in resource space.

    Only the axes passed to the constructor are *present*; algebra
    treats absent axes as zero, while :meth:`fits` treats axes absent
    from the **budget** as unconstrained.
    """

    __slots__ = ("_v",)

    def __init__(self, **axes: float):
        for a in axes:
            if a not in AXES:
                raise ValueError(
                    f"unknown resource axis {a!r} (known: {AXES})")
        object.__setattr__(self, "_v",
                           {a: float(v) for a, v in axes.items()})

    def __setattr__(self, *a):  # immutability guard
        raise AttributeError("ResourceVector is immutable")

    # --- mapping-ish access ---------------------------------------------
    @property
    def axes(self) -> Tuple[str, ...]:
        return tuple(self._v)

    def get(self, axis: str, default: float = 0.0) -> float:
        return self._v.get(axis, default)

    def __getitem__(self, axis: str) -> float:
        return self._v[axis]

    def __contains__(self, axis: str) -> bool:
        return axis in self._v

    def __iter__(self) -> Iterator[str]:
        return iter(self._v)

    def items(self):
        return self._v.items()

    def as_dict(self) -> Dict[str, float]:
        return dict(self._v)

    # --- algebra ---------------------------------------------------------
    def _merge(self, other: "ResourceVector", sign: float
               ) -> "ResourceVector":
        axes = dict(self._v)
        for a, v in other._v.items():
            axes[a] = axes.get(a, 0.0) + sign * v
        return ResourceVector(**axes)

    def __add__(self, other: "ResourceVector") -> "ResourceVector":
        return self._merge(other, 1.0)

    def __sub__(self, other: "ResourceVector") -> "ResourceVector":
        return self._merge(other, -1.0)

    def __mul__(self, k: float) -> "ResourceVector":
        return ResourceVector(**{a: v * float(k)
                                 for a, v in self._v.items()})

    __rmul__ = __mul__

    def __eq__(self, other) -> bool:
        return isinstance(other, ResourceVector) and self._v == other._v

    def __hash__(self):
        return hash(tuple(sorted(self._v.items())))

    def __repr__(self) -> str:
        inner = ", ".join(f"{a}={v:g}" for a, v in self._v.items())
        return f"ResourceVector({inner})"

    # --- admission tests --------------------------------------------------
    def fits(self, budget: "ResourceVector", eps: float = 1e-9) -> bool:
        """Componentwise ``demand <= budget``.  Axes the budget does not
        carry are unconstrained; axes this vector does not carry demand
        nothing."""
        return all(v <= budget._v[a] + eps
                   for a, v in self._v.items() if a in budget._v)

    def headroom(self, used: "ResourceVector") -> "ResourceVector":
        """Remaining capacity per *budgeted* axis (may be negative when
        over-committed).  Axes ``used`` carries but this vector does not
        are ignored — they were never constrained."""
        return ResourceVector(**{a: v - used._v.get(a, 0.0)
                                 for a, v in self._v.items()})


def single_axis(axis: str, value: float) -> ResourceVector:
    """The scalar shim's budget: one constrained axis, all others free."""
    return ResourceVector(**{axis: value})


class DemandModel:
    """Per-axis demand as a function of admitted work units.

    ``curves`` maps axes to monotone unit->amount functions (the
    calibrated memory function on the *primary* axis, plus optional
    side-car curves, e.g. host staging RAM for an HBM-resident job);
    ``fixed`` maps axes to per-placement constants that do not scale
    with the unit count (an executor's average CPU load).
    """

    __slots__ = ("primary_axis", "curves", "fixed")

    def __init__(self, curves: Mapping[str, "MemoryFunction"],
                 fixed: Optional[Mapping[str, float]] = None,
                 primary_axis: str = "host_ram"):
        for a in curves:
            if a not in AXES:
                raise ValueError(f"unknown demand axis {a!r}")
        for a in (fixed or {}):
            if a not in AXES:
                raise ValueError(f"unknown demand axis {a!r}")
        # primary first so inverse() tie-breaks toward the primary axis
        ordered = {}
        if primary_axis in curves:
            ordered[primary_axis] = curves[primary_axis]
        ordered.update(curves)
        self.curves = ordered
        self.fixed = {a: float(v) for a, v in (fixed or {}).items()}
        self.primary_axis = primary_axis

    @classmethod
    def scalar(cls, fn: "MemoryFunction", axis: str = "host_ram",
               cpu_load: Optional[float] = None) -> "DemandModel":
        """The back-compat shim: one calibrated curve on one axis (plus
        an optional fixed CPU load)."""
        fixed = {} if cpu_load is None else {"cpu": cpu_load}
        return cls({axis: fn}, fixed, primary_axis=axis)

    @classmethod
    def from_model_config(cls, cfg, max_len: int, *,
                          host_ram_per_req_gb: float = 0.0,
                          refit: bool = False) -> "DemandModel":
        """DEPRECATED shim over the ``kv-growth`` estimator (which now
        owns the per-``(config, max_len)`` calibration cache) — kept
        bit-identical for existing callers.  Prefer::

            get_estimator("kv-growth").estimate(
                ModelTarget(cfg, max_len, ...)).model
        """
        import warnings
        warnings.warn(
            "DemandModel.from_model_config is deprecated; use "
            "repro.sched.estimator.get_estimator('kv-growth')"
            ".estimate(ModelTarget(cfg, max_len, ...)) instead",
            DeprecationWarning, stacklevel=2)
        # runtime-only import: this module must stay loadable before
        # repro.core (see module docstring)
        from repro.sched.estimator import KVGrowthEstimator, ModelTarget
        est = KVGrowthEstimator(refit=refit)
        target = ModelTarget(cfg, int(max_len),
                             host_ram_per_req_gb=host_ram_per_req_gb)
        return est.estimate(target).model

    @property
    def primary_fn(self) -> Optional["MemoryFunction"]:
        return self.curves.get(self.primary_axis)

    def demand(self, units: float) -> ResourceVector:
        """Total per-axis demand of a placement processing ``units``."""
        axes: Dict[str, float] = {a: float(fn(units))
                                  for a, fn in self.curves.items()}
        for a, v in self.fixed.items():
            axes[a] = axes.get(a, 0.0) + v
        return ResourceVector(**axes)

    def inverse(self, budget: ResourceVector
                ) -> Tuple[float, Optional[str]]:
        """Largest ``units`` whose demand fits ``budget``, and the axis
        that bound it (min over per-axis curve inverses).

        Fixed demands gate: if a fixed demand exceeds its budgeted axis,
        nothing fits (0 units, that axis binding).  Curve axes the
        budget does not carry are unconstrained.  Returns ``inf`` with
        ``None`` binding when no budgeted axis constrains the demand.
        """
        for a, v in self.fixed.items():
            if a in budget and v > budget[a]:
                return 0.0, a
        units, binding = np.inf, None
        for a, fn in self.curves.items():
            if a not in budget:
                continue
            # fixed overhead sharing an axis with a curve shrinks the
            # curve's budget on that axis
            x = float(fn.inverse(budget[a] - self.fixed.get(a, 0.0)))
            if x < units:
                units, binding = x, a
        return units, binding
