"""Elastic runtime: spill-aware shrink admission, deterministic failure
injection, and replica autoscaling on the shared ClusterRuntime.

Admission so far has been *binary*: a job (or a join candidate) whose
demand vector does not fit the budget waits.  "Don't cry over spilled
records" (PAPERS.md) shows data-parallel tasks can run with LESS memory
than their working set at a *modeled* slowdown — spilled records are
re-read from disk, costing time instead of correctness — and "A
Workload-Specific Memory Capacity Configuration Approach" shows that
demand/performance trade-off is learnable per workload.  This module
makes the runtime elastic along exactly that axis, plus the two failure
modes the substrate already half-supports:

* :class:`SlowdownCurve` — the learnable trade-off: monotone
  ``fraction of demanded memory -> execution-time multiplier`` points.
  :func:`fit_slowdown_curve` derives one from a calibrated memory
  curve (the in-memory share of a shrunken grant follows the curve's
  inverse; the spilled share pays the disk re-read factor), so convex
  and concave working sets shrink differently — the workload-specific
  part.  The **conservative fallback is the flat curve** ("not
  shrinkable"): an estimate the scheduler does not trust never
  volunteers for a memory cut.
* :class:`ElasticController` — the shrink-vs-wait-vs-reject policy:
  given the largest demand fraction that fits the free budget, it
  shrinks iff the curve prices that fraction under ``max_slowdown``
  (and above ``min_fraction``), waits when the price is too high, and
  rejects only when nothing is free at all.  Consumers charge the
  decision's slowdown into *virtual time* — executor rate in the batch
  simulator, decode-step cost in the serving engine — so a shrunken
  grant is never a free lunch.
* :class:`FailureSchedule` — deterministic, seeded fail/repair
  injection for hosts AND serving replica ``Node``s.  The schedule is
  drawn once at construction from its own RNG (consumer RNG streams
  are untouched — flags-off runs stay bit-identical) and rides the
  shared :class:`~repro.sched.cluster.EventLoop` under its own event
  kinds (``efail``/``erepair``), so it composes with the simulator's
  legacy Poisson ``fail`` events instead of colliding with them.
* :class:`Autoscaler` — spawn/drain replica ``Node``s from *sustained*
  queue-depth and SLO-attainment trends (the signals ``node_steps``
  and the metrics windows already expose), with
  :func:`pick_spawn_node` preferring the rack whose uplink has the
  most residual fair-share headroom when a topology is bound.

Like the rest of ``repro.sched``'s substrate modules, this file imports
nothing from ``repro.core`` or ``repro.serve`` — it is import-cycle
free, so the estimator registry can attach shrink curves to every
:class:`~repro.sched.estimator.DemandEstimate` without a cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.sched.resources import MEMORY_AXES, ResourceVector

_EPS = 1e-12


# ---------------------------------------------------------------------------
# SlowdownCurve: the demand-vs-slowdown trade-off
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SlowdownCurve:
    """Monotone map from the *fraction of demanded memory actually
    granted* to the modeled execution-time multiplier.

    ``points`` are ``(fraction, slowdown)`` pairs sorted by ascending
    fraction with ``slowdown`` non-increasing in ``fraction`` and the
    full grant free (``slowdown_at(1.0) == 1.0``).  A curve whose only
    point is ``(1.0, 1.0)`` is **flat** — "not shrinkable" — which is
    the conservative fallback: estimates the scheduler does not trust
    never volunteer for a memory cut."""

    points: Tuple[Tuple[float, float], ...] = ((1.0, 1.0),)

    def __post_init__(self):
        pts = tuple(sorted((float(f), float(s)) for f, s in self.points))
        if not pts:
            pts = ((1.0, 1.0),)
        for f, s in pts:
            if not 0.0 < f <= 1.0:
                raise ValueError(f"shrink fraction must be in (0, 1], "
                                 f"got {f}")
            if s < 1.0 - 1e-9:
                raise ValueError(f"slowdown must be >= 1, got {s} "
                                 f"at fraction {f}")
        object.__setattr__(self, "points", pts)

    @classmethod
    def flat(cls) -> "SlowdownCurve":
        """The not-shrinkable curve (conservative fallback)."""
        return cls(((1.0, 1.0),))

    @classmethod
    def linear(cls, max_slowdown: float, min_fraction: float = 0.5,
               n: int = 5) -> "SlowdownCurve":
        """Linear price: full grant free, ``min_fraction`` costs
        ``max_slowdown``, interpolated between — the declared-constant
        fallback for targets with no calibrated curve to derive from."""
        if not 0.0 < min_fraction < 1.0:
            raise ValueError(f"min_fraction must be in (0, 1), "
                             f"got {min_fraction}")
        fs = np.linspace(min_fraction, 1.0, max(int(n), 2))
        span = 1.0 - min_fraction
        return cls(tuple(
            (float(f),
             1.0 + (float(max_slowdown) - 1.0) * (1.0 - float(f)) / span)
            for f in fs))

    @property
    def min_fraction(self) -> float:
        """Smallest grant fraction the curve prices at all."""
        return self.points[0][0]

    @property
    def shrinkable(self) -> bool:
        """Whether the curve prices ANY fraction below the full grant."""
        return self.min_fraction < 1.0 - 1e-9

    def slowdown_at(self, fraction: float) -> float:
        """Modeled time multiplier of running on ``fraction`` of the
        demanded memory: piecewise-linear between the curve's points,
        ``inf`` below the smallest priced fraction (can't shrink that
        far), exactly 1.0 at or above the full grant."""
        f = float(fraction)
        if f >= 1.0 - 1e-12:
            return 1.0
        if f < self.min_fraction - 1e-12:
            return float("inf")
        xs = np.asarray([p[0] for p in self.points])
        ys = np.asarray([p[1] for p in self.points])
        return float(np.interp(f, xs, ys))


def fit_slowdown_curve(fn, units: float, *,
                       spill_cost: float = 3.0,
                       fractions: Sequence[float] = (0.25, 0.375, 0.5,
                                                     0.625, 0.75,
                                                     0.875, 1.0)
                       ) -> SlowdownCurve:
    """Derive the demand-vs-slowdown curve from a calibrated memory
    function: a grant of ``f * fn(units)`` keeps the working set of
    ``fn.inverse(f * fn(units))`` items in memory and spills the rest,
    each spilled item paying the disk re-read factor ``spill_cost``::

        slowdown(f) = (in_mem + spill_cost * (units - in_mem)) / units

    The curve's *shape* carries the workload: a concave (power-family)
    working set keeps most items in memory under a deep cut (cheap to
    shrink), a convex one loses them fast (expensive) — the
    workload-specific memory-capacity trade-off, learned from the same
    two-probe calibration the admission inverse already runs on.
    Degenerate curves (no inverse, non-positive demand) fall back to
    the flat not-shrinkable curve."""
    units = float(units)
    inverse = getattr(fn, "inverse", None)
    if units <= 0.0 or not callable(inverse):
        return SlowdownCurve.flat()
    try:
        full = float(fn(units))
    except (ValueError, OverflowError, FloatingPointError):
        return SlowdownCurve.flat()
    if not np.isfinite(full) or full <= 0.0:
        return SlowdownCurve.flat()
    pts: List[Tuple[float, float]] = []
    for f in sorted(set(float(x) for x in fractions)):
        if not 0.0 < f <= 1.0:
            continue
        if f >= 1.0 - 1e-12:
            pts.append((1.0, 1.0))
            continue
        try:
            in_mem = float(inverse(f * full))
        except (ValueError, OverflowError, FloatingPointError):
            return SlowdownCurve.flat()
        if not np.isfinite(in_mem):
            return SlowdownCurve.flat()
        in_mem = min(max(in_mem, 0.0), units)
        s = (in_mem + float(spill_cost) * (units - in_mem)) / units
        pts.append((f, max(s, 1.0)))
    if not pts:
        return SlowdownCurve.flat()
    if pts[-1][0] < 1.0 - 1e-12:
        pts.append((1.0, 1.0))
    return SlowdownCurve(tuple(pts))


# ---------------------------------------------------------------------------
# ElasticController: shrink vs wait vs reject
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticDecision:
    """One shrink-vs-wait-vs-reject verdict."""
    action: str                     # "shrink" | "wait" | "reject"
    fraction: float = 1.0           # granted fraction of demanded memory
    slowdown: float = 1.0           # modeled time multiplier charged

    def __bool__(self) -> bool:
        return self.action == "shrink"


class ElasticController:
    """The shrink policy: given the largest demand fraction that fits
    the free budget and the workload's :class:`SlowdownCurve`, decide
    whether running smaller-but-slower beats waiting.

    * **shrink** — the fraction is priced (>= the curve's and the
      controller's ``min_fraction``) and its slowdown is within
      ``max_slowdown``: book the shrunken vector, charge the slowdown.
    * **wait**   — the curve is flat (not shrinkable / conservative
      fallback), the cut is too deep, or the price exceeds the cap:
      today's behaviour, the job/request stays queued.
    * **reject** — nothing is free at all (fraction <= 0): shrinking
      cannot help; the caller's structured-reject path applies.
    """

    def __init__(self, max_slowdown: float = 2.5,
                 min_fraction: float = 0.25):
        if max_slowdown < 1.0:
            raise ValueError(f"max_slowdown must be >= 1, "
                             f"got {max_slowdown}")
        if not 0.0 < min_fraction <= 1.0:
            raise ValueError(f"min_fraction must be in (0, 1], "
                             f"got {min_fraction}")
        self.max_slowdown = float(max_slowdown)
        self.min_fraction = float(min_fraction)

    def decide(self, curve: Optional[SlowdownCurve],
               fraction: float) -> ElasticDecision:
        f = float(fraction)
        if f <= _EPS:
            return ElasticDecision("reject", 0.0, float("inf"))
        if f >= 1.0 - 1e-12:
            # it fits outright — nothing to shrink
            return ElasticDecision("shrink", 1.0, 1.0)
        if curve is None or not curve.shrinkable:
            return ElasticDecision("wait", f, float("inf"))
        if f < max(self.min_fraction, curve.min_fraction) - 1e-12:
            return ElasticDecision("wait", f, float("inf"))
        s = curve.slowdown_at(f)
        if not np.isfinite(s) or s > self.max_slowdown + 1e-12:
            return ElasticDecision("wait", f, s)
        return ElasticDecision("shrink", f, s)

    def __repr__(self) -> str:
        return (f"ElasticController(max_slowdown={self.max_slowdown}, "
                f"min_fraction={self.min_fraction})")


def shrink_vector(vec: ResourceVector, fraction: float) -> ResourceVector:
    """Scale a demand vector's MEMORY axes by ``fraction`` — cpu and
    link bandwidth are average-rate resources the spill model does not
    shrink (the slowdown already charges the time they are held)."""
    f = float(fraction)
    return ResourceVector(**{a: (v * f if a in MEMORY_AXES else v)
                             for a, v in vec.items()})


# ---------------------------------------------------------------------------
# FailureSchedule: deterministic seeded fail/repair injection
# ---------------------------------------------------------------------------

class FailureSchedule:
    """A pre-drawn fail/repair plan for hosts or serving replicas,
    injected onto a :class:`~repro.sched.cluster.ClusterRuntime` as its
    own event kinds (``efail``/``erepair``).

    Determinism has two parts: the plan is drawn ONCE at construction
    from the schedule's own seeded RNG (so attaching it perturbs no
    consumer RNG stream), and the events ride the shared virtual clock
    (so seeded runs replay bit-identically).  This deliberately does
    NOT reuse the simulator's legacy ``fail`` kind — that handler
    re-arms itself from the simulator RNG unconditionally, which a
    deterministic plan must not trigger."""

    FAIL_KIND = "efail"
    REPAIR_KIND = "erepair"

    def __init__(self, failures: Sequence[Tuple[float, int]],
                 repair_s: float = 5.0):
        """``failures`` — explicit ``(time, target index)`` pairs;
        ``repair_s`` — downtime per failure (the repair event is pushed
        by the fail handler, so overlapping plans stay well-formed)."""
        if repair_s < 0.0:
            raise ValueError(f"repair_s must be >= 0, got {repair_s}")
        self.failures: Tuple[Tuple[float, int], ...] = tuple(
            sorted((float(t), int(idx)) for t, idx in failures))
        for t, _ in self.failures:
            if t < 0.0:
                raise ValueError(f"failure time must be >= 0, got {t}")
        self.repair_s = float(repair_s)
        self._on_fail: Optional[Callable[[float, int], None]] = None
        self._on_repair: Optional[Callable[[float, int], None]] = None
        self._n_targets = 0
        #: injected-event counters (observability; deterministic)
        self.n_failed = 0
        self.n_repaired = 0

    @classmethod
    def poisson(cls, *, seed: int, mtbf_s: float, n_targets: int,
                horizon_s: float, repair_s: float = 5.0,
                max_failures: Optional[int] = None) -> "FailureSchedule":
        """Draw a Poisson fail plan (exponential inter-failure times per
        target) from a dedicated seeded RNG, truncated at ``horizon_s``
        and optionally ``max_failures`` — the stochastic-but-replayable
        construction benches use."""
        if mtbf_s <= 0.0:
            raise ValueError(f"mtbf_s must be > 0, got {mtbf_s}")
        rng = np.random.default_rng(seed)
        events: List[Tuple[float, int]] = []
        for idx in range(int(n_targets)):
            t = float(rng.exponential(mtbf_s))
            while t < horizon_s:
                events.append((t, idx))
                t += repair_s + float(rng.exponential(mtbf_s))
        events.sort()
        if max_failures is not None:
            events = events[:int(max_failures)]
        return cls(events, repair_s=repair_s)

    def attach(self, runtime, *, on_fail: Callable[[float, int], None],
               on_repair: Callable[[float, int], None],
               n_targets: int) -> "FailureSchedule":
        """Register the ``efail``/``erepair`` handlers on ``runtime``
        and push every planned failure whose target index is in range.
        ``on_fail(t, idx)`` / ``on_repair(t, idx)`` are the consumer's
        workload-specific reactions (drain a replica, requeue a host's
        executors); the schedule owns the repair timing."""
        self._on_fail = on_fail
        self._on_repair = on_repair
        self._n_targets = int(n_targets)
        self._runtime = runtime
        runtime.on(self.FAIL_KIND, self._handle_fail)
        runtime.on(self.REPAIR_KIND, self._handle_repair)
        for t, idx in self.failures:
            if 0 <= idx < self._n_targets:
                runtime.push(t, self.FAIL_KIND, idx)
        return self

    def _handle_fail(self, t: float, idx: int):
        self.n_failed += 1
        if self._runtime.tracer is not None:
            self._runtime.tracer.instant(
                "efail", t, process="runtime", thread="failures",
                args={"target": idx})
        self._on_fail(t, idx)
        self._runtime.push(t + self.repair_s, self.REPAIR_KIND, idx)

    def _handle_repair(self, t: float, idx: int):
        self.n_repaired += 1
        if self._runtime.tracer is not None:
            self._runtime.tracer.instant(
                "erepair", t, process="runtime", thread="failures",
                args={"target": idx})
        self._on_repair(t, idx)

    def __repr__(self) -> str:
        return (f"FailureSchedule({len(self.failures)} failures, "
                f"repair_s={self.repair_s})")


# ---------------------------------------------------------------------------
# Autoscaler: replica spawn/drain from sustained trends
# ---------------------------------------------------------------------------

class Autoscaler:
    """Decides replica scale-up/scale-down from *sustained* signals —
    a single bursty sample never flaps the fleet.

    Signals (both already measured by the engine): queue depth per
    active replica (pending + in-transit load) and windowed SLO
    attainment of recently finished requests.  ``observe`` returns
    ``"up"`` / ``"down"`` / ``"hold"``; the consumer owns the actual
    spawn/drain mechanics (the engine pre-provisions ``max_replicas``
    Nodes and flips ``Node.up``).  Streak counters reset after each
    action, so consecutive scale-ups need ``sustain`` fresh samples
    each."""

    KIND = "autoscale"

    def __init__(self, *, max_replicas: int, min_replicas: int = 1,
                 interval_s: float = 1.0,
                 scale_up_queue: float = 4.0,
                 scale_down_queue: float = 0.5,
                 slo_floor: float = 0.9, sustain: int = 3,
                 window: int = 32):
        if max_replicas < 1 or min_replicas < 1 \
                or min_replicas > max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{min_replicas}..{max_replicas}")
        if interval_s <= 0.0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        if sustain < 1:
            raise ValueError(f"sustain must be >= 1, got {sustain}")
        self.max_replicas = int(max_replicas)
        self.min_replicas = int(min_replicas)
        self.interval_s = float(interval_s)
        self.scale_up_queue = float(scale_up_queue)
        self.scale_down_queue = float(scale_down_queue)
        self.slo_floor = float(slo_floor)
        self.sustain = int(sustain)
        self.window = int(window)
        self._slo: List[bool] = []
        self._up_streak = 0
        self._down_streak = 0
        #: decision log: (t, action, queue_per_replica, attainment)
        self.decisions: List[Tuple[float, str, float, float]] = []

    # --- signal feeds ----------------------------------------------------
    def observe_finished(self, ok: bool) -> None:
        """One finished request's SLO verdict into the sliding window."""
        self._slo.append(bool(ok))
        if len(self._slo) > self.window:
            del self._slo[:len(self._slo) - self.window]

    def attainment(self) -> float:
        """Windowed SLO attainment; full attainment with no history."""
        if not self._slo:
            return 1.0
        return sum(self._slo) / len(self._slo)

    # --- the decision ----------------------------------------------------
    def observe(self, now: float, *, queue_depth: float,
                active: int) -> str:
        """Fold one periodic sample and return the action.  Scale-up
        pressure: queue depth per active replica at/above
        ``scale_up_queue`` OR attainment below ``slo_floor``; scale-down
        calm: per-replica depth at/below ``scale_down_queue`` AND
        attainment healthy AND more than ``min_replicas`` active."""
        per = float(queue_depth) / max(int(active), 1)
        attain = self.attainment()
        if (per >= self.scale_up_queue or attain < self.slo_floor) \
                and active < self.max_replicas:
            self._up_streak += 1
            self._down_streak = 0
        elif per <= self.scale_down_queue and attain >= self.slo_floor \
                and active > self.min_replicas:
            self._down_streak += 1
            self._up_streak = 0
        else:
            self._up_streak = 0
            self._down_streak = 0
        action = "hold"
        if self._up_streak >= self.sustain:
            action = "up"
            self._up_streak = 0
        elif self._down_streak >= self.sustain:
            action = "down"
            self._down_streak = 0
        self.decisions.append((float(now), action, per, attain))
        return action

    def __repr__(self) -> str:
        return (f"Autoscaler({self.min_replicas}.."
                f"{self.max_replicas}, interval={self.interval_s}s)")


def pick_spawn_node(candidates: Sequence[int], topology=None
                    ) -> Optional[int]:
    """Which inactive replica Node to spawn: with a topology bound,
    prefer the node whose ingress path has the most residual fair-share
    bandwidth (spawn on the rack with uplink headroom — a replica that
    cannot be fed is no relief); ties and the no-topology case take the
    lowest node id (seeded determinism)."""
    cands = sorted(int(c) for c in candidates)
    if not cands:
        return None
    if topology is None or getattr(topology, "ingress", None) is None:
        return cands[0]
    def headroom(nid: int) -> float:
        name = f"n{nid}"
        if not topology.has_node(name):
            return -1.0
        try:
            return float(topology.path_residual_gbps(
                topology.ingress, name))
        except KeyError:
            return -1.0
    return max(cands, key=lambda nid: (headroom(nid), -nid))
