"""Network topology as a first-class ClusterRuntime citizen: Links,
Transmissions, and fair-share bandwidth partitioning on the EventLoop.

Until this module, the ``net`` axis was fiction twice over: admission
booked a *declared* linear contention curve
(``ModelTarget.net_gbps_per_req``) and routing saw only per-node
counters — no link could congest, no transfer cost virtual time, and a
preempted request could only requeue locally because moving its KV had
no price.  This module makes cross-node traffic REAL on the shared
virtual clock, in the style of the Helix simulator's
``NetworkLink``/``TransmissionObject`` pair:

* :class:`Link`         — one directed edge: bandwidth (GB/s), fixed
  latency, and a ledger of in-flight :class:`Transmission`\\ s.  The
  link's bandwidth is **fair-share partitioned**: each of ``n``
  concurrent flows gets ``bandwidth / n``.
* :class:`Transmission` — one transfer (``gb`` bytes over a path of
  links): progress is advanced lazily and its completion event is
  re-timed (generation-counted, so superseded events are stale — the
  same discipline as the simulator's re-timed ``finish`` events)
  whenever a flow joins or leaves any link on its path.
* :class:`Topology`     — named nodes + directed links with
  deterministic shortest-hop path lookup.  ``attach(runtime)`` registers
  the ``net-start``/``net-done`` handlers on a
  :class:`~repro.sched.cluster.ClusterRuntime`; ``transmit()`` then runs
  transfers as real events on that loop.  Completed transfers are
  logged as measured ``(bytes, duration)`` probes —
  :meth:`Topology.net_probes` feeds them to the estimator registry
  (``ModelTarget.net_probes``), replacing the declared net constant
  with a curve fitted through the existing two-point family selection.
* ``register_topology`` — a preset registry mirroring the router /
  placement / estimator registries: ``single-switch``, ``two-rack``,
  ``ring``.  Replica node ``nid`` maps to topology node ``n<nid>``;
  every preset also has an ``ingress`` node (where request payloads
  enter the cluster).
* :class:`TopoAwareRouter` (``topo-aware``) — scores candidate nodes by
  **path headroom**: the bottleneck link's residual fair share along
  the ingress route (what one more flow would actually get), not a
  per-node scalar.  Degrades to ``least-loaded`` when no topology is
  bound (the ``net-aware`` router stays registered as the
  deprecated-but-pinned per-node-counter shim).

The fair-share model gives a hard lower bound the property tests pin:
a transfer of ``gb`` bytes over a path whose narrowest link has
bandwidth ``B`` can never complete before ``latency + gb / B`` — it
could only ever get *less* than the exclusive bandwidth.

Like the rest of ``repro.sched``, this module imports nothing from
``repro.core`` or ``repro.serve``.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.sched.cluster import Router, _fit_score, register_router

_EPS = 1e-12


class Link:
    """One directed edge: ``bandwidth`` GB/s shared fairly among the
    in-flight transmissions in its ledger, plus a fixed propagation
    latency charged once per transfer before any byte moves."""

    __slots__ = ("name", "src", "dst", "gbps", "latency_s", "flows",
                 "busy_s", "bytes_gb", "peak_flows", "_busy_since")

    def __init__(self, src: str, dst: str, gbps: float,
                 latency_s: float = 0.0, name: Optional[str] = None):
        if gbps <= 0.0:
            raise ValueError(f"link {src}->{dst}: bandwidth must be > 0")
        if latency_s < 0.0:
            raise ValueError(f"link {src}->{dst}: latency must be >= 0")
        self.src = str(src)
        self.dst = str(dst)
        self.gbps = float(gbps)
        self.latency_s = float(latency_s)
        self.name = name or f"{self.src}->{self.dst}"
        #: tid -> in-flight Transmission (the per-link ledger)
        self.flows: Dict[int, "Transmission"] = {}
        #: virtual seconds with >= 1 flow in the ledger (closed
        #: intervals; in-progress busy is added by ``Topology.link_stats``)
        self.busy_s = 0.0
        #: GB actually moved over this link (credited as flows advance)
        self.bytes_gb = 0.0
        #: highest concurrent-flow count ever seen
        self.peak_flows = 0
        self._busy_since: Optional[float] = None

    @property
    def n_flows(self) -> int:
        return len(self.flows)

    def add_flow(self, tr: "Transmission", now: float) -> None:
        """Ledger insert + busy/peak bookkeeping (0 -> 1 flows opens a
        busy interval)."""
        if not self.flows:
            self._busy_since = now
        self.flows[tr.tid] = tr
        if len(self.flows) > self.peak_flows:
            self.peak_flows = len(self.flows)

    def drop_flow(self, tid: int, now: float) -> None:
        """Ledger remove; the last flow leaving closes the busy
        interval into ``busy_s``."""
        if self.flows.pop(tid, None) is not None and not self.flows \
                and self._busy_since is not None:
            self.busy_s += max(now - self._busy_since, 0.0)
            self._busy_since = None

    def fair_share(self) -> float:
        """GB/s each CURRENT flow gets (full bandwidth when idle)."""
        return self.gbps / max(len(self.flows), 1)

    def residual_gbps(self) -> float:
        """GB/s one MORE flow would get — the router's headroom view."""
        return self.gbps / (len(self.flows) + 1)

    def __repr__(self) -> str:
        return (f"Link({self.name}, {self.gbps}GB/s, "
                f"{self.n_flows} flows)")


class Transmission:
    """One transfer in flight: ``gb`` bytes over ``path``.  Progress
    (``done_gb``) advances lazily at the current fair-share ``rate``;
    ``gen`` counts re-timings so superseded completion events read as
    stale, exactly like the simulator's executor ``version``."""

    __slots__ = ("tid", "src", "dst", "gb", "tag", "path", "start_t",
                 "t_last", "done_gb", "rate", "gen", "finish_t",
                 "on_complete")

    def __init__(self, tid: int, src: str, dst: str, gb: float,
                 path: Tuple[Link, ...], start_t: float,
                 tag: str = "", on_complete: Optional[Callable] = None):
        self.tid = tid
        self.src = src
        self.dst = dst
        self.gb = float(gb)
        self.tag = tag
        self.path = path
        self.start_t = float(start_t)
        self.t_last = float(start_t)
        self.done_gb = 0.0
        self.rate = 0.0
        self.gen = 0
        self.finish_t: Optional[float] = None
        self.on_complete = on_complete

    @property
    def remaining_gb(self) -> float:
        return max(self.gb - self.done_gb, 0.0)

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.finish_t is None \
            else self.finish_t - self.start_t

    def __repr__(self) -> str:
        return (f"Transmission({self.tid}, {self.src}->{self.dst}, "
                f"{self.done_gb:.3g}/{self.gb:.3g}GB)")


class Topology:
    """Named nodes + directed links, with transfers as real events.

    Convention: serving replica / simulator host ``nid`` is topology
    node ``n<nid>`` (:meth:`replica_name`); ``ingress`` names the node
    where request payloads enter.  Paths are shortest-hop BFS with
    insertion-ordered (deterministic) tie-breaking, cached per
    ``(src, dst)``.
    """

    def __init__(self, name: str = "", ingress: Optional[str] = None):
        self.name = name
        self.ingress = ingress
        self._nodes: Dict[str, None] = {}
        self._adj: Dict[str, List[Link]] = {}
        self._paths: Dict[Tuple[str, str], Tuple[Link, ...]] = {}
        self._runtime = None
        self._tids = itertools.count()
        self._active: Dict[int, Transmission] = {}
        self._log: List[Transmission] = []

    # --- construction -----------------------------------------------------
    def add_node(self, name: str) -> None:
        self._nodes.setdefault(str(name), None)
        self._adj.setdefault(str(name), [])

    def add_link(self, src: str, dst: str, gbps: float,
                 latency_s: float = 0.0) -> Link:
        """One DIRECTED edge (use :meth:`add_duplex` for both ways)."""
        for n in (src, dst):
            if n not in self._nodes:
                raise KeyError(f"unknown topology node {n!r} — "
                               f"add_node() it first")
        link = Link(src, dst, gbps, latency_s)
        self._adj[src].append(link)
        self._paths.clear()           # edges changed: route cache stale
        return link

    def add_duplex(self, a: str, b: str, gbps: float,
                   latency_s: float = 0.0) -> Tuple[Link, Link]:
        """Two independent directed links (full-duplex: each direction
        has its own bandwidth and flow ledger)."""
        return (self.add_link(a, b, gbps, latency_s),
                self.add_link(b, a, gbps, latency_s))

    def nodes(self) -> Tuple[str, ...]:
        return tuple(self._nodes)

    def links(self) -> Tuple[Link, ...]:
        return tuple(l for adj in self._adj.values() for l in adj)

    @staticmethod
    def replica_name(nid: int) -> str:
        """Topology node name for cluster node ``nid``."""
        return f"n{int(nid)}"

    def has_node(self, name: str) -> bool:
        return name in self._nodes

    # --- path lookup ------------------------------------------------------
    def path(self, src: str, dst: str) -> Tuple[Link, ...]:
        """Shortest-hop path ``src -> dst`` (BFS over insertion-ordered
        adjacency, so ties are deterministic).  Empty tuple when
        ``src == dst``; raises when unreachable."""
        key = (src, dst)
        hit = self._paths.get(key)
        if hit is not None:
            return hit
        for n in (src, dst):
            if n not in self._nodes:
                raise KeyError(f"unknown topology node {n!r}")
        if src == dst:
            self._paths[key] = ()
            return ()
        prev: Dict[str, Link] = {}
        q = deque([src])
        seen = {src}
        while q:
            cur = q.popleft()
            for link in self._adj[cur]:
                if link.dst in seen:
                    continue
                seen.add(link.dst)
                prev[link.dst] = link
                if link.dst == dst:
                    q.clear()
                    break
                q.append(link.dst)
        if dst not in prev:
            raise KeyError(f"no path {src!r} -> {dst!r} in topology "
                           f"{self.name!r}")
        hops: List[Link] = []
        cur = dst
        while cur != src:
            link = prev[cur]
            hops.append(link)
            cur = link.src
        out = tuple(reversed(hops))
        self._paths[key] = out
        return out

    def latency_s(self, src: str, dst: str) -> float:
        return sum(l.latency_s for l in self.path(src, dst))

    def exclusive_gbps(self, src: str, dst: str) -> float:
        """Bottleneck bandwidth with the path to itself: the best any
        single transfer could ever see (the lower-bound divisor)."""
        p = self.path(src, dst)
        return min((l.gbps for l in p), default=float("inf"))

    def path_residual_gbps(self, src: str, dst: str) -> float:
        """Bottleneck RESIDUAL fair share along the path: the GB/s one
        more flow would get given the current in-flight ledgers — the
        ``topo-aware`` router's scoring signal."""
        p = self.path(src, dst)
        return min((l.residual_gbps() for l in p), default=float("inf"))

    def estimate_transfer_s(self, src: str, dst: str, gb: float) -> float:
        """Modeled time for a ``gb`` transfer starting NOW at the
        current contention (residual share held constant) — what the
        migrate-vs-recompute decision compares against recompute cost."""
        res = self.path_residual_gbps(src, dst)
        if res == float("inf"):
            return 0.0
        return self.latency_s(src, dst) + float(gb) / max(res, _EPS)

    # --- transmissions on the event loop ----------------------------------
    def attach(self, runtime) -> "Topology":
        """Bind to a :class:`~repro.sched.cluster.ClusterRuntime`:
        register the transmission event handlers on its loop.  Safe to
        call once per runtime; transfers then run as ``net-start`` /
        ``net-done`` events interleaved with the consumer's own."""
        self._runtime = runtime
        runtime.on("net-start", self._on_start)
        runtime.on("net-done", self._on_done)
        return self

    def transmit(self, src: str, dst: str, gb: float,
                 now: Optional[float] = None, tag: str = "",
                 on_complete: Optional[Callable] = None) -> Transmission:
        """Start a transfer; ``on_complete(t, transmission)`` fires when
        the last byte lands.  The transfer holds a slot in every link
        ledger along the path from ``now + path latency`` (pipe delay)
        until completion, repartitioning each link's fair share as it
        joins and leaves."""
        if self._runtime is None:
            raise RuntimeError("topology not attached to a "
                               "ClusterRuntime — call attach() first")
        t0 = self._runtime.t if now is None else float(now)
        path = self.path(src, dst)
        tr = Transmission(next(self._tids), src, dst, max(float(gb), 0.0),
                          path, t0, tag=tag, on_complete=on_complete)
        self._active[tr.tid] = tr
        if not path or tr.gb <= _EPS:
            # same-node (or empty) transfer: completes after latency,
            # still through the loop so callbacks stay event-ordered
            tr.done_gb = tr.gb
            self._runtime.push(t0 + self.latency_s(src, dst),
                               "net-done", (tr.tid, tr.gen))
        else:
            self._runtime.push(t0 + sum(l.latency_s for l in path),
                               "net-start", tr.tid)
        return tr

    def _on_start(self, t: float, tid: int):
        tr = self._active.get(tid)
        if tr is None:
            return False                      # cancelled before start
        for link in tr.path:
            link.add_flow(tr, t)
        tr.t_last = t
        self._repartition(t)
        self._trace_links(t, tr.path)

    def _on_done(self, t: float, payload):
        tid, gen = payload
        tr = self._active.get(tid)
        if tr is None or tr.gen != gen:
            return False                      # superseded re-timing
        self._advance(t)
        if tr.remaining_gb > 1e-9 * max(tr.gb, 1.0):
            self._retime(t)                   # numeric drift: re-time
            return False
        self._finalize(tr, t)

    # --- fair-share mechanics ---------------------------------------------
    def _started(self) -> List[Transmission]:
        """Active flows that are past their pipe delay (hold link
        slots), in tid order for determinism."""
        seen: Dict[int, Transmission] = {}
        for link in self.links():
            seen.update(link.flows)
        return [seen[tid] for tid in sorted(seen)]

    def _advance(self, now: float) -> None:
        for tr in self._started():
            dt = now - tr.t_last
            if dt > 0.0:
                moved = min(tr.gb, tr.done_gb + tr.rate * dt) \
                    - tr.done_gb
                tr.done_gb += moved
                if moved > 0.0:
                    for link in tr.path:
                        link.bytes_gb += moved
            tr.t_last = now

    def _retime(self, now: float) -> None:
        """Recompute every started flow's fair-share rate (min over its
        path of ``link bandwidth / link flows``) and push a fresh
        generation-stamped completion event."""
        for tr in self._started():
            tr.rate = min(l.fair_share() for l in tr.path)
            tr.gen += 1
            eta = now + tr.remaining_gb / max(tr.rate, _EPS)
            self._runtime.push(eta, "net-done", (tr.tid, tr.gen))

    def _repartition(self, now: float) -> None:
        self._advance(now)
        self._retime(now)

    def _finalize(self, tr: Transmission, t: float) -> None:
        for link in tr.path:
            link.drop_flow(tr.tid, t)
        del self._active[tr.tid]
        tr.finish_t = t
        tr.done_gb = tr.gb
        self._log.append(tr)
        self._repartition(t)                  # survivors speed up
        tracer = getattr(self._runtime, "tracer", None)
        if tracer is not None:
            tag = tr.tag or "net"
            tracer.complete(f"xfer:{tag}", tr.start_t, t,
                            process="network", thread=tag,
                            cat="network",
                            args={"gb": tr.gb, "src": tr.src,
                                  "dst": tr.dst, "tid": tr.tid,
                                  "t0": tr.start_t, "t1": t})
        self._trace_links(t, tr.path)
        if tr.on_complete is not None:
            tr.on_complete(t, tr)

    def _trace_links(self, t: float, path: Sequence[Link]) -> None:
        """Counter-track samples of the affected links' flow counts —
        the report integrates these into per-link busy fractions."""
        tracer = getattr(self._runtime, "tracer", None)
        if tracer is None:
            return
        for link in path:
            tracer.counter(f"link:{link.name}", t,
                           {"flows": link.n_flows}, process="network")

    def link_stats(self, now: Optional[float] = None,
                   elapsed: Optional[float] = None) -> Dict[str, Dict]:
        """Per-link utilization ledger: busy virtual seconds (including
        any interval still open at ``now``), busy fraction of
        ``elapsed``, GB moved, and peak concurrent flows."""
        out: Dict[str, Dict] = {}
        for link in self.links():
            busy = link.busy_s
            if link._busy_since is not None and now is not None:
                busy += max(float(now) - link._busy_since, 0.0)
            out[link.name] = {
                "busy_s": busy,
                "busy_frac": busy / elapsed
                if elapsed is not None and elapsed > 0.0 else 0.0,
                "bytes_gb": link.bytes_gb,
                "peak_flows": link.peak_flows,
            }
        return out

    # --- measured probes ---------------------------------------------------
    def completed(self, tag: Optional[str] = None) -> List[Transmission]:
        return [tr for tr in self._log
                if tag is None or tr.tag == tag]

    def net_probes(self, tag: Optional[str] = None,
                   max_points: int = 64) -> Tuple[Tuple[float, float], ...]:
        """Measured ``(bytes GB, duration s)`` pairs from completed
        transmissions — the probes ``ModelTarget.net_probes`` feeds the
        two-point family-selection fit, replacing the declared
        ``net_gbps_per_req`` constant with observed behaviour (the fit's
        intercept absorbs latency, its slope the effective inverse
        bandwidth under the contention the run actually saw)."""
        pts = [(tr.gb, tr.duration_s) for tr in self.completed(tag)
               if tr.duration_s is not None and tr.duration_s > 0.0
               and tr.gb > 0.0]
        return tuple(pts[-int(max_points):])

    def transfer_times(self, tag: Optional[str] = None) -> List[float]:
        return [tr.duration_s for tr in self.completed(tag)
                if tr.duration_s is not None]

    @property
    def in_flight(self) -> int:
        return len(self._active)

    def __repr__(self) -> str:
        return (f"Topology({self.name!r}, {len(self._nodes)} nodes, "
                f"{len(self.links())} links, {self.in_flight} in flight)")


# ---------------------------------------------------------------------------
# Preset registry (mirrors the router / placement / estimator registries)
# ---------------------------------------------------------------------------

_TOPO_REGISTRY: Dict[str, Callable[..., Topology]] = {}


def register_topology(name: str):
    """Decorator adding a topology builder (``**kwargs -> Topology``)
    to the preset registry under ``name``."""
    def deco(fn: Callable[..., Topology]) -> Callable[..., Topology]:
        _TOPO_REGISTRY[name] = fn
        return fn
    return deco


def get_topology(name: str, **kwargs) -> Topology:
    try:
        builder = _TOPO_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown topology {name!r} (available: "
                       f"{available_topologies()})") from None
    return builder(**kwargs)


def available_topologies() -> Tuple[str, ...]:
    return tuple(_TOPO_REGISTRY)


def _add_replicas(topo: Topology, nodes: int) -> List[str]:
    names = [Topology.replica_name(i) for i in range(int(nodes))]
    for n in names:
        topo.add_node(n)
    return names


@register_topology("single-switch")
def single_switch(nodes: int = 2, gbps: float = 10.0,
                  ingress_gbps: Optional[float] = None,
                  latency_s: float = 0.0) -> Topology:
    """``ingress -> sw -> n<i>``: one shared switch; every node hangs
    off it at ``gbps`` full duplex, ingress feeds the switch at
    ``ingress_gbps`` (default: same as the node links, so the shared
    ingress uplink is the natural contention point)."""
    topo = Topology("single-switch", ingress="ingress")
    topo.add_node("ingress")
    topo.add_node("sw")
    topo.add_duplex("ingress", "sw",
                    gbps if ingress_gbps is None else ingress_gbps,
                    latency_s)
    for n in _add_replicas(topo, nodes):
        topo.add_duplex("sw", n, gbps, latency_s)
    return topo


@register_topology("two-rack")
def two_rack(nodes: int = 4, gbps: float = 10.0,
             uplink_gbps=2.5, latency_s: float = 0.0) -> Topology:
    """``ingress -> core -> rack{0,1} -> n<i>``: nodes split evenly
    (first half on rack 0); the rack uplinks are the narrow links.
    ``uplink_gbps`` may be a scalar or a per-rack ``(r0, r1)`` pair —
    heterogeneous rack uplinks are how the benchmarks make topology
    blindness observable."""
    if int(nodes) < 2:
        raise ValueError("two-rack needs >= 2 nodes")
    up = tuple(uplink_gbps) if isinstance(uplink_gbps, (tuple, list)) \
        else (float(uplink_gbps), float(uplink_gbps))
    topo = Topology("two-rack", ingress="ingress")
    for n in ("ingress", "core", "rack0", "rack1"):
        topo.add_node(n)
    topo.add_duplex("ingress", "core", 2.0 * gbps, latency_s)
    topo.add_duplex("core", "rack0", up[0], latency_s)
    topo.add_duplex("core", "rack1", up[1], latency_s)
    names = _add_replicas(topo, nodes)
    half = (len(names) + 1) // 2
    for i, n in enumerate(names):
        topo.add_duplex("rack0" if i < half else "rack1", n,
                        gbps, latency_s)
    return topo


@register_topology("ring")
def ring(nodes: int = 4, gbps: float = 10.0,
         ingress_gbps: Optional[float] = None,
         latency_s: float = 0.0) -> Topology:
    """``n0 -> n1 -> ... -> n0`` duplex ring; ingress hangs off ``n0``,
    so far-side nodes pay multi-hop paths (hop count is what the
    shortest-hop router trades against link residuals)."""
    if int(nodes) < 2:
        raise ValueError("ring needs >= 2 nodes")
    topo = Topology("ring", ingress="ingress")
    topo.add_node("ingress")
    names = _add_replicas(topo, nodes)
    topo.add_duplex("ingress", names[0],
                    gbps if ingress_gbps is None else ingress_gbps,
                    latency_s)
    for i, n in enumerate(names):
        topo.add_duplex(n, names[(i + 1) % len(names)], gbps, latency_s)
    return topo


# ---------------------------------------------------------------------------
# The topology-aware router
# ---------------------------------------------------------------------------

@register_router("topo-aware")
class TopoAwareRouter(Router):
    """Route on PATH headroom: the bottleneck link's residual fair
    share from the ingress to each candidate node (what delivering one
    more request would actually get), with the generic worst-axis fit
    score breaking ties.  The :class:`~repro.sched.cluster.ClusterRuntime`
    binds ``self.topology`` before each route; with none bound this
    degrades to ``least-loaded`` (and ``net-aware`` remains the
    deprecated per-node-counter shim, golden-pinned)."""

    uses_topology = True

    def route(self, demand, nodes, now=0.0):
        cands = [n for n in nodes if n.up] or list(nodes)
        topo = self.topology
        if topo is None or topo.ingress is None:
            return max(cands,
                       key=lambda n: (_fit_score(n, demand), -n.nid))

        def key(n):
            name = Topology.replica_name(n.nid)
            if not topo.has_node(name):
                res = 0.0                 # off-fabric node: last resort
            else:
                res = topo.path_residual_gbps(topo.ingress, name)
            return (res, _fit_score(n, demand), -n.nid)
        return max(cands, key=key)
