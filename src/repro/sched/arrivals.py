"""Open-arrival workload generation.

The paper evaluates a closed batch (all jobs present at t=0); a runtime
system faces a continuous stream. This module turns an application
universe (``repro.core.workloads``) into timed arrival streams:

* :func:`poisson_arrivals` — memoryless arrivals at a configurable rate
  with a per-class input-size mix (small/medium/large, paper Table 4)
  and optional per-app weighting.
* :func:`trace_arrivals`   — replay an explicit ``(t, app, size)`` trace
  (e.g. recorded from production) against the universe.

Streams are plain sorted lists of :class:`Arrival`; the simulator turns
each into a job whose turnaround is measured from its arrival time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.workloads import INPUT_SIZES_M_ITEMS, AppProfile

# default per-class mix: production streams skew small (many interactive
# queries) with a heavy tail of large analytics jobs
DEFAULT_SIZE_WEIGHTS: Dict[str, float] = {
    "small": 0.5, "medium": 0.35, "large": 0.15,
}


@dataclass(frozen=True)
class Arrival:
    t: float              # arrival time (s)
    app: AppProfile
    items: float          # input size in M-items
    tenant: Optional[str] = None  # owning tenant (fairness accounting)


@dataclass
class ArrivalConfig:
    rate_per_s: float = 0.02          # Poisson arrival rate (jobs/s)
    n_jobs: int = 20                  # stream length
    horizon_s: Optional[float] = None  # truncate the stream at this time
    size_weights: Dict[str, float] = field(
        default_factory=lambda: dict(DEFAULT_SIZE_WEIGHTS))
    app_weights: Optional[Sequence[float]] = None  # per-app mix (uniform)


def sample_input_size(rng: np.random.Generator,
                      size_weights: Optional[Dict[str, float]] = None
                      ) -> float:
    """Draw an input size (M-items) from the class mix over the paper's
    small/medium/large sizes (Table 4)."""
    weights = size_weights or DEFAULT_SIZE_WEIGHTS
    classes = [c for c in INPUT_SIZES_M_ITEMS if weights.get(c, 0.0) > 0]
    p = np.asarray([weights[c] for c in classes], float)
    p /= p.sum()
    cls = classes[int(rng.choice(len(classes), p=p))]
    return float(INPUT_SIZES_M_ITEMS[cls])


def poisson_arrivals(apps: Sequence[AppProfile], acfg: ArrivalConfig,
                     seed: Union[int, Sequence[int]] = 0,
                     tenant: Optional[str] = None) -> List[Arrival]:
    """Open Poisson stream: exponential inter-arrival gaps at
    ``rate_per_s``, app drawn from ``app_weights`` (uniform by default),
    size from the per-class mix. ``seed`` takes anything
    ``np.random.default_rng`` accepts (ints or int sequences).
    ``tenant`` stamps every arrival with an owning tenant (merge
    per-tenant streams with ``sorted(a + b, key=lambda x: x.t)``)."""
    if acfg.rate_per_s <= 0:
        raise ValueError("rate_per_s must be positive")
    rng = np.random.default_rng(seed)
    p = None
    if acfg.app_weights is not None:
        p = np.asarray(acfg.app_weights, float)
        if len(p) != len(apps):
            raise ValueError("app_weights length != number of apps")
        p = p / p.sum()
    out: List[Arrival] = []
    t = 0.0
    for _ in range(acfg.n_jobs):
        t += float(rng.exponential(1.0 / acfg.rate_per_s))
        if acfg.horizon_s is not None and t > acfg.horizon_s:
            break
        app = apps[int(rng.choice(len(apps), p=p))]
        out.append(Arrival(t, app, sample_input_size(rng,
                                                     acfg.size_weights),
                           tenant=tenant))
    return out


def trace_arrivals(trace: Sequence[Tuple],
                   apps: Sequence[AppProfile]) -> List[Arrival]:
    """Replay ``(t, app_name, size)`` rows; ``size`` is either a class
    name from the paper's Table 4 or an explicit M-items value.  Rows
    may carry a fourth element, the owning tenant name (or None)."""
    by_name = {a.name: a for a in apps}
    out: List[Arrival] = []
    for row in trace:
        t, name, size = row[0], row[1], row[2]
        tenant = row[3] if len(row) > 3 else None
        if name not in by_name:
            raise KeyError(f"unknown application {name!r}")
        items = INPUT_SIZES_M_ITEMS[size] if isinstance(size, str) \
            else float(size)
        out.append(Arrival(float(t), by_name[name], float(items),
                           tenant=None if tenant is None else str(tenant)))
    return sorted(out, key=lambda a: a.t)


def load_trace_jsonl(path: str,
                     apps: Sequence[AppProfile]) -> List[Arrival]:
    """Replay a recorded JSONL trace against an application universe —
    the entry point for real-cluster-log replay.

    Each non-blank line is an object with ``t`` (arrival seconds),
    ``app`` (a name in ``apps``), either ``items`` (explicit M-items)
    or ``size`` (a Table-4 class name: small/medium/large), and an
    optional ``tenant`` (owning tenant name for fairness accounting).
    Rows may be out of order in the file; the stream comes back
    time-sorted, via the same validation as :func:`trace_arrivals`."""
    import json

    rows: List[Tuple] = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: bad JSON: {e}") from None
            if "t" not in rec or "app" not in rec:
                raise ValueError(
                    f"{path}:{ln}: trace rows need 't' and 'app'")
            if "items" in rec:
                size: Union[str, float] = float(rec["items"])
            elif "size" in rec:
                size = str(rec["size"])
                if size not in INPUT_SIZES_M_ITEMS:
                    raise ValueError(
                        f"{path}:{ln}: unknown size class {size!r} "
                        f"(known: {tuple(INPUT_SIZES_M_ITEMS)})")
            else:
                raise ValueError(
                    f"{path}:{ln}: trace rows need 'items' or 'size'")
            tenant = rec.get("tenant")
            rows.append((float(rec["t"]), str(rec["app"]), size,
                         None if tenant is None else str(tenant)))
    return trace_arrivals(rows, apps)
