"""Multi-tenant fairness: credit-scored tenants, weighted-DRF routing,
and per-node knapsack packing.

At millions-of-users scale admission is per-*tenant*, not per-request: a
noisy neighbor whose demand keeps outrunning its prediction can starve
well-behaved tenants even when aggregate goodput looks healthy.  This
module adds the tenant axis on top of the existing vector-admission
machinery:

* :class:`Tenant` / :class:`TenantRegistry` — the tenant universe, each
  with a provisioned ``weight`` and a live **credit score** computed
  from signals the system already measures (see below).  The registry
  also keeps the per-(tenant, node) usage ledger the fairness policies
  score against.
* :class:`WeightedDRFRouter` (registry name ``drf``) — routes each
  request to the node where its tenant's *weighted dominant share*
  (dominant resource share over the :class:`~repro.sched.resources.
  ResourceVector` axes, divided by the credit-coupled effective weight)
  would be lowest after placement.  With no registry bound it degrades
  exactly to ``least-loaded``.
* :func:`pack_step` — the per-node knapsack packer the continuous
  batcher uses instead of greedy FIFO-prefix joins when a registry is
  bound: candidates are offered in progressive-filling DRF order
  (lowest weighted share first) and any candidate whose marginal demand
  vector fits the remaining per-axis headroom is admitted (greedy-skip),
  so one tenant's oversized head-of-line request can no longer block
  everyone behind it.

**Credit score.**  ``credit(t)`` is the mean of the signal scores that
have data, clamped to ``[min_credit, 1]`` (no signals = full credit):

* *attainment* — the fraction of the tenant's last ``window`` finished
  requests that met their SLO;
* *error budget* — ``1 - miss_frac / error_budget`` clamped to [0, 1]:
  a tenant that spent its allowed miss fraction scores 0;
* *latency* — ``target / p99(observed latency / target)`` over the
  window, clamped to [0, 1]: sustained p99 at 2x target scores 0.5;
* *demand prediction* — ``1 / (1 + fresh_rejects / window)`` where only
  structured rejects with ``origin == "new"`` count (requeue churn from
  preemption is the scheduler's doing, not the tenant's mis-prediction —
  see the ``origin`` field on ``info["reject"]``).

Every score is monotone in its signal and ``effective_weight = weight *
credit``, so a lower credit can only *raise* a tenant's weighted share —
i.e. push it later in the admission order, never earlier (the credit-
monotonicity invariant ``tests/test_tenancy.py`` pins).

``tenants=None`` everywhere (the default) keeps every schedule
bit-identical to the pre-tenancy engine: the batcher runs its legacy
FIFO-prefix join inverse and routers never see a registry.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import (Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.sched.cluster import Node, Router, _fit_score, register_router
from repro.sched.resources import ResourceVector

_EPS = 1e-12

#: registry key for requests that carry no tenant (they share one
#: default bucket at weight 1.0 so mixed populations stay well-defined)
UNTENANTED = None


def _ew(samples: Sequence[Tuple[float, float]], halflife: float):
    """Exponential-decay weights for timestamped ``(t, value)`` samples:
    weight halves every ``halflife`` virtual seconds behind the newest
    sample."""
    ts = np.asarray([s[0] for s in samples], float)
    vs = np.asarray([float(s[1]) for s in samples], float)
    w = 0.5 ** ((ts.max() - ts) / max(halflife, _EPS))
    return w, vs


def _ew_mean(samples, halflife: float) -> float:
    w, v = _ew(samples, halflife)
    return float(np.sum(w * v) / max(np.sum(w), _EPS))


def _ew_sum(samples, halflife: float) -> float:
    w, v = _ew(samples, halflife)
    return float(np.sum(w * v))


def _ew_percentile(samples, halflife: float, q: float) -> float:
    """Weighted percentile: the smallest value whose cumulative decay
    weight reaches ``q`` percent of the total."""
    w, v = _ew(samples, halflife)
    order = np.argsort(v, kind="stable")
    v, w = v[order], w[order]
    cum = np.cumsum(w) / max(float(np.sum(w)), _EPS)
    idx = int(np.searchsorted(cum, q / 100.0, side="left"))
    return float(v[min(idx, len(v) - 1)])


@dataclass(frozen=True)
class Tenant:
    """Provisioned identity: the name requests carry, the fair-share
    ``weight`` operators assign, and the ``error_budget`` — the SLO miss
    fraction the tenant is allowed before its credit starts paying for
    it (SRE-style: 0.1 = one miss in ten is tolerated).

    ``credit_halflife_s`` switches the tenant's credit signals from the
    registry's hard sliding window (a sample counts fully for
    ``window`` events, then vanishes off a cliff) to an exponential
    decay in virtual time: a sample's influence halves every
    ``credit_halflife_s`` seconds, so one bad burst fades smoothly
    instead of dominating the score until it ages out all at once.
    ``None`` (the default) keeps the window behaviour bit-identical."""
    name: str
    weight: float = 1.0
    error_budget: float = 0.1
    credit_halflife_s: Optional[float] = None

    def __post_init__(self):
        if self.weight <= 0.0:
            raise ValueError(f"tenant {self.name!r}: weight must be "
                             f"> 0, got {self.weight}")
        if not 0.0 <= self.error_budget <= 1.0:
            raise ValueError(f"tenant {self.name!r}: error_budget must "
                             f"be in [0, 1], got {self.error_budget}")
        if self.credit_halflife_s is not None \
                and self.credit_halflife_s <= 0.0:
            raise ValueError(f"tenant {self.name!r}: credit_halflife_s "
                             f"must be > 0, "
                             f"got {self.credit_halflife_s}")


class TenantRegistry:
    """The tenant universe plus its live fairness state: sliding-window
    SLO/latency/reject signals feeding :meth:`credit`, and the
    per-(tenant, node) usage ledger feeding :meth:`weighted_share`.

    Signal observation is deterministic (windows are plain deques over
    virtual-time events), so seeded runs with tenants stay bit-identical
    across machines."""

    def __init__(self, tenants: Sequence[Tenant] = (), *,
                 window: int = 64, min_credit: float = 0.05):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not 0.0 < min_credit <= 1.0:
            raise ValueError(f"min_credit must be in (0, 1], "
                             f"got {min_credit}")
        self.window = int(window)
        self.min_credit = float(min_credit)
        self._tenants: Dict[Optional[str], Tenant] = {}
        # sliding-window signals, per tenant key (None = untenanted)
        self._slo: Dict[Optional[str], deque] = {}
        self._lat_ratio: Dict[Optional[str], deque] = {}
        self._fresh_rejects: Dict[Optional[str], deque] = {}
        self.rejects: Dict[Optional[str], Dict[str, int]] = {}
        # usage ledger: tenant -> node id -> booked vector
        self._usage: Dict[Optional[str], Dict[int, ResourceVector]] = {}
        #: the registry's virtual clock — the max ``now`` any observe
        #: hook has seen.  Half-life tenants stamp their samples with
        #: it; untimed observations reuse the current value (all-equal
        #: stamps degrade the decay to the plain window mean).
        self._clock = 0.0
        for t in tenants:
            self.add(t)

    # --- the tenant universe ---------------------------------------------
    def add(self, tenant: Tenant) -> Tenant:
        if tenant.name in self._tenants:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        self._tenants[tenant.name] = tenant
        return tenant

    def ensure(self, name: Optional[str]) -> Tenant:
        """Get-or-create: unknown names register at default weight, so
        a trace carrying a new tenant never crashes admission."""
        if name not in self._tenants:
            self._tenants[name] = Tenant(name=name or "",
                                         weight=1.0)
        return self._tenants[name]

    def get(self, name: Optional[str]) -> Tenant:
        return self._tenants.get(name) or Tenant(name=name or "")

    def names(self) -> Tuple[Optional[str], ...]:
        return tuple(self._tenants)

    def __contains__(self, name: Optional[str]) -> bool:
        return name in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # --- signal observation ----------------------------------------------
    def _win(self, store: Dict, name: Optional[str]) -> deque:
        if name not in store:
            store[name] = deque(maxlen=self.window)
        return store[name]

    def _stamp(self, now: Optional[float]) -> float:
        if now is not None:
            self._clock = max(self._clock, float(now))
        return self._clock

    def _halflife(self, name: Optional[str]) -> Optional[float]:
        return self.get(name).credit_halflife_s

    def _observe(self, store: Dict, name: Optional[str], value,
                 now: Optional[float]) -> None:
        """Append one signal sample: raw value for window tenants
        (bit-identical to the pre-halflife registry), ``(t, value)``
        for half-life tenants.  The window still caps sample COUNT
        either way; the half-life only reweights what is inside it."""
        t = self._stamp(now)
        win = self._win(store, name)
        if self._halflife(name) is not None:
            win.append((t, value))
        else:
            win.append(value)

    def observe_slo(self, name: Optional[str], ok: bool,
                    now: Optional[float] = None) -> None:
        """One finished request's SLO verdict (both deadlines held)."""
        self._observe(self._slo, name, bool(ok), now)

    def observe_latency_ratio(self, name: Optional[str],
                              ratio: float,
                              now: Optional[float] = None) -> None:
        """One observed-latency / target ratio sample (TTFT over its
        deadline); the window's p99 feeds the latency score."""
        self._observe(self._lat_ratio, name, float(ratio), now)

    def observe_reject(self, name: Optional[str],
                       origin: str = "new",
                       now: Optional[float] = None) -> None:
        """One structured join reject.  Only ``origin == "new"`` counts
        toward the demand-prediction score — a requeued (preempted)
        request bouncing off admission is scheduler churn, not the
        tenant mis-declaring its demand."""
        by = self.rejects.setdefault(name, {})
        by[origin] = by.get(origin, 0) + 1
        self._observe(self._fresh_rejects, name, origin == "new", now)

    def observe_request(self, req) -> None:
        """Convenience hook for the engine's retire path: fold one
        finished :class:`~repro.serve.request.Request` into the SLO and
        latency windows (stamped at its finish time, which is what the
        half-life decays against)."""
        self.observe_slo(req.tenant, req.meets_slo(), now=req.finish_t)
        if req.ttft_deadline is not None \
                and req.first_token_t is not None:
            self.observe_latency_ratio(
                req.tenant,
                (req.first_token_t - req.arrival) / req.ttft_deadline,
                now=req.finish_t)

    # --- credit -----------------------------------------------------------
    def credit(self, name: Optional[str]) -> float:
        """The live credit score in ``[min_credit, 1]`` — the mean of
        the signal scores that have data (see the module docstring for
        the formula).  A tenant with no history has full credit."""
        hl = self._halflife(name)
        scores: List[float] = []
        slo = self._slo.get(name)
        if slo:
            attain = _ew_mean(slo, hl) if hl is not None \
                else sum(slo) / len(slo)
            scores.append(attain)
            budget = self.get(name).error_budget
            miss = 1.0 - attain
            scores.append(min(max(1.0 - miss / budget, 0.0), 1.0)
                          if budget > 0.0 else (1.0 if miss == 0.0
                                                else 0.0))
        lat = self._lat_ratio.get(name)
        if lat:
            p99 = _ew_percentile(lat, hl, 99) if hl is not None \
                else float(np.percentile(np.asarray(lat, float), 99))
            scores.append(min(max(1.0 / max(p99, _EPS), 0.0), 1.0))
        rej = self._fresh_rejects.get(name)
        if rej:
            fresh = _ew_sum(rej, hl) if hl is not None else sum(rej)
            scores.append(1.0 / (1.0 + fresh / float(self.window)))
        if not scores:
            return 1.0
        return min(max(float(np.mean(scores)), self.min_credit), 1.0)

    def effective_weight(self, name: Optional[str]) -> float:
        """The credit-coupled DRF weight: provisioned weight times live
        credit, floored away from zero so shares stay finite."""
        return max(self.get(name).weight * self.credit(name), _EPS)

    # --- the usage ledger -------------------------------------------------
    def add_usage(self, name: Optional[str], nid: int,
                  vec: ResourceVector) -> None:
        by_node = self._usage.setdefault(name, {})
        by_node[nid] = by_node.get(nid, ResourceVector()) + vec

    def set_node_usage(self, nid: int,
                       by_tenant: Dict[Optional[str], ResourceVector]
                       ) -> None:
        """Reconcile one node's per-tenant usage (the engine calls this
        from its post-step ledger sync, so the registry's view matches
        the Node claim ledger exactly)."""
        for by_node in self._usage.values():
            by_node.pop(nid, None)
        for name, vec in by_tenant.items():
            self._usage.setdefault(name, {})[nid] = vec

    def usage(self, name: Optional[str],
              nid: Optional[int] = None) -> ResourceVector:
        by_node = self._usage.get(name, {})
        if nid is not None:
            return by_node.get(nid, ResourceVector())
        total = ResourceVector()
        for vec in by_node.values():
            total = total + vec
        return total

    # --- dominant shares --------------------------------------------------
    @staticmethod
    def dominant_share(vec: ResourceVector,
                       capacity: ResourceVector) -> float:
        """The DRF dominant share: max over capacitated axes of the
        tenant's usage fraction (axes the capacity does not carry are
        unconstrained and never dominate)."""
        share = 0.0
        for a, cap in capacity.items():
            if cap > _EPS:
                share = max(share, vec.get(a, 0.0) / cap)
        return share

    def weighted_share_of(self, name: Optional[str], vec: ResourceVector,
                          capacity: ResourceVector) -> float:
        """Dominant share of an explicit usage vector divided by the
        tenant's effective (credit-coupled) weight — the quantity DRF
        minimizes across tenants.  Lower credit divides by less, so the
        share only ever grows (credit monotonicity)."""
        return self.dominant_share(vec, capacity) \
            / self.effective_weight(name)

    def weighted_share(self, name: Optional[str],
                       capacity: ResourceVector,
                       nid: Optional[int] = None) -> float:
        return self.weighted_share_of(name, self.usage(name, nid),
                                      capacity)

    # --- serialization ----------------------------------------------------
    def to_dict(self) -> Dict:
        """Provisioned state only (weights, error budgets, knobs) —
        live signals and usage are runtime state and do not persist."""
        return {
            "window": self.window,
            "min_credit": self.min_credit,
            "tenants": [
                {"name": t.name, "weight": t.weight,
                 "error_budget": t.error_budget,
                 **({"credit_halflife_s": t.credit_halflife_s}
                    if t.credit_halflife_s is not None else {})}
                for k, t in self._tenants.items() if k is not None],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TenantRegistry":
        return cls([Tenant(name=row["name"],
                           weight=float(row.get("weight", 1.0)),
                           error_budget=float(row.get("error_budget",
                                                      0.1)),
                           credit_halflife_s=row.get(
                               "credit_halflife_s"))
                    for row in d.get("tenants", [])],
                   window=int(d.get("window", 64)),
                   min_credit=float(d.get("min_credit", 0.05)))

    def summary(self, capacity: Optional[ResourceVector] = None) -> Dict:
        """Per-tenant live view for CLI tables / metrics: credit,
        effective weight, reject counts, and (with a capacity) the
        current weighted dominant share."""
        out: Dict[str, Dict] = {}
        for key, t in self._tenants.items():
            if key is None:
                continue
            row = {"weight": t.weight,
                   "error_budget": t.error_budget,
                   "credit": self.credit(key),
                   "effective_weight": self.effective_weight(key),
                   "rejects": dict(self.rejects.get(key, {}))}
            if capacity is not None:
                row["weighted_share"] = self.weighted_share(key, capacity)
            out[t.name] = row
        return out


# ---------------------------------------------------------------------------
# weighted-DRF router
# ---------------------------------------------------------------------------

@register_router("drf")
class WeightedDRFRouter(Router):
    """Route to the node where the requesting tenant's weighted dominant
    share would be LOWEST after placement (progressive filling across
    nodes), so each tenant's footprint spreads instead of piling one
    replica full of one tenant.  Ties break on the generic worst-axis
    fit score, then the lowest node id (seeded determinism).

    The runtime binds ``self.tenancy`` (the :class:`TenantRegistry`)
    and ``self.tenant`` (the requesting tenant) before each ``route``
    call — the same late-binding pattern as ``uses_topology``.  With no
    registry bound this router IS ``least-loaded``, which keeps
    ``--router drf`` safe on untenanted deployments."""

    uses_tenancy = True
    tenancy: Optional[TenantRegistry] = None
    tenant: Optional[str] = None

    def route(self, demand, nodes, now=0.0):
        cands = [n for n in nodes if n.up] or list(nodes)
        reg = self.tenancy
        if reg is None:
            return max(cands,
                       key=lambda n: (_fit_score(n, demand), -n.nid))

        def key(n: Node):
            post = reg.usage(self.tenant, n.nid)
            if demand is not None:
                post = post + demand
            share = reg.weighted_share_of(self.tenant, post, n.capacity)
            return (-share, _fit_score(n, demand), -n.nid)
        return max(cands, key=key)


# ---------------------------------------------------------------------------
# per-node knapsack packing
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Skip:
    """One candidate the packer offered but declined for lack of
    resources: the axis whose remaining headroom fell shortest and by
    how much (candidates never offered because the batch-slot cap
    filled first produce no Skip — they were not rejected, merely not
    reached this step, matching how the legacy FIFO path treats pending
    work beyond its prefix window)."""
    rid: int
    tenant: Optional[str]
    axis: Optional[str]
    deficit: float
    origin: str                     # "new" | "requeue"


def request_origin(req) -> str:
    """Where a join candidate came from: ``"requeue"`` when it has run
    before (preempted at least once), ``"new"`` on its first offer —
    the distinction per-tenant reject accounting needs to not
    double-count preemption churn."""
    return "requeue" if (req.admissions > 0 or req.preemptions > 0) \
        else "new"


def pack_step(registry: TenantRegistry, cands: Sequence,
              headroom: ResourceVector, capacity: ResourceVector,
              usage: Dict[Optional[str], ResourceVector],
              demand_vec: Callable[[object], ResourceVector],
              slots: int) -> Tuple[List, List[Skip]]:
    """The per-node knapsack: pick which queued requests join this step
    under the node's per-axis ``headroom``, in progressive-filling
    weighted-DRF order, instead of admitting a greedy FIFO prefix.

    Each round offers the next candidate of the tenant with the lowest
    weighted dominant share (``usage`` grows as admissions land, so
    shares re-rank every round; ties break on queue position, keeping
    the plan deterministic).  A candidate whose marginal demand vector
    fits the REMAINING headroom is admitted and subtracted; one that
    does not is skipped with a structured reason — later (smaller)
    candidates, including the same tenant's, are still offered, so the
    pack never admits less than the FIFO prefix would have and never
    exceeds the headroom on any axis.

    ``usage`` is mutated in place (admitted vectors accumulate) so the
    caller's eviction accounting and the join accounting agree."""
    queues: Dict[Optional[str], deque] = {}
    pos: Dict[int, int] = {}
    for i, r in enumerate(cands):
        queues.setdefault(r.tenant, deque()).append(r)
        pos[id(r)] = i
    admitted: List = []
    skips: List[Skip] = []
    used = ResourceVector()
    while queues and len(admitted) < slots:
        tenant = min(
            queues,
            key=lambda t: (registry.weighted_share_of(
                t, usage.get(t, ResourceVector()), capacity),
                pos[id(queues[t][0])]))
        r = queues[tenant].popleft()
        if not queues[tenant]:
            del queues[tenant]
        vec = demand_vec(r)
        if (used + vec).fits(headroom):
            admitted.append(r)
            used = used + vec
            usage[tenant] = usage.get(tenant, ResourceVector()) + vec
        else:
            rem = headroom.headroom(used)
            overs = {a: float(v - rem.get(a, 0.0))
                     for a, v in vec.items()
                     if a in rem and v > rem.get(a, 0.0) + 1e-9}
            axis = max(overs, key=overs.get) if overs else None
            skips.append(Skip(r.rid, tenant, axis,
                              overs.get(axis, 0.0), request_origin(r)))
    return admitted, skips
