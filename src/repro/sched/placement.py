"""Pluggable placement policies: who gets offered capacity, in what order.

The simulator's dispatcher used to hard-wire FCFS-over-jid with an
in-index host scan.  This module extracts that choice behind a tiny
protocol so queue ordering and host-scan order are selectable per run
(``SimConfig.placement``, ``benchmarks/run.py --placement``,
``launch/serve.py --placement``) without touching the admission logic:

* ``fcfs``          — jobs in arrival (jid) order, hosts in index order.
  The default; byte-identical to the pre-registry dispatcher.
* ``sjf``           — shortest remaining (isolated) job first: small
  jobs overtake large ones, trading makespan for mean turnaround.
* ``best-fit``      — FCFS over jobs, but hosts scanned tightest-fit
  first (least free primary memory), packing fragments before opening
  fresh hosts.
* ``arrival-aware`` — jobs ordered by normalized waiting time
  ``(now - arrival) / c_iso`` descending: the job whose slowdown is
  growing fastest is served first (directly optimizes ANTT under open
  arrival streams).

Jobs and hosts are duck-typed (``.arrival``/``.c_iso``/``.unassigned``
and ``.free_vector()`` respectively) so this module imports nothing from
``repro.core`` — registration is import-cycle-free and third-party
policies can register their own types.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple, Type

_EPS = 1e-12


class PlacementPolicy:
    """Ordering protocol.  Subclass + ``@register_placement(name)``.

    Both hooks must be *pure orderings* (no admission decisions, no RNG):
    they receive already-schedulable jobs / candidate hosts and return
    them in offer order.  Stability matters — ties must preserve input
    order so runs stay deterministic.
    """

    name = "base"

    def order_jobs(self, jobs: Sequence, now: float = 0.0) -> List:
        return list(jobs)

    def order_hosts(self, job, hosts: Sequence,
                    primary_axis: str = "host_ram") -> List:
        return list(hosts)


_REGISTRY: Dict[str, Type[PlacementPolicy]] = {}


def register_placement(name: str):
    """Class decorator adding a policy to the registry under ``name``."""
    def deco(cls: Type[PlacementPolicy]) -> Type[PlacementPolicy]:
        if not issubclass(cls, PlacementPolicy):
            raise TypeError(f"{cls!r} is not a PlacementPolicy")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_placement(name: str) -> PlacementPolicy:
    """Instantiate the registered policy ``name`` (KeyError with the
    available names otherwise)."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r} "
                       f"(available: {available_placements()})") from None


def available_placements() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


@register_placement("fcfs")
class FCFSPlacement(PlacementPolicy):
    """First-come-first-served over jid, hosts in index order — the
    pre-registry dispatcher, bit-for-bit."""


@register_placement("sjf")
class SJFPlacement(PlacementPolicy):
    """Shortest remaining isolated work first (stable on ties)."""

    def order_jobs(self, jobs, now: float = 0.0):
        def remaining(j):
            frac = j.unassigned / max(getattr(j, "items", j.unassigned),
                                      _EPS)
            return j.c_iso * frac
        return sorted(jobs, key=remaining)


@register_placement("best-fit")
class BestFitPlacement(PlacementPolicy):
    """FCFS over jobs; hosts scanned tightest-fit first (least free
    primary memory), so fragments fill before fresh hosts open."""

    def order_hosts(self, job, hosts, primary_axis: str = "host_ram"):
        return sorted(hosts,
                      key=lambda h: h.free_vector().get(primary_axis, 0.0))


@register_placement("arrival-aware")
class ArrivalAwarePlacement(PlacementPolicy):
    """Serve the job whose normalized turnaround is degrading fastest:
    order by waiting time over isolated runtime, descending.  Under a
    batch (all arrivals at t=0) this prioritizes short jobs — the ANTT
    view of SJF; under an open stream it balances waiting against size."""

    def order_jobs(self, jobs, now: float = 0.0):
        def urgency(j):
            return (now - getattr(j, "arrival", 0.0)) / max(j.c_iso, _EPS)
        return sorted(jobs, key=urgency, reverse=True)
