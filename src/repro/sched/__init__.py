"""Online scheduling subsystem: the paper's runtime, factored out.

Three parts, shared by the cluster simulator (``core/simulator.py``) and
the serving driver (``launch/serve.py``):

* ``admission``  — :class:`AdmissionController`: predict -> two-point
  calibrate -> budget-inverse admission (how many units fit under a
  memory budget), plus the scheduler's budget-shading rules
  (safety margin, conservative fallback, OOM backoff).
* ``arrivals``   — open-arrival workload generation: Poisson or
  trace-driven arrival streams with per-class input-size mixes over an
  application universe, so the system runs as a continuously-fed queue
  rather than a batch at t=0.
* ``online``     — :class:`OnlineRefresher`: folds newly profiled
  arrivals back into a fitted :class:`~repro.core.predictor.MoEPredictor`
  (KNN append + scaler-bound widening) without a full refit.
"""
from repro.sched.admission import (  # noqa: F401
    AdmissionController,
    AdmissionDecision,
)
from repro.sched.arrivals import (  # noqa: F401
    Arrival,
    ArrivalConfig,
    poisson_arrivals,
    trace_arrivals,
)
from repro.sched.online import OnlineRefresher  # noqa: F401
