"""Online scheduling subsystem: the paper's runtime, factored out.

Eight parts, shared by the cluster simulator (``core/simulator.py``) and
the serving driver (``launch/serve.py``):

* ``cluster``    — the event-driven :class:`ClusterRuntime` substrate:
  a virtual-clock :class:`EventLoop`, per-node booked-capacity
  :class:`Node` ledgers, :class:`ClusterState`, and the ``Router``
  registry (``single`` / ``least-loaded`` / ``net-aware`` /
  ``topo-aware``) that routes each admitted job/request to a node by
  its predicted multi-axis demand.  BOTH the batch simulator and the
  serving engine run on this one loop (``Simulator.run`` and
  single-replica ``Engine`` results are golden-pinned bit-identical to
  the pre-runtime paths).

* ``topology``   — the network as a first-class runtime citizen:
  :class:`Link` (fair-share bandwidth partitioning over a per-link
  in-flight ledger), :class:`Transmission` events on the same
  :class:`EventLoop`, :class:`Topology` presets
  (``single-switch`` / ``two-rack`` / ``ring`` via
  ``register_topology``), the ``topo-aware`` router (bottleneck-link
  residual path headroom), and measured ``net_probes()`` feeding the
  estimator registry.

* ``estimator``  — :class:`DemandEstimator` registry (``moe`` /
  ``oracle`` / ``single-family`` / ``ann`` / ``conservative`` /
  ``kv-growth``): ONE ``estimate(target, probes) -> DemandEstimate``
  entry point producing the full multi-axis demand model (predicted
  side-car curves included) with per-axis confidence and the
  conservative-fallback flag.  Selectable via ``SimConfig.estimator``,
  ``benchmarks/run.py --estimator``, ``launch/serve.py --estimator``.

* ``resources``  — :class:`ResourceVector` (named axes ``host_ram`` /
  ``cpu`` / ``hbm`` / ``net`` with ``+``/``-``/``fits``/``headroom``
  algebra) and :class:`DemandModel` (per-axis demand curves + fixed
  per-placement loads), so admission reasons about multiple resources
  jointly instead of one GB number.
* ``admission``  — :class:`AdmissionController`: predict -> two-point
  calibrate -> budget-inverse admission along the *binding axis* (min
  over per-axis inverses), plus the scheduler's budget-shading rules
  (safety margin, conservative fallback, OOM backoff).  The scalar
  ``admit(fn, budget_gb)`` API remains as a shim over single-axis
  vectors.
* ``placement``  — :class:`PlacementPolicy` registry (``fcfs`` /
  ``sjf`` / ``best-fit`` / ``arrival-aware``): queue ordering and
  host-scan order, extracted from the dispatcher and selectable per run.
* ``arrivals``   — open-arrival workload generation: Poisson or
  trace-driven arrival streams with per-class input-size mixes over an
  application universe, so the system runs as a continuously-fed queue
  rather than a batch at t=0.
* ``tenancy``    — multi-tenant fairness: :class:`Tenant` /
  :class:`TenantRegistry` (credit scores from live SLO / latency /
  reject signals, a per-(tenant, node) usage ledger), the ``drf``
  weighted-DRF router (dominant share over credit-coupled weight), and
  the per-node knapsack packer (:func:`pack_step`) the continuous
  batcher runs instead of greedy FIFO when a registry is bound.
* ``online``     — :class:`OnlineRefresher`: folds newly profiled
  arrivals back into a fitted :class:`~repro.core.predictor.MoEPredictor`
  (KNN append + scaler-bound widening) without a refit.
* ``elastic``    — the elastic runtime: :class:`SlowdownCurve`
  (demand-vs-slowdown, fit from spill-model probes), the
  :class:`ElasticController` shrink-vs-wait-vs-reject policy behind
  ``AdmissionController.shrink_target``, deterministic
  :class:`FailureSchedule` fail/repair injection, and the
  queue/SLO-trend :class:`Autoscaler` with topology-aware spawn
  placement (:func:`pick_spawn_node`).
"""
from repro.sched.resources import (  # noqa: F401
    AXES,
    MEMORY_AXES,
    DemandModel,
    ResourceVector,
    single_axis,
)
from repro.sched.cluster import (  # noqa: F401
    ClusterRuntime,
    ClusterState,
    EventLoop,
    Node,
    Router,
    available_routers,
    get_router,
    register_router,
)
from repro.sched.topology import (  # noqa: F401
    Link,
    TopoAwareRouter,
    Topology,
    Transmission,
    available_topologies,
    get_topology,
    register_topology,
)
from repro.sched.admission import (  # noqa: F401
    AdmissionController,
    AdmissionDecision,
)
from repro.sched.estimator import (  # noqa: F401
    DemandEstimate,
    DemandEstimator,
    JobTarget,
    ModelTarget,
    available_estimators,
    get_estimator,
    register_estimator,
    resolve_estimator,
    wrap_predictor,
)
from repro.sched.placement import (  # noqa: F401
    PlacementPolicy,
    available_placements,
    get_placement,
    register_placement,
)
from repro.sched.arrivals import (  # noqa: F401
    Arrival,
    ArrivalConfig,
    load_trace_jsonl,
    poisson_arrivals,
    trace_arrivals,
)
from repro.sched.tenancy import (  # noqa: F401
    Tenant,
    TenantRegistry,
    WeightedDRFRouter,
    pack_step,
    request_origin,
)
from repro.sched.elastic import (  # noqa: F401
    Autoscaler,
    ElasticController,
    ElasticDecision,
    FailureSchedule,
    SlowdownCurve,
    fit_slowdown_curve,
    pick_spawn_node,
    shrink_vector,
)
from repro.sched.online import OnlineRefresher  # noqa: F401
