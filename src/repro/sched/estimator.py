"""Unified demand estimation: ONE predicted multi-axis entry point.

The paper's contribution is *predicting* an application's memory
function (MoE selector + two-point calibration) and inverting it under
a budget.  Before this module, the demand side was scattered: the
predictor handed back a scalar curve, ``DemandModel`` bundled it with
*declared* side-car curves (``AppProfile.aux_demand``), and serving kept
its own calibration cache.  Every admission consumer now goes through a
single pluggable API:

* :class:`DemandEstimator` — protocol ``estimate(target, probes) ->
  DemandEstimate``: a full multi-axis :class:`~repro.sched.resources.
  DemandModel` plus per-axis confidence and a conservative-fallback
  flag.  Estimators that learn online also expose ``partial_update``
  (the :class:`~repro.sched.online.OnlineRefresher` hook).
* a registry mirroring ``repro.sched.placement`` —
  ``register_estimator`` / ``get_estimator`` / ``available_estimators``
  — selectable per run via ``SimConfig.estimator``,
  ``benchmarks/run.py --estimator`` and ``launch/serve.py
  --estimator``.

Registered implementations:

``moe``            the flagship (paper): KNN family selection +
                   two-point calibration on the 5%/10% probes, PLUS
                   **predicted** side-car curves — each aux axis the
                   workload exposes (host staging RAM, interconnect
                   ``net``) is probed at the same input sizes and fitted
                   (``net`` with the simple linear contention curve,
                   other axes with the best expert family), replacing
                   the deprecated declared ``AppProfile.aux_demand``
                   consumption.
``oracle``         ground-truth curves on every axis, confidence 1.0.
``single-family``  one expert family for everything (Fig. 9 baseline).
``ann``            the QUASAR-style monolithic regressor baseline.
``conservative``   no learned selector: best probe fit, always flagged
                   conservative (the scheduler halves memory budgets —
                   paper Section 6.9); on serving targets it pads the
                   calibrated footprint instead.
``kv-growth``      the serving footprint: two-point affine calibration
                   of weights+KV vs batch at ``max_len`` — this
                   estimator owns the per-``(config, max_len)``
                   calibration cache that used to live on
                   ``DemandModel.from_model_config`` (now a deprecated
                   bit-identical shim over it).

Targets are plain dataclasses: :class:`JobTarget` (an
``AppProfile`` + total work units — the simulator's case) and
:class:`ModelTarget` (a model config + ``max_len`` — the serving case).
Passing ``probes`` (measured ``(x, y)`` pairs) calibrates from them
instead of measuring through the target.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from repro.core import experts
from repro.sched.elastic import SlowdownCurve, fit_slowdown_curve
from repro.core.experts import MemoryFunction
from repro.sched.resources import DemandModel

if TYPE_CHECKING:
    from repro.core.workloads import AppProfile

#: Aux-axis fit quality worse than this relative error maps to zero
#: confidence (linear in between) — a heuristic scale, not a gate.
_AUX_ERR_SCALE = 0.25


# ---------------------------------------------------------------------------
# Targets and the estimate
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class JobTarget:
    """A schedulable job: which application, how much work, and which
    axis its primary (calibrated) memory curve budgets."""
    app: "AppProfile"
    units: float                      # total work (M-items / k-tokens)
    primary_axis: str = "host_ram"
    #: owning tenant for fairness accounting (None = untenanted)
    tenant: Optional[str] = None


@dataclass(frozen=True)
class ModelTarget:
    """A serving deployment: model config + context length, plus the
    per-request side-car intensities the deployment declares.
    ``page_size`` is the KV allocation granularity in tokens (1 = dense
    slot-per-token; > 1 = the paged backend's page quantum, which the
    estimate exposes so admission books page-rounded demand)."""
    cfg: object
    max_len: int
    host_ram_per_req_gb: float = 0.0  # pinned host staging per request
    net_gbps_per_req: float = 0.0     # egress/interconnect per request
    page_size: int = 1                # KV allocation granularity
    #: measured (bytes GB, duration s) pairs from completed topology
    #: Transmissions (``Topology.net_probes()``); >= 2 points replace
    #: the declared net_gbps_per_req constant with a fitted curve
    net_probes: Optional[Tuple[Tuple[float, float], ...]] = None


Target = Union[JobTarget, ModelTarget]


@dataclass(frozen=True)
class DemandEstimate:
    """What an estimator hands the admission controller: the full
    multi-axis demand model, how much to trust each axis, and whether
    the scheduler should fall back to conservative budget shading."""
    model: DemandModel
    confidence: Dict[str, float] = field(default_factory=dict)  # per axis
    conservative: bool = False
    info: Dict = field(default_factory=dict)
    #: demand-vs-slowdown trade-off along the primary memory axis
    #: (spill-aware shrink admission).  ``None`` or the flat curve both
    #: mean "not shrinkable" — the conservative fallback; estimators fit
    #: it from the same probes the demand curve came from.
    shrink: Optional[SlowdownCurve] = None

    @property
    def primary_fn(self) -> Optional[MemoryFunction]:
        return self.model.primary_fn

    def aux_curves(self) -> Dict[str, MemoryFunction]:
        """Every predicted curve except the primary one."""
        return {a: fn for a, fn in self.model.curves.items()
                if a != self.model.primary_axis}


# ---------------------------------------------------------------------------
# Protocol + registry (mirrors repro.sched.placement)
# ---------------------------------------------------------------------------

class DemandEstimator:
    """Estimation protocol.  Subclass + ``@register_estimator(name)``.

    ``estimate`` must be deterministic given ``(target, probes, rng)``;
    any measurement noise comes from the ``rng`` the caller passes, so
    seeded runs stay reproducible."""

    name = "base"
    #: expert families this estimator fits against (OnlineRefresher
    #: reads this off the registry handle)
    families: Sequence[str] = experts.FAMILIES
    #: whether partial_update folds observations in (vs dropping them)
    supports_online_update = False

    def estimate(self, target: Target,
                 probes: Optional[Sequence[Tuple[float, float]]] = None,
                 *, rng: Optional[np.random.Generator] = None
                 ) -> DemandEstimate:
        raise NotImplementedError

    def partial_update(self, features: np.ndarray, family: str) -> bool:
        """Online refresh hook: fold one profiled observation into the
        estimator.  Estimators that do not learn online drop the
        observation (return False) instead of raising, so the refresher
        can stream into any registry handle."""
        return False


_REGISTRY: Dict[str, Type[DemandEstimator]] = {}


def register_estimator(name: str):
    """Class decorator adding an estimator to the registry."""
    def deco(cls: Type[DemandEstimator]) -> Type[DemandEstimator]:
        if not issubclass(cls, DemandEstimator):
            raise TypeError(f"{cls!r} is not a DemandEstimator")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_estimator(name: str, **kwargs) -> DemandEstimator:
    """Instantiate the registered estimator ``name``.  ``kwargs`` are
    forwarded to the constructor (every job estimator accepts a
    ``predictor=`` keyword, used or ignored as appropriate, so sweeps
    can construct any of them uniformly)."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown estimator {name!r} "
                       f"(available: {available_estimators()})") from None
    return cls(**kwargs)


def available_estimators() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


#: Estimators a config-level sweep (``SimConfig.estimator`` /
#: ``benchmarks/run.py --estimator``) can instantiate around whatever
#: predictor the swept policy happens to carry.  ``ann`` needs a fitted
#: ANNPredictor passed explicitly and ``kv-growth`` only estimates
#: serving ModelTargets, so neither is sweepable.
SWEEPABLE_ESTIMATORS = ("moe", "oracle", "single-family", "conservative")


def resolve_estimator(spec, predictor=None) -> Optional[DemandEstimator]:
    """The consumer-side resolution rule: an estimator instance passes
    through; a registry name is instantiated around ``predictor``; an
    empty spec wraps the predictor in its faithful estimator (the
    back-compat default — bit-identical to the pre-estimator paths)."""
    if isinstance(spec, DemandEstimator):
        return spec
    if spec:
        return get_estimator(spec, predictor=predictor)
    return wrap_predictor(predictor)


def wrap_predictor(predictor) -> Optional[DemandEstimator]:
    """Adapt a fitted ``repro.core.predictor`` object to the estimator
    API (the migration shim: ``OursPolicy(moe)`` keeps working and keeps
    its exact RNG draw order)."""
    if predictor is None:
        return None
    if isinstance(predictor, DemandEstimator):
        return predictor
    from repro.core.predictor import (ANNPredictor, OraclePredictor,
                                      UnifiedFamilyPredictor)
    if isinstance(predictor, OraclePredictor):
        return OracleEstimator()
    if isinstance(predictor, UnifiedFamilyPredictor):
        return SingleFamilyEstimator(family=predictor.family)
    if isinstance(predictor, ANNPredictor):
        return ANNEstimator(predictor=predictor)
    if hasattr(predictor, "select_family"):
        return MoEEstimator(predictor=predictor)
    if hasattr(predictor, "predict_function"):
        return PredictorEstimator(predictor=predictor)
    raise TypeError(f"cannot adapt {type(predictor).__name__} to the "
                    f"DemandEstimator API")


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _fit_probes(family: str,
                probes: Sequence[Tuple[float, float]]) -> MemoryFunction:
    """Instantiate (m, b) from measured probes: the paper's exact
    two-point solve for two, least-squares beyond."""
    pts = sorted((float(x), float(y)) for x, y in probes)
    if len(pts) < 2:
        raise ValueError("calibration needs at least two probes")
    if len(pts) == 2:
        (x1, y1), (x2, y2) = pts
        return experts.calibrate_two_point(family, x1, y1, x2, y2)
    xs, ys = zip(*pts)
    return experts.fit(family, xs, ys)


def _two_point_best(xs: np.ndarray, ys: np.ndarray,
                    families: Sequence[str]
                    ) -> Tuple[MemoryFunction, float]:
    """The paper's calibration style applied to family selection:
    two-point-solve each candidate family through the end probes and
    keep the one whose RELATIVE error over all probes is smallest.
    (Least-squares fits minimize absolute residuals, which lets a
    power fit beat an exact affine curve whose small probe it crushes.)"""
    best_fn, best_err = None, np.inf
    for fam in families:
        try:
            fn = experts.calibrate_two_point(
                fam, float(xs[0]), float(ys[0]),
                float(xs[-1]), float(ys[-1]))
        except (ValueError, AssertionError):
            continue
        err = experts.relative_error(fn, xs, ys)
        if err < best_err:
            best_fn, best_err = fn, err
    if best_fn is None:                      # degenerate probes
        best_fn = experts.fit("affine", xs, ys)
        best_err = experts.relative_error(best_fn, xs, ys)
    return best_fn, float(best_err)


def predict_aux_curves(app: "AppProfile", xs: np.ndarray,
                       rng: Optional[np.random.Generator],
                       families: Sequence[str] = experts.FAMILIES,
                       skip: Tuple[str, ...] = ()
                       ) -> Tuple[Dict[str, MemoryFunction],
                                  Dict[str, float], Dict]:
    """PREDICT the side-car demand curves: probe each aux axis the
    workload exposes at the same calibration sizes as the primary curve
    and two-point-calibrate it.  ``net`` gets the simple linear
    contention curve (affine: bandwidth scales with the split); other
    axes pick the candidate family with the best relative probe fit.
    This replaces reading declared ``AppProfile.aux_demand`` curves
    straight into admission."""
    curves: Dict[str, MemoryFunction] = {}
    conf: Dict[str, float] = {}
    calib: Dict[str, List] = {}
    for axis in sorted(getattr(app, "aux_demand", {}) or {}):
        if axis in skip:
            continue
        ys = np.asarray([app.measure_axis(axis, float(x), rng)
                         for x in xs])
        if axis == "net":
            fn, err = _two_point_best(xs, ys, ("affine",))
        else:
            fn, err = _two_point_best(xs, ys, families)
        curves[axis] = fn
        conf[axis] = float(np.clip(1.0 - err / _AUX_ERR_SCALE, 0.0, 1.0))
        calib[axis] = list(zip(xs.tolist(), ys.tolist()))
    return curves, conf, calib


def _job_estimate(primary_fn: MemoryFunction, target: JobTarget,
                  xs: np.ndarray, rng, info: Dict,
                  primary_conf: float, conservative: bool,
                  families: Sequence[str] = experts.FAMILIES
                  ) -> DemandEstimate:
    """Assemble the multi-axis estimate: primary curve + predicted aux
    curves (probed AFTER the primary calibration, so workloads without
    aux axes keep the exact pre-estimator RNG stream)."""
    aux, aux_conf, aux_calib = predict_aux_curves(
        target.app, xs, rng, families, skip=(target.primary_axis,))
    curves = {target.primary_axis: primary_fn}
    curves.update(aux)
    conf = {target.primary_axis: primary_conf}
    conf.update(aux_conf)
    if aux_calib:
        info = {**info, "aux_calib": aux_calib,
                "aux_families": {a: fn.family for a, fn in aux.items()}}
    model = DemandModel(curves, primary_axis=target.primary_axis)
    # the demand-vs-slowdown curve rides the SAME calibrated primary
    # fit (no extra probes, no RNG draws); a conservative estimate is
    # never shrinkable — flat curve
    shrink = (SlowdownCurve.flat() if conservative
              else fit_slowdown_curve(primary_fn, target.units))
    return DemandEstimate(model, conf, conservative, info, shrink=shrink)


# ---------------------------------------------------------------------------
# Serving footprint calibration (owned by KVGrowthEstimator)
# ---------------------------------------------------------------------------

#: (config name, max_len) -> calibrated affine footprint-vs-batch fit.
#: The fit only depends on the abstract parameter/cache shapes, so
#: reuse is exact; ``refit=True`` bypasses (e.g. after editing a config
#: in-process).
_FOOTPRINT_CACHE: Dict[Tuple[str, int], MemoryFunction] = {}


def calibrate_model_footprint(cfg, max_len: int, *,
                              refit: bool = False) -> MemoryFunction:
    """Probe the model's abstract weights + KV cache at batch 2 and 4
    and two-point-solve the affine footprint-vs-batch curve (intercept =
    weights GB, slope = KV GB per request at ``max_len``), cached per
    ``(config name, max_len)`` with a one-line reused-vs-refit note."""
    # runtime-only imports: repro.sched must stay loadable before
    # repro.models
    from repro.models import model as model_lib
    from repro.utils.tree import tree_bytes

    key = (getattr(cfg, "name", repr(cfg)), int(max_len))
    fn = None if refit else _FOOTPRINT_CACHE.get(key)
    if fn is None:
        def fp(batch: int) -> float:
            w = tree_bytes(model_lib.abstract(cfg))
            c = model_lib.init_cache(cfg, batch, int(max_len),
                                     abstract_only=True)
            return (w + tree_bytes(c)) / 2 ** 30
        fn = experts.calibrate_two_point("affine", 2, fp(2), 4, fp(4))
        _FOOTPRINT_CACHE[key] = fn
        print(f"footprint calibration: fit {key[0]}@{max_len} "
              f"(weights {fn.m:.4f} GB + {fn.b:.5f} GB/slot)")
    else:
        print(f"footprint calibration: reused cached fit for "
              f"{key[0]}@{max_len}")
    return fn


def _measured_net_curve(net_probes) -> Tuple[Optional[float],
                                             Optional[Dict]]:
    """Learn the per-request net intensity from observed Transmission
    completions: fit duration-vs-bytes over the measured ``(gb, s)``
    probes with the SAME two-point family selection the aux axes use
    (the affine truth — link latency intercept + inverse-bandwidth
    slope — wins on clean data, but congested traces may genuinely
    curve), then read off the effective GB/s one in-flight request
    sustains at the mean observed transfer size.  Returns
    ``(confidence, info)`` — ``(None, None)`` when the probes cannot
    support a fit (fewer than two distinct sizes, degenerate fit)."""
    if not net_probes:
        return None, None
    pts = sorted({(float(x), float(y)) for x, y in net_probes
                  if float(x) > 0.0 and float(y) > 0.0})
    if len(pts) < 2 or pts[0][0] >= pts[-1][0]:
        return None, None
    xs = np.asarray([x for x, _ in pts])
    ys = np.asarray([y for _, y in pts])
    fit, err = _two_point_best(xs, ys, experts.FAMILIES)
    mean_gb = float(np.mean(xs))
    dur = float(fit(mean_gb))
    if dur <= 0.0:
        return None, None
    conf = float(np.clip(1.0 - err / _AUX_ERR_SCALE, 0.0, 1.0))
    return conf, {"family": fit.family,
                  "gbps_per_req": mean_gb / dur,
                  "err": float(err), "n_probes": len(pts)}


def _model_estimate(target: ModelTarget, *, pad: float = 1.0,
                    conservative: bool = False,
                    refit: bool = False,
                    probes: Optional[Sequence[Tuple[float, float]]] = None
                    ) -> DemandEstimate:
    """The serving demand model: the calibrated (or probe-supplied)
    affine footprint on ``hbm``, plus per-request side-car axes.  ``pad``
    inflates the KV slope and the side-cars (the conservative serving
    policy books headroom for the uncertain, growing parts; the weights
    intercept is exact and stays put)."""
    if probes is not None:
        fn = _fit_probes("affine", probes)
    else:
        fn = calibrate_model_footprint(target.cfg, target.max_len,
                                       refit=refit)
    if pad != 1.0:
        fn = MemoryFunction("affine", fn.m, fn.b * pad)
    curves: Dict[str, MemoryFunction] = {"hbm": fn}
    if target.host_ram_per_req_gb > 0.0:
        curves["host_ram"] = MemoryFunction(
            "affine", 0.0, float(target.host_ram_per_req_gb) * pad)
    if target.net_gbps_per_req > 0.0:
        # linear contention: egress bandwidth scales with in-flight
        # requests (unpadded — an average-rate axis, not OOM-able)
        curves["net"] = MemoryFunction(
            "affine", 0.0, float(target.net_gbps_per_req))
    net_conf, net_info = _measured_net_curve(
        getattr(target, "net_probes", None))
    if net_info is not None:
        curves["net"] = MemoryFunction(
            "affine", 0.0, net_info["gbps_per_req"])
    conf = {a: (0.0 if conservative else 1.0) for a in curves}
    if net_conf is not None:
        conf["net"] = net_conf        # measured, not declared
    info = {"family": "affine", "max_len": int(target.max_len),
            "pad": pad,
            "page_size": int(getattr(target, "page_size", 1))}
    if net_info is not None:
        info["net_measured"] = net_info
    # serving shrink: a request can join on a fraction of its KV
    # reservation, paying recompute/spill overhead per decode step —
    # the weights intercept is not shrinkable, so the declared linear
    # price covers only the growing KV share.  Conservative -> flat.
    shrink = (SlowdownCurve.flat() if conservative
              else SlowdownCurve.linear(1.6, min_fraction=0.5))
    return DemandEstimate(DemandModel(curves, primary_axis="hbm"),
                          conf, conservative, info, shrink=shrink)


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------

@register_estimator("moe")
class MoEEstimator(DemandEstimator):
    """The flagship: wraps a fitted
    :class:`~repro.core.predictor.MoEPredictor`.  Primary curve via the
    paper's select -> two-point-calibrate runtime path (identical RNG
    draw order — the pre-estimator results are pinned bit-identical);
    side-car axes *predicted* from profiled aux probes."""

    supports_online_update = True

    def __init__(self, predictor=None):
        if predictor is None or not hasattr(predictor, "select_family"):
            raise ValueError("the moe estimator wraps a fitted "
                             "MoEPredictor — pass predictor=")
        self.predictor = predictor

    @property
    def families(self):
        return self.predictor.families

    def select_family(self, features):
        return self.predictor.select_family(features)

    def partial_update(self, features, family) -> bool:
        return self.predictor.partial_update(features, family)

    def estimate(self, target, probes=None, *, rng=None):
        if isinstance(target, ModelTarget):
            return _model_estimate(target, probes=probes)
        from repro.core.predictor import calibration_points
        app = target.app
        if probes is not None:
            fam, dist, confident = self.predictor.select_family(
                app.features)
            fn = _fit_probes(fam, probes)
            xs = np.asarray(sorted(float(x) for x, _ in probes))
            info = {"family": fam, "distance": dist,
                    "confident": confident,
                    "calib": [list(p) for p in probes]}
        else:
            fn, info = self.predictor.predict_function(
                app, target.units, rng)
            confident = bool(info.get("confident", True))
            dist = float(info.get("distance", 0.0))
            xs = calibration_points(target.units)
        fb = max(getattr(self.predictor, "fallback_distance", 0.35),
                 1e-9)
        conf = float(np.clip(1.0 - dist / fb, 0.0, 1.0))
        return _job_estimate(fn, target, xs, rng, info, conf,
                             conservative=not confident,
                             families=self.predictor.families)


@register_estimator("oracle")
class OracleEstimator(DemandEstimator):
    """Prophetic: ground-truth curves on EVERY axis, no probing cost,
    confidence 1.0.  The schedule-dynamics-matched upper bound."""

    def __init__(self, predictor=None):
        pass                              # nothing to wrap

    def estimate(self, target, probes=None, *, rng=None):
        if isinstance(target, ModelTarget):
            return _model_estimate(target, probes=probes)
        app = target.app
        curves = {target.primary_axis: app.true_fn}
        for axis, fn in sorted((app.aux_demand or {}).items()):
            if axis != target.primary_axis:
                curves[axis] = fn
        conf = {a: 1.0 for a in curves}
        model = DemandModel(curves, primary_axis=target.primary_axis)
        return DemandEstimate(model, conf, False,
                              {"family": app.family, "oracle": True},
                              shrink=fit_slowdown_curve(app.true_fn,
                                                        target.units))


@register_estimator("single-family")
class SingleFamilyEstimator(DemandEstimator):
    """Fig. 9 baseline: ONE expert family for every application and
    every axis, calibrated on the 5%/10% probes (bit-identical to
    :class:`~repro.core.predictor.UnifiedFamilyPredictor`)."""

    def __init__(self, family: str = "power", predictor=None):
        if predictor is not None and hasattr(predictor, "family"):
            family = predictor.family
        if family not in experts.FAMILIES:
            raise ValueError(f"unknown family {family!r}")
        self.family = family
        self.families = (family,)

    def estimate(self, target, probes=None, *, rng=None):
        if isinstance(target, ModelTarget):
            return _model_estimate(target, probes=probes)
        app = target.app
        if probes is not None:
            fn = _fit_probes(self.family, probes)
            xs = np.asarray(sorted(float(x) for x, _ in probes))
        else:
            x1, x2 = 0.05 * target.units, 0.10 * target.units
            y1, y2 = app.measure(x1, rng), app.measure(x2, rng)
            fn = experts.calibrate_two_point(self.family, x1, y1, x2, y2)
            xs = np.asarray([x1, x2])
        return _job_estimate(fn, target, xs, rng,
                             {"family": self.family}, 0.5, False,
                             families=self.families)


@register_estimator("ann")
class ANNEstimator(DemandEstimator):
    """QUASAR-style monolithic baseline: wraps a fitted
    :class:`~repro.core.predictor.ANNPredictor` (one regressor over
    (features, x) -> y); aux axes probed + best-family fitted."""

    def __init__(self, predictor=None):
        if predictor is None or not hasattr(predictor, "_predict_log_y"):
            raise ValueError("the ann estimator wraps a fitted "
                             "ANNPredictor — pass predictor=")
        self.predictor = predictor

    def estimate(self, target, probes=None, *, rng=None):
        if isinstance(target, ModelTarget):
            return _model_estimate(target, probes=probes)
        from repro.core.predictor import calibration_points
        fn, info = self.predictor.predict_function(
            target.app, target.units, rng)
        xs = calibration_points(target.units)
        # a monolithic net carries no usable confidence signal
        return _job_estimate(fn, target, xs, rng, info, 0.5, False)


@register_estimator("conservative")
class ConservativeEstimator(DemandEstimator):
    """No learned selector: fit the probe curve with whichever family
    explains it best and ALWAYS flag the estimate conservative, so the
    scheduler applies its low-confidence shading (halved memory budgets,
    paper Section 6.9).  On serving targets there is no shading hook in
    the batcher, so the footprint's growing parts are padded by
    ``pad`` instead."""

    def __init__(self, predictor=None, pad: float = 1.25):
        self.pad = float(pad)

    def estimate(self, target, probes=None, *, rng=None):
        if isinstance(target, ModelTarget):
            return _model_estimate(target, pad=self.pad,
                                   conservative=True, probes=probes)
        from repro.core.predictor import calibration_points
        app = target.app
        if probes is not None:
            xs = np.asarray(sorted(float(x) for x, _ in probes))
            ys = np.asarray([y for _, y in
                             sorted((float(x), float(y))
                                    for x, y in probes)])
        else:
            xs = calibration_points(target.units)
            ys = np.asarray([app.measure(float(x), rng) for x in xs])
        fn, errs = experts.best_family(xs, ys, self.families)
        info = {"family": fn.family, "confident": False,
                "fit_errors": errs,
                "calib": list(zip(xs.tolist(), ys.tolist()))}
        return _job_estimate(fn, target, xs, rng, info, 0.0, True)


@register_estimator("kv-growth")
class KVGrowthEstimator(DemandEstimator):
    """The serving footprint estimator: owns the per-``(config,
    max_len)`` two-point affine calibration cache.
    ``DemandModel.from_model_config`` is now a deprecated shim over this
    (bit-identical: same cache, same curves)."""

    def __init__(self, predictor=None, refit: bool = False):
        self.refit = bool(refit)

    def estimate(self, target, probes=None, *, rng=None):
        if not isinstance(target, ModelTarget):
            raise TypeError("kv-growth estimates serving ModelTargets; "
                            "use moe/oracle/... for job targets")
        return _model_estimate(target, refit=self.refit, probes=probes)


class PredictorEstimator(DemandEstimator):
    """Last-resort adapter for any duck-typed ``predict_function``
    object (custom predictors keep working through the estimator API)."""

    name = "predictor"

    def __init__(self, predictor=None):
        if predictor is None:
            raise ValueError("pass predictor=")
        self.predictor = predictor

    def estimate(self, target, probes=None, *, rng=None):
        from repro.core.predictor import calibration_points
        fn, info = self.predictor.predict_function(
            target.app, target.units, rng)
        xs = calibration_points(target.units)
        conservative = not info.get("confident", True)
        return _job_estimate(fn, target, xs, rng, info,
                             0.0 if conservative else 0.5, conservative)
