"""Budget-inverse admission control (paper Sections 4.1/4.2), over
vector resource budgets.

The paper's runtime decides, per host, how much work to admit from a
predicted memory function: select an expert family, calibrate it on two
small probes, then invert it under the free-memory budget.  This module
owns that loop for every consumer (simulator policies, serving driver),
generalized from a single scalar GB budget to a
:class:`~repro.sched.resources.ResourceVector` over named axes
(``host_ram`` / ``cpu`` / ``hbm`` / ``net``): the admitted unit count is
the **min over per-axis inverses** of a :class:`DemandModel`, and the
decision records which axis bound it.

Since the DemandEstimator redesign the controller is built AROUND an
estimator instance (``repro.sched.estimator`` registry): ``estimate()``
produces the full multi-axis :class:`DemandModel` (with per-axis
confidence and the conservative flag) and ``admit_target()`` runs
estimate -> shade -> binding-axis inverse in one call.  The per-call
curve/scalar APIs below (``admit(fn, budget_gb)``, ``calibrate``) are
DEPRECATED shims kept bit-identical to the PR 2/3 paths: a bare curve
becomes a single-axis demand model, a bare float a single-axis budget
vector, and the same code path runs (goldens pinned by
``tests/test_resources.py`` / ``tests/test_estimator.py``).

Units are deliberately abstract ("units" = M-items for Spark jobs,
concurrent requests for the serving batch) — the controller only cares
that each per-axis curve ``fn(units) -> amount`` is monotone.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import experts
from repro.core.experts import MemoryFunction
from repro.sched.resources import (MEMORY_AXES, DemandModel, ResourceVector,
                                   single_axis)


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of a budget-inverse admission query."""
    units: float          # admitted work units (0 if nothing fits)
    mem_gb: float         # primary-axis booking for those units
    budget_gb: float      # primary-axis shaded budget the inverse ran on
    fn: Optional[MemoryFunction]  # calibrated primary curve (if any)
    info: Dict = field(default_factory=dict)
    binding_axis: Optional[str] = None   # axis that bound the inverse
    #   (None: the caller's cap bound first, or nothing constrained)
    booked: Optional[ResourceVector] = None  # full per-axis booking
    budget: Optional[ResourceVector] = None  # full shaded budget vector

    def __bool__(self) -> bool:
        return self.units > 0.0


class AdmissionController:
    """Owns predict -> two-point-calibrate -> budget-inverse admission.

    Stateless with respect to any particular host or request stream;
    scheduler policies keep one instance and feed it per-decision
    budgets (scalar GB or :class:`ResourceVector`)."""

    def __init__(self, safety_margin: float = 0.0,
                 conservative_factor: float = 0.5,
                 oom_backoff: float = 0.5, max_oom_shifts: int = 3,
                 estimator=None):
        """``estimator`` — a :class:`~repro.sched.estimator.
        DemandEstimator` instance or registry name; when set,
        :meth:`estimate` / :meth:`admit_target` run the full
        predict -> multi-axis-demand -> binding-axis-inverse pipeline
        through it."""
        self.safety_margin = float(safety_margin)
        self.conservative_factor = float(conservative_factor)
        self.oom_backoff = float(oom_backoff)
        self.max_oom_shifts = int(max_oom_shifts)
        if estimator is not None:
            from repro.sched.estimator import resolve_estimator
            estimator = resolve_estimator(estimator)
        self.estimator = estimator

    # --- the estimator pipeline ------------------------------------------
    def estimate(self, target, probes=None, *, rng=None):
        """Predicted multi-axis demand for ``target`` via the attached
        estimator (see ``repro.sched.estimator``)."""
        if self.estimator is None:
            raise RuntimeError(
                "this AdmissionController has no estimator attached — "
                "construct it with estimator=<instance or registry name>")
        return self.estimator.estimate(target, probes, rng=rng)

    def admit_target(self, target, free: Union[float, ResourceVector], *,
                     probes=None, rng=None, cap: float = np.inf,
                     floor: float = 0.0, book: bool = True,
                     safety_margin: Optional[float] = None,
                     oom_count: int = 0,
                     shading: str = "per-axis",
                     info: Optional[Dict] = None) -> AdmissionDecision:
        """The one-call pipeline: estimate the target's multi-axis
        demand, shade the free capacity by the scheduler's risk rules,
        and invert along the binding axis.

        ``shading`` selects the risk model:

        * ``"per-axis"`` (default) — each memory axis's budget is shaded
          by THAT axis's estimate confidence (full confidence leaves the
          axis unshaded, zero confidence reproduces the conservative
          halving, linear in between).  A well-predicted primary curve
          no longer pays for an uncertain side-car, and vice versa.
        * ``"scalar"`` — the deprecated pre-per-axis behaviour: the
          single ``conservative`` flag halves every memory axis.  Kept
          bit-identical (golden-pinned in ``tests/test_cluster.py``).
        """
        est = self.estimate(target, probes, rng=rng)
        if shading == "per-axis":
            budget = self.effective_budget(
                free, safety_margin=safety_margin,
                conservative=est.conservative, oom_count=oom_count,
                confidence=est.confidence)
        elif shading == "scalar":
            import warnings
            warnings.warn(
                "admit_target(shading='scalar') is deprecated — the "
                "default per-axis path shades each memory axis by its "
                "own DemandEstimate confidence",
                DeprecationWarning, stacklevel=2)
            budget = self.effective_budget(
                free, safety_margin=safety_margin,
                conservative=est.conservative, oom_count=oom_count)
        else:
            raise ValueError(f"unknown shading {shading!r} "
                             f"(choose from 'per-axis', 'scalar')")
        merged = {"estimate": est, **(info or {})}
        dec = self.admit(est.model, budget, cap=cap, floor=floor,
                         book=book, info=merged)
        # decision provenance: every admit_target decision records what
        # the inverse actually saw — raw free capacity, the shaded
        # budget, the per-axis confidence that shaded it, and the
        # binding axis.  info is the frozen dataclass's one mutable
        # field, so post-hoc enrichment is the supported idiom.
        dec.info["provenance"] = {
            "free": dict(free.items())
            if isinstance(free, ResourceVector) else float(free),
            "budget": dict(budget.items())
            if isinstance(budget, ResourceVector) else float(budget),
            "confidence": dict(est.confidence),
            "conservative": bool(est.conservative),
            "binding_axis": dec.binding_axis,
        }
        if "reject" in dec.info:
            dec.info["reject"]["confidence"] = dict(est.confidence)
        return dec

    # --- calibration (deprecated shim) -----------------------------------
    def calibrate(self, family: str,
                  probes: Sequence[Tuple[float, float]]) -> MemoryFunction:
        """DEPRECATED shim: estimators calibrate via ``estimate(target,
        probes)`` now; this delegates to the same implementation.
        Instantiate (m, b) from measured (x, y) probes — two probes use
        the paper's exact two-point solve, more fall back to the
        least-squares fit (same families, same guards)."""
        from repro.sched.estimator import _fit_probes
        return _fit_probes(family, probes)

    # --- budget shading --------------------------------------------------
    def effective_budget(self, free: Union[float, ResourceVector], *,
                         safety_margin: Optional[float] = None,
                         conservative: bool = False,
                         oom_count: int = 0,
                         confidence: Optional[Dict[str, float]] = None
                         ) -> Union[float, ResourceVector]:
        """Shade raw free capacity by the scheduler's risk rules: global
        safety margin, the low-confidence conservative fallback (paper
        Section 6.9), and exponential backoff after OOM kills (paper
        Section 2.3).

        On a :class:`ResourceVector`, only the memory axes
        (``host_ram``/``hbm``) are shaded — CPU and link bandwidth are
        average-rate resources where overshoot time-shares rather than
        OOM-kills, so risk shading does not apply.

        ``confidence`` (axis -> [0, 1], a
        :class:`~repro.sched.estimator.DemandEstimate`'s per-axis
        confidence) switches a memory axis from the binary conservative
        halving to a continuous shade::

            factor = conservative_factor + (1 - conservative_factor) * c

        so confidence 1.0 leaves the axis unshaded and confidence 0.0
        reproduces the halving exactly.  Memory axes absent from
        ``confidence`` (and the scalar float path) keep the legacy
        ``conservative`` flag behaviour."""
        margin = self.safety_margin if safety_margin is None \
            else float(safety_margin)
        shifts = min(int(oom_count), self.max_oom_shifts)

        def shade(v: float, conf: Optional[float] = None) -> float:
            budget = float(v) * (1.0 - margin)
            if conf is not None:
                cf = self.conservative_factor
                budget *= cf + (1.0 - cf) * min(max(float(conf), 0.0),
                                                1.0)
            elif conservative:
                budget *= self.conservative_factor
            budget *= self.oom_backoff ** shifts
            return budget

        if isinstance(free, ResourceVector):
            conf = confidence or {}
            return ResourceVector(**{
                a: (shade(v, conf.get(a)) if a in MEMORY_AXES else v)
                for a, v in free.items()})
        return shade(free)

    # --- budget-inverse admission ---------------------------------------
    @staticmethod
    def _normalize(demand: Union[MemoryFunction, DemandModel],
                   budget: Union[float, ResourceVector]
                   ) -> Tuple[DemandModel, ResourceVector]:
        """Scalar back-compat shim: a bare curve becomes a single-axis
        demand model, a bare float a single-axis budget vector on the
        demand's primary axis."""
        if isinstance(demand, DemandModel):
            dm = demand
        else:
            dm = DemandModel.scalar(demand)
        if isinstance(budget, ResourceVector):
            bv = budget
        else:
            bv = single_axis(dm.primary_axis, float(budget))
        return dm, bv

    @staticmethod
    def _book_vector(dm: DemandModel, units: float,
                     bv: ResourceVector) -> ResourceVector:
        """Per-axis booking for ``units``: predicted demand, clamped to
        the budget that admitted it.  Infinite admissions (a curve that
        saturates below its budget, with no cap) book the whole budgeted
        axis — the caller must bound the work some other way."""
        axes: Dict[str, float] = {}
        for a, fn in dm.curves.items():
            if not np.isfinite(units):
                axes[a] = bv[a] if a in bv else 0.0
                continue
            amount = float(fn(units))
            axes[a] = min(amount, bv[a]) if a in bv else amount
        for a, v in dm.fixed.items():
            axes[a] = axes.get(a, 0.0) + v
        return ResourceVector(**axes)

    def admit(self, demand: Union[MemoryFunction, DemandModel],
              budget: Union[float, ResourceVector], *,
              cap: float = np.inf, floor: float = 0.0,
              book: bool = True,
              info: Optional[Dict] = None) -> AdmissionDecision:
        """Largest ``units <= cap`` whose demand fits ``budget`` on every
        budgeted axis (min over per-axis inverses); zero-unit decision
        when that falls below ``floor``.  The decision records the
        ``binding_axis`` — ``None`` when the caller's ``cap`` (or
        nothing) bound first.

        ``book=False`` skips the booked-demand evaluation (``mem_gb``
        reads 0.0, ``booked`` is None) for callers that only size —
        e.g. the simulator's per-(job, host) candidate scan, which books
        separately after adjusting the unit count."""
        dm, bv = self._normalize(demand, budget)
        primary = dm.primary_axis
        budget_gb = float(bv.get(primary, np.inf))
        raw, binding = dm.inverse(bv)
        units = float(min(raw, cap))
        if units < raw:
            binding = None                     # the cap bound first
        if units <= 0.0 or units < floor - 1e-12:
            # structured reject reason: which axis bound, how short the
            # budget falls of the smallest useful grant, so callers /
            # metrics never see a silent zero-unit decision
            info_d = dict(info or {})
            floor_u = max(float(floor), 1.0)
            need = dm.demand(floor_u)
            deficit = {a: float(v - bv[a]) for a, v in need.items()
                       if a in bv and v > bv[a] + 1e-12}
            axis = binding
            if axis is None and deficit:
                axis = max(deficit, key=deficit.get)
            info_d["reject"] = {
                "axis": axis,
                "units": units,
                "floor": float(floor),
                "deficit": deficit,
                # requeue-vs-new provenance: callers that re-offer
                # preempted work pass info={"origin": "requeue"} so
                # per-tenant reject accounting doesn't double-count
                # preemption churn as fresh demand mis-prediction
                "origin": info_d.get("origin", "new"),
            }
            return AdmissionDecision(0.0, 0.0, budget_gb, dm.primary_fn,
                                     info_d, binding, None, bv)
        if book:
            booked = self._book_vector(dm, units, bv)
            mem = booked.get(primary, 0.0)
        else:
            booked, mem = None, 0.0
        return AdmissionDecision(units, mem, budget_gb, dm.primary_fn,
                                 dict(info or {}), binding, booked, bv)

    def shrink_target(self, demand: Union[MemoryFunction, DemandModel],
                      budget: Union[float, ResourceVector], *,
                      units: float, curve, elastic,
                      book: bool = True,
                      info: Optional[Dict] = None) -> AdmissionDecision:
        """Spill-aware shrink admission: when ``units`` of work does NOT
        fit ``budget`` outright, walk the binding memory axis down to
        the largest demand **fraction** that fits, price that fraction
        on the workload's demand-vs-slowdown ``curve``
        (:class:`~repro.sched.elastic.SlowdownCurve`), and let
        ``elastic`` (:class:`~repro.sched.elastic.ElasticController`)
        decide shrink-vs-wait-vs-reject.

        On **shrink** the decision books the shrunken vector — memory
        axes scaled by the granted fraction, average-rate axes (cpu,
        net) unscaled — and carries ``info["shrink"] = {fraction,
        slowdown, axis}``; THE CALLER must charge the slowdown into
        virtual time (executor rate, decode-step cost): a shrunken
        grant runs on less memory by paying time, never silently.  On
        **wait**/**reject** the zero-unit decision carries the usual
        structured ``info["reject"]`` plus ``info["elastic"]`` with the
        verdict, so telemetry can tell "priced too high" from "would
        not fit at any price"."""
        from repro.sched.elastic import ElasticDecision, shrink_vector
        dm, bv = self._normalize(demand, budget)
        primary = dm.primary_axis
        budget_gb = float(bv.get(primary, np.inf))
        units = float(units)
        info_d = dict(info or {})
        need = dm.demand(units)

        def _zero(verdict: ElasticDecision, axis, fraction: float
                  ) -> AdmissionDecision:
            deficit = {a: float(v - bv[a]) for a, v in need.items()
                       if a in bv and v > bv[a] + 1e-12}
            info_d["elastic"] = {"action": verdict.action,
                                 "fraction": float(fraction),
                                 "slowdown": float(verdict.slowdown)}
            info_d["reject"] = {
                "axis": axis, "units": 0.0, "floor": units,
                "deficit": deficit,
                "origin": info_d.get("origin", "new"),
            }
            return AdmissionDecision(0.0, 0.0, budget_gb, dm.primary_fn,
                                     info_d, axis, None, bv)

        # average-rate axes don't shrink: if cpu / link demand already
        # exceeds its budget, no memory cut helps
        for a, v in need.items():
            if a not in MEMORY_AXES and a in bv and v > bv[a] + 1e-12:
                return _zero(ElasticDecision("wait", 0.0, float("inf")),
                             a, 0.0)
        # largest memory fraction that fits = min over budgeted memory
        # axes of budget/demand; the argmin is the binding axis the
        # curve is walked down
        fraction, binding = 1.0, None
        for a, v in need.items():
            if a in MEMORY_AXES and a in bv and v > 1e-12:
                r = float(bv[a]) / float(v)
                if r < fraction:
                    fraction, binding = r, a
        verdict = elastic.decide(curve, fraction)
        if verdict.action != "shrink":
            return _zero(verdict, binding, fraction)
        if book:
            # scale the RAW demand by the granted fraction, THEN clamp
            # to the budget (shrink_vector on a pre-clamped booking
            # would double-shrink the binding axis)
            booked = shrink_vector(need, verdict.fraction)
            booked = ResourceVector(**{
                a: (min(v, bv[a]) if a in bv else v)
                for a, v in booked.items()})
            mem = booked.get(primary, 0.0)
        else:
            booked, mem = None, 0.0
        info_d["shrink"] = {"fraction": float(verdict.fraction),
                            "slowdown": float(verdict.slowdown),
                            "axis": binding}
        return AdmissionDecision(units, mem, budget_gb, dm.primary_fn,
                                 info_d, binding, booked, bv)

    def book(self, fn: MemoryFunction, units: float,
             budget_gb: float) -> float:
        """Primary-axis memory to reserve for ``units``: the predicted
        footprint, never more than the budget that admitted it."""
        return min(float(fn(units)), float(budget_gb))

    def admit_batch(self, demand: Union[MemoryFunction, DemandModel],
                    budget: Union[float, ResourceVector], *,
                    min_batch: int = 1,
                    max_batch: Optional[int] = None) -> AdmissionDecision:
        """Integer variant for request serving: whole requests only,
        always at least ``min_batch`` (a server must make progress even
        when the model barely fits).  When the forced minimum does NOT
        fit the budget, the decision carries ``info['forced'] = True`` so
        callers can log over-budget forced progress instead of booking
        it silently.

        An UNBOUNDED admission (every budgeted curve saturates below its
        budget) requires an explicit ``max_batch`` — silently returning
        a huge batch would be a foot-gun for any non-affine footprint."""
        dm, bv = self._normalize(demand, budget)
        cap = np.inf if max_batch is None else float(max_batch)
        dec = self.admit(dm, bv, cap=cap)
        if not np.isfinite(dec.units):
            fam = dec.fn.family if dec.fn is not None else "?"
            raise ValueError(
                f"unbounded admission: {fam} footprint saturates below "
                f"the {dec.budget_gb} GB {dm.primary_axis} budget — "
                f"pass max_batch")
        n = int(dec.units)
        if max_batch is not None:
            n = min(n, int(max_batch))
        n = max(n, int(min_batch))
        need = dm.demand(n)
        forced_axes = [a for a, v in need.items()
                       if a in bv and v > bv[a] + 1e-9]
        booked = self._book_vector(dm, float(n), bv)
        return AdmissionDecision(
            float(n), booked.get(dm.primary_axis, 0.0), dec.budget_gb,
            dec.fn, {**dec.info, "forced": bool(forced_axes),
                     "forced_axes": forced_axes,
                     "demand": need.as_dict(),
                     "min_batch": min_batch},
            dec.binding_axis, booked, bv)
