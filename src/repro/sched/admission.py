"""Memory-budgeted admission control (paper Sections 4.1/4.2).

The paper's runtime decides, per host, how much work to admit from a
predicted memory function: select an expert family, calibrate it on two
small probes, then invert it under the free-memory budget. The cluster
simulator's policies and the serving driver both consumed private copies
of this logic; :class:`AdmissionController` is the single shared owner.

Units are deliberately abstract ("units" = M-items for Spark jobs,
concurrent requests for the serving batch) — the controller only cares
that ``fn(units) -> GB`` is monotone.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import experts
from repro.core.experts import MemoryFunction


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of a budget-inverse admission query."""
    units: float          # admitted work units (0 if nothing fits)
    mem_gb: float         # memory booked for those units (<= budget_gb)
    budget_gb: float      # the shaded budget the inverse ran against
    fn: MemoryFunction    # the calibrated function used
    info: Dict = field(default_factory=dict)

    def __bool__(self) -> bool:
        return self.units > 0.0


class AdmissionController:
    """Owns predict -> two-point-calibrate -> budget-inverse admission.

    Stateless with respect to any particular host or request stream;
    scheduler policies keep one instance and feed it per-decision budgets.
    """

    def __init__(self, safety_margin: float = 0.0,
                 conservative_factor: float = 0.5,
                 oom_backoff: float = 0.5, max_oom_shifts: int = 3):
        self.safety_margin = float(safety_margin)
        self.conservative_factor = float(conservative_factor)
        self.oom_backoff = float(oom_backoff)
        self.max_oom_shifts = int(max_oom_shifts)

    # --- calibration -----------------------------------------------------
    def calibrate(self, family: str,
                  probes: Sequence[Tuple[float, float]]) -> MemoryFunction:
        """Instantiate (m, b) from measured (x, y) probes.

        Two probes use the paper's exact two-point solve; more probes fall
        back to the least-squares fit (same families, same guards)."""
        probes = sorted((float(x), float(y)) for x, y in probes)
        if len(probes) < 2:
            raise ValueError("calibration needs at least two probes")
        if len(probes) == 2:
            (x1, y1), (x2, y2) = probes
            return experts.calibrate_two_point(family, x1, y1, x2, y2)
        xs, ys = zip(*probes)
        return experts.fit(family, xs, ys)

    # --- budget shading --------------------------------------------------
    def effective_budget(self, free_gb: float, *,
                         safety_margin: Optional[float] = None,
                         conservative: bool = False,
                         oom_count: int = 0) -> float:
        """Shade raw free memory by the scheduler's risk rules: global
        safety margin, the low-confidence conservative fallback (paper
        Section 6.9), and exponential backoff after OOM kills (paper
        Section 2.3)."""
        margin = self.safety_margin if safety_margin is None \
            else float(safety_margin)
        budget = float(free_gb) * (1.0 - margin)
        if conservative:
            budget *= self.conservative_factor
        budget *= self.oom_backoff ** min(int(oom_count),
                                          self.max_oom_shifts)
        return budget

    # --- budget-inverse admission ---------------------------------------
    def admit(self, fn: MemoryFunction, budget_gb: float, *,
              cap: float = np.inf, floor: float = 0.0,
              book: bool = True,
              info: Optional[Dict] = None) -> AdmissionDecision:
        """Largest ``units <= cap`` with ``fn(units) <= budget_gb``;
        zero-unit decision when that falls below ``floor``. An infinite
        result (curve saturates below the budget AND no cap) books the
        whole budget — the caller must bound the work some other way.

        ``book=False`` skips the booked-memory evaluation (``mem_gb``
        reads 0.0) for callers that only size — e.g. the simulator's
        per-(job, host) candidate scan, which books separately after
        adjusting the unit count."""
        budget_gb = float(budget_gb)
        units = float(min(fn.inverse(budget_gb), cap))
        if units <= 0.0 or units < floor - 1e-12:
            return AdmissionDecision(0.0, 0.0, budget_gb, fn,
                                     dict(info or {}))
        if not book:
            mem = 0.0
        elif np.isfinite(units):
            mem = self.book(fn, units, budget_gb)
        else:
            mem = budget_gb
        return AdmissionDecision(units, mem, budget_gb, fn,
                                 dict(info or {}))

    def book(self, fn: MemoryFunction, units: float,
             budget_gb: float) -> float:
        """Memory to reserve for ``units``: the predicted footprint,
        never more than the budget that admitted it."""
        return min(float(fn(units)), float(budget_gb))

    def admit_batch(self, fn: MemoryFunction, budget_gb: float, *,
                    min_batch: int = 1,
                    max_batch: Optional[int] = None) -> int:
        """Integer variant for request serving: whole requests only,
        always at least ``min_batch`` (a server must make progress even
        when the model barely fits).

        An UNBOUNDED admission (the curve saturates below the budget)
        requires an explicit ``max_batch`` — silently returning a huge
        batch would be a foot-gun for any non-affine footprint."""
        cap = np.inf if max_batch is None else float(max_batch)
        dec = self.admit(fn, budget_gb, cap=cap)
        if not np.isfinite(dec.units):
            raise ValueError(
                f"unbounded admission: {fn.family} footprint saturates "
                f"below the {budget_gb} GB budget — pass max_batch")
        n = int(dec.units)
        if max_batch is not None:
            n = min(n, int(max_batch))
        return max(n, int(min_batch))
