"""The paper's contribution: mixture-of-experts memory prediction.

Offline (``fit``): profile each training program across input sizes, fit
every expert family, label the program with the best one; learn the
[0,1] feature scaler, PCA projection, and the KNN expert selector.

Runtime (``predict_function``): extract the target's features (100MB-ish
probe), scale + project, KNN-select the family (distance = confidence;
beyond ``fallback_distance`` the scheduler uses a conservative policy),
then two-point-calibrate (5%/10% probes) to instantiate (m, b).

Unified baselines for Fig. 9 / QUASAR: single-family predictors and an
ANN regressor over (features, x) -> y.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import experts
from repro.core.classifiers import KNN, MLP
from repro.core.experts import MemoryFunction
from repro.core.pca import PCA, Scaler
from repro.core.workloads import AppProfile

PROFILE_SIZES = (0.3, 3.0, 30.0, 100.0, 300.0, 1000.0)  # M-items sweep


def calibration_points(total_items: float) -> np.ndarray:
    """The runtime calibration sizes (paper Section 4.1): the ~100MB
    feature-extraction probe plus the 5% and 10% runs.  Shared with
    ``repro.sched.estimator`` so predicted side-car curves are probed at
    exactly the same input sizes as the primary memory curve."""
    return np.asarray([min(0.1, 0.01 * total_items),
                       0.05 * total_items, 0.10 * total_items])


def profile_curve(app: AppProfile, rng: np.random.Generator,
                  sizes: Sequence[float] = PROFILE_SIZES
                  ) -> Tuple[np.ndarray, np.ndarray]:
    xs = np.asarray(sizes, float)
    ys = np.asarray([app.measure(x, rng) for x in xs])
    return xs, ys


@dataclass
class MoEPredictor:
    families: Sequence[str] = experts.FAMILIES
    knn_k: int = 1
    fallback_distance: float = 0.35
    # online-row hygiene: a new row whose features sit within
    # ``dedupe_tol`` (RMS per-dim distance, raw feature space) of an
    # existing row with the SAME family adds no information — drop it;
    # and at most ``max_online_rows`` online rows are kept, evicting the
    # OLDEST online row first (offline training rows are never evicted)
    dedupe_tol: float = 0.05
    max_online_rows: int = 256
    scaler: Optional[Scaler] = None
    pca: Optional[PCA] = None
    knn: Optional[KNN] = None
    train_labels: Dict[str, str] = field(default_factory=dict)
    # raw (features, family) rows backing the KNN table — kept so online
    # partial updates can re-project when the scaler envelope widens
    _X_raw: Optional[np.ndarray] = None
    _y_raw: Optional[np.ndarray] = None
    _n_fit: int = 0                    # offline rows; rows beyond are online

    def fit(self, train_apps: List[AppProfile], seed: int = 0
            ) -> "MoEPredictor":
        rng = np.random.default_rng(seed)
        X, y = [], []
        for app in train_apps:
            xs, ys = profile_curve(app, rng)
            fn, _ = experts.best_family(xs, ys, self.families)
            self.train_labels[app.name] = fn.family
            X.append(app.features)
            y.append(fn.family)
        X = np.asarray(X, float)
        self._X_raw = X
        self._y_raw = np.asarray(y)
        self._n_fit = len(X)
        self.scaler = Scaler.fit(X)
        Xs = self.scaler.transform(X)
        self.pca = PCA.fit(Xs, n_components=min(5, Xs.shape[1]))
        self.knn = KNN(k=self.knn_k).fit(self.pca.transform(Xs),
                                         np.asarray(y))
        return self

    @property
    def n_online_rows(self) -> int:
        return len(self._X_raw) - self._n_fit if self._X_raw is not None \
            else 0

    def _is_duplicate(self, f: np.ndarray, family: str) -> bool:
        same = self._y_raw == family
        if not np.any(same):
            return False
        d = self._X_raw[same] - f[None, :]
        rms = np.sqrt(np.mean(d * d, axis=1))
        return bool(np.min(rms) <= self.dedupe_tol)

    def _drop_row(self, idx: int) -> None:
        """Remove row ``idx`` from the raw table AND the projected KNN
        table (rows correspond 1:1 in both append and rebuild paths)."""
        self._X_raw = np.delete(self._X_raw, idx, axis=0)
        self._y_raw = np.delete(self._y_raw, idx)
        self.knn.X = np.delete(self.knn.X, idx, axis=0)
        self.knn.y = np.delete(self.knn.y, idx)

    def partial_update(self, features: np.ndarray, family: str) -> bool:
        """Online refresh hook (used by repro.sched.online): fold ONE
        newly profiled program into the selector without a full refit —
        no re-profiling of training programs, no PCA re-fit.  Returns
        False when the row was dropped as a near-duplicate.

        The new row is appended to the KNN table; if it falls outside
        the training envelope, the [0,1] scaler bounds widen and the
        stored rows are re-projected through the FIXED PCA basis (an
        O(n*d) matrix multiply).  The table is bounded: a row within
        ``dedupe_tol`` of an existing same-family row is rejected, and
        beyond ``max_online_rows`` online rows the oldest online row is
        evicted (training rows are permanent)."""
        if self.knn is None:
            raise RuntimeError("partial_update() requires a fitted "
                               "predictor")
        f = np.asarray(features, float)
        if self._is_duplicate(f, family):
            return False
        if self.max_online_rows <= 0:
            return False                   # online rows disabled
        if self.n_online_rows >= self.max_online_rows:
            self._drop_row(self._n_fit)    # oldest online row
        self._X_raw = np.vstack([self._X_raw, f[None, :]])
        self._y_raw = np.append(self._y_raw, family)
        lo = np.minimum(self.scaler.lo, f)
        hi = np.maximum(self.scaler.hi, f)
        if np.any(lo < self.scaler.lo) or np.any(hi > self.scaler.hi):
            # a wider envelope CONTRACTS every scaled coordinate, so KNN
            # distances shrink against the fixed confidence threshold —
            # shrink the threshold by the same (geometric-mean) factor
            # or a second unseen cluster would suddenly look "near" and
            # lose the paper's distance-based soundness fallback
            old_span = np.maximum(self.scaler.hi - self.scaler.lo, 1e-12)
            new_span = np.maximum(hi - lo, 1e-12)
            self.fallback_distance *= float(
                np.exp(np.mean(np.log(old_span / new_span))))
            self.scaler = Scaler(lo=lo, hi=hi)
            Z = self.pca.transform(self.scaler.transform(self._X_raw))
            self.knn = KNN(k=self.knn_k).fit(Z, self._y_raw)
        else:
            z = self.pca.transform(self.scaler.transform(f[None, :]))
            self.knn.X = np.vstack([self.knn.X, z])
            self.knn.y = np.append(self.knn.y, family)
        return True

    # --- runtime ---------------------------------------------------------
    def select_family(self, features: np.ndarray
                      ) -> Tuple[str, float, bool]:
        """Returns (family, distance, confident)."""
        Z = self.pca.transform(
            self.scaler.transform(features[None, :]))
        labels, dist = self.knn.predict_with_confidence(Z)
        return str(labels[0]), float(dist[0]), float(dist[0]) <= \
            self.fallback_distance

    def predict_function(self, app: AppProfile, total_items: float,
                         rng: np.random.Generator
                         ) -> Tuple[MemoryFunction, Dict]:
        """Full runtime path: select family, then calibrate on the 5% and
        10% probes (paper Section 4.1) PLUS the ~100MB feature-extraction
        probe, whose footprint was measured anyway — the extra small-x
        anchor pins the curve in the per-executor-allocation regime
        (two knee-region points alone extrapolate poorly; measured:
        large exp-saturation jobs over-provisioned ~2x at chunk scale)."""
        fam, dist, confident = self.select_family(app.features)
        xs = calibration_points(total_items)
        ys = np.asarray([app.measure(x, rng) for x in xs])
        fn = experts.fit(fam, xs, ys)
        info = {"family": fam, "distance": dist, "confident": confident,
                "calib": list(zip(xs.tolist(), ys.tolist()))}
        return fn, info


@dataclass
class UnifiedFamilyPredictor:
    """Fig. 9 baseline: ONE family for every application."""
    family: str

    def predict_function(self, app: AppProfile, total_items: float,
                         rng: np.random.Generator
                         ) -> Tuple[MemoryFunction, Dict]:
        x1, x2 = 0.05 * total_items, 0.10 * total_items
        y1, y2 = app.measure(x1, rng), app.measure(x2, rng)
        fn = experts.calibrate_two_point(self.family, x1, y1, x2, y2)
        return fn, {"family": self.family}

    def fit(self, train_apps, seed: int = 0):
        return self


@dataclass
class ANNPredictor:
    """Fig. 9's strongest unified baseline / QUASAR's estimator: a neural
    net regressor over (features, log-x) -> log-y trained on the training
    programs' curves. One monolithic model — exactly what the paper argues
    cannot capture diverse behaviors."""
    hidden: Tuple[int, ...] = (64, 32)
    epochs: int = 600
    lr: float = 0.01
    _mlp: Optional[MLP] = None
    _W: Optional[list] = None
    scaler: Optional[Scaler] = None
    _ymean: float = 0.0
    _ystd: float = 1.0

    def fit(self, train_apps: List[AppProfile], seed: int = 0
            ) -> "ANNPredictor":
        rng = np.random.default_rng(seed)
        X, t = [], []
        feats = np.asarray([a.features for a in train_apps])
        self.scaler = Scaler.fit(feats)
        for app in train_apps:
            xs, ys = profile_curve(app, rng)
            f = self.scaler.transform(app.features[None, :])[0]
            for x, y in zip(xs, ys):
                X.append(np.concatenate([f, [np.log(x)]]))
                t.append(np.log(max(y, 1e-6)))
        X = np.asarray(X, float)
        t = np.asarray(t, float)
        self._ymean, self._ystd = float(t.mean()), float(t.std() + 1e-9)
        tn = (t - self._ymean) / self._ystd
        # tiny numpy MLP regressor (Adam, MSE)
        sizes = [X.shape[1], *self.hidden, 1]
        rg = np.random.default_rng(seed)
        W = [(rg.normal(0, np.sqrt(2 / sizes[i]), (sizes[i], sizes[i + 1])),
              np.zeros(sizes[i + 1])) for i in range(len(sizes) - 1)]
        mom = [(np.zeros_like(w), np.zeros_like(b), np.zeros_like(w),
                np.zeros_like(b)) for w, b in W]
        for step in range(1, self.epochs + 1):
            acts = [X]
            for li, (w, b) in enumerate(W):
                z = acts[-1] @ w + b
                acts.append(np.maximum(z, 0) if li < len(W) - 1 else z)
            delta = (acts[-1][:, 0] - tn)[:, None] * (2.0 / len(X))
            grads = []
            for li in reversed(range(len(W))):
                w, b = W[li]
                grads.append((li, acts[li].T @ delta, delta.sum(0)))
                if li > 0:
                    delta = (delta @ w.T) * (acts[li] > 0)
            for li, gw, gb in grads:
                w, b = W[li]
                mw, mb, vw, vb = mom[li]
                mw = 0.9 * mw + 0.1 * gw
                mb = 0.9 * mb + 0.1 * gb
                vw = 0.999 * vw + 0.001 * gw ** 2
                vb = 0.999 * vb + 0.001 * gb ** 2
                mom[li] = (mw, mb, vw, vb)
                bc1, bc2 = 1 - 0.9 ** step, 1 - 0.999 ** step
                W[li] = (w - self.lr * (mw / bc1)
                         / (np.sqrt(vw / bc2) + 1e-8),
                         b - self.lr * (mb / bc1)
                         / (np.sqrt(vb / bc2) + 1e-8))
        self._W = W
        return self

    def _predict_log_y(self, features: np.ndarray, x: float) -> float:
        f = self.scaler.transform(features[None, :])[0]
        a = np.concatenate([f, [np.log(max(x, 1e-9))]])[None, :]
        for li, (w, b) in enumerate(self._W):
            a = a @ w + b
            if li < len(self._W) - 1:
                a = np.maximum(a, 0)
        return float(a[0, 0]) * self._ystd + self._ymean

    def predict_function(self, app: AppProfile, total_items: float,
                         rng: np.random.Generator
                         ) -> Tuple[MemoryFunction, Dict]:
        """Sample the net once on a log grid and return a fast
        interpolating curve (keeps the scheduler interface uniform)."""
        grid = np.geomspace(1e-4, max(total_items * 2, 1.0), 64)
        logy = np.asarray([self._predict_log_y(app.features, xi)
                           for xi in grid])
        return SampledFn(np.log(grid), logy), {"family": "ann"}


class SampledFn(MemoryFunction):
    """Monotone-ish log-log interpolated curve (see ANNPredictor)."""

    def __init__(self, logx, logy):
        object.__setattr__(self, "family", "ann")
        object.__setattr__(self, "m", 0.0)
        object.__setattr__(self, "b", 0.0)
        object.__setattr__(self, "logx", logx)
        object.__setattr__(self, "logy", logy)

    def __call__(self, x):
        lx = np.log(np.maximum(np.asarray(x, float), 1e-12))
        out = np.exp(np.interp(lx, self.logx, self.logy))
        return out if np.ndim(x) else float(out)

    def inverse(self, y: float, x_hint: float = 1.0) -> float:
        ys = np.exp(self.logy)
        # first grid point exceeding the budget (curve may be non-monotone)
        over = np.nonzero(ys > y)[0]
        if len(over) == 0:
            return np.inf
        if over[0] == 0:
            return 0.0
        i = over[0]
        # log-linear interpolation between grid points i-1 and i
        ly = np.log(max(y, 1e-12))
        t = (ly - self.logy[i - 1]) / max(
            self.logy[i] - self.logy[i - 1], 1e-12)
        return float(np.exp(self.logx[i - 1]
                            + t * (self.logx[i] - self.logx[i - 1])))


class OraclePredictor:
    """Prophetic: returns the ground-truth function, no profiling cost."""

    def fit(self, train_apps, seed: int = 0):
        return self

    def predict_function(self, app: AppProfile, total_items: float,
                         rng: np.random.Generator
                         ) -> Tuple[MemoryFunction, Dict]:
        return app.true_fn, {"family": app.family, "oracle": True}
