"""Event-driven cluster simulator for memory-aware task co-location.

Reproduces the paper's evaluation mechanics: jobs arrive (batch at t=0
FCFS, or as an open arrival stream via ``arrivals=``), profile while
waiting (feature probe + 5%/10% calibration runs, whose processed items
CREDIT the job — no wasted cycles), then a dispatcher spawns executors
on hosts with spare memory and CPU headroom. Memory mis-prediction has
real consequences: moderate over-subscription causes paging (host-wide
slowdown), large overflow OOM-kills the executor and its items are
re-queued (paper Section 2.3).

Admission sizing (predict -> calibrate -> budget-inverse along the
binding axis of a vector budget: primary memory, CPU slack, secondary
axes) is owned by ``repro.sched.admission.AdmissionController`` — the
same controller the serving driver uses.  Queue ordering and host-scan
order come from the ``repro.sched.placement`` registry
(``SimConfig.placement``: fcfs / sjf / best-fit / arrival-aware);
policies only decide the budget each host offers and how to size under
it.

Policies: OURS (mixture-of-experts), QUASAR-like (single ANN estimator),
PAIRWISE (<=2 per host, claims all free memory), ONLINE-SEARCH (probing
overhead), ORACLE (ground truth, no profiling).

Fault tolerance (optional): Poisson host failures re-queue non-check-
pointed work; straggler executors get speculative backups.

Rates are piecewise-constant between events; every host-state change
re-times that host's executors (lazy re-heap with version counters).

Since the ClusterRuntime redesign the event clock, heap, and per-host
booked-capacity ledger live on the shared
``repro.sched.cluster`` substrate (the same one the serving engine's
replicas run on): the simulator registers arrive/profiled/finish/fail
handlers on a :class:`~repro.sched.cluster.ClusterRuntime` and
``Simulator.run`` is a thin shim over ``runtime.run`` — pinned
bit-identical to the pre-runtime loop by ``tests/test_cluster.py``.
"""
from __future__ import annotations

import itertools
import os
import warnings
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.core.experts import MemoryFunction
from repro.core.workloads import AppProfile
# cluster/resources/placement are import-cycle-free (they never import
# repro.core); admission/estimator are NOT — see the lazy imports in
# Policy.__init__ / Policy.bind
from repro.sched.cluster import ClusterRuntime, ClusterState, Node
from repro.sched.placement import get_placement
from repro.sched.resources import DemandModel, ResourceVector

if TYPE_CHECKING:  # runtime import is lazy: repro.sched.admission
    # imports repro.core (experts), so importing it back at module
    # scope would be circular when repro.sched loads first
    from repro.sched.admission import AdmissionController
    from repro.sched.estimator import DemandEstimate


def _default_placement() -> str:
    # benchmarks/run.py --placement selects the queue/host-scan order
    # for every SimConfig a bench module builds, without threading an
    # argument through each of them
    return os.environ.get("REPRO_PLACEMENT", "fcfs")


def _default_estimator() -> str:
    # benchmarks/run.py --estimator sweeps the demand estimator the same
    # way; "" means "wrap the policy's own predictor" (the faithful
    # bit-identical default)
    return os.environ.get("REPRO_ESTIMATOR", "")


@dataclass
class SimConfig:
    n_hosts: int = 40
    host_mem_gb: float = 64.0
    paging_slowdown: float = 8.0
    oom_overflow_frac: float = 0.25   # overflow beyond this -> OOM kill
    oom_waste_frac: float = 0.10      # runtime wasted by a killed executor
    profile_frac_lo: float = 0.08     # profiling time as a fraction of C_is
    profile_frac_hi: float = 0.15
    # items processed during profiling run at SINGLE-executor rate and
    # credit the job (paper: "no computing cycle is wasted") — a small,
    # honest credit, not a head start.
    profile_single_host: bool = True
    safety_margin: float = 0.0
    min_alloc_gb: float = 2.0
    tasks_per_slot: int = 4           # Spark task granularity per host slot
    pairwise_default_heap: float = 0.5  # primary executor's default claim
    cpu_slack: float = 1.15           # admit while sum(load) <= slack
    #   (loads are AVERAGES; transient >100% just time-shares — the
    #    proportional slowdown model charges for it)
    online_search_eta: float = 0.30   # ONLINE-SEARCH probe overhead
    online_alloc_lo: float = 0.65     # ONLINE-SEARCH allocation quality
    # fault tolerance
    failures: bool = False
    host_mtbf_s: float = 0.0          # 0 -> no failures
    repair_time_s: float = 300.0
    checkpoint_interval_s: float = 60.0
    straggler_prob: float = 0.0
    straggler_factor: float = 0.35
    speculative_backup: bool = True
    max_sim_time: float = 1e9
    # --- vector-resource admission ------------------------------------
    # The axis ``host_mem_gb`` capacitates and the calibrated memory
    # function predicts.  The paper's clusters budget host RAM; the
    # TPU-jobs universe budgets pod HBM (primary_axis="hbm") with host
    # staging RAM as a secondary axis in extra_capacity.
    primary_axis: str = "host_ram"
    # additional per-host axis capacities, e.g. {"host_ram": 96.0} when
    # the primary axis is hbm; jobs demand them via AppProfile.aux_demand
    extra_capacity: Dict[str, float] = field(default_factory=dict)
    # queue-ordering / host-scan policy (repro.sched.placement registry)
    placement: str = field(default_factory=_default_placement)
    # demand estimator (repro.sched.estimator registry: moe / oracle /
    # single-family / ann / conservative) for estimator-sweepable
    # policies (OURS; baselines keep their defining predictors).
    # "" = wrap the policy's own predictor — bit-identical to the
    # pre-estimator behaviour
    estimator: str = field(default_factory=_default_estimator)
    # --- network topology (repro.sched.topology) ----------------------
    # preset name ("single-switch" / "two-rack" / "ring"); "" = no
    # fabric — every pre-topology schedule stays bit-identical.  With a
    # fabric bound and stage_gb_per_item > 0, each spawned executor's
    # input stages from the topology's ingress as a real Transmission
    # and the executor only starts processing when its last byte lands
    # (net contention now costs virtual time, not a closed-form curve)
    topology: str = ""
    stage_gb_per_item: float = 0.0
    topology_gbps: float = 10.0
    topology_latency_s: float = 0.0
    # --- elastic runtime (repro.sched.elastic) -------------------------
    # shrink policy: an ElasticController.  When set, a job whose chunk
    # does NOT fit a host's budget may run on a FRACTION of its demanded
    # memory (spilling the rest) at the modeled slowdown from its
    # estimate's demand-vs-slowdown curve — charged into the executor's
    # rate, so virtual time pays for the memory cut.  None (default)
    # keeps binary admission, bit-identical.
    elastic: Optional[object] = None
    # deterministic seeded failure injection: a FailureSchedule whose
    # pre-drawn fail/repair events ride the runtime under its own event
    # kinds (the legacy Poisson ``failures``/``host_mtbf_s`` channel is
    # untouched and composable).  None (default) injects nothing.
    failure_plan: Optional[object] = None

    def host_capacity(self) -> ResourceVector:
        """Per-host capacity vector: the primary memory axis, the CPU
        slack (admission gate, paper Section 6.8), and any extra axes."""
        axes = {self.primary_axis: self.host_mem_gb,
                "cpu": self.cpu_slack}
        axes.update(self.extra_capacity)
        return ResourceVector(**axes)


@dataclass
class Job:
    jid: int
    app: AppProfile
    items: float                      # total M-items
    c_iso: float                      # isolated execution time (analytic)
    fn_hat: Optional[MemoryFunction] = None
    demand_est: Optional["DemandEstimate"] = None  # full multi-axis
    info: Dict = field(default_factory=dict)
    unassigned: float = 0.0
    done: float = 0.0
    arrival: float = 0.0              # open-arrival time (0 for batch)
    profiled_at: float = 0.0
    finish: Optional[float] = None
    conservative: bool = False
    active: int = 0                   # running executors (O(1) finish check)
    oom_count: int = 0
    tenant: Optional[str] = None      # owning tenant (fairness accounting)


@dataclass
class Executor:
    eid: int
    job: Job
    host: "Host"
    items_left: float
    mem_true: float
    mem_claimed: float
    rate_base: float
    last_t: float
    version: int = 0
    delay_until: float = 0.0          # online-search probe delay
    straggle: float = 1.0
    done_since_ckpt: float = 0.0
    claimed_vec: Optional[ResourceVector] = None  # full per-axis booking


@dataclass
class Host:
    """Executor-level host state.  Booked-capacity accounting lives on
    the wrapped :class:`~repro.sched.cluster.Node` (the shared substrate
    the serving engine's replicas use too); the host keeps what is
    simulator-specific — live executors, true memory, paging."""
    hid: int
    mem_cap: float                    # primary-axis capacity (shortcut)
    execs: List[Executor] = field(default_factory=list)
    up: bool = True
    capacity: Optional[ResourceVector] = None  # full axis capacities
    node: Optional[Node] = None       # booked-claims ledger

    def __post_init__(self):
        if self.node is None:
            cap = self.capacity if self.capacity is not None \
                else ResourceVector(host_ram=self.mem_cap)
            self.node = Node(self.hid, cap)

    @property
    def mem_true(self) -> float:
        return sum(e.mem_true for e in self.execs)

    @property
    def mem_claimed(self) -> float:
        return sum(e.mem_claimed for e in self.execs)

    @property
    def cpu_used(self) -> float:
        return sum(e.job.app.cpu_load for e in self.execs)

    def free_vector(self) -> ResourceVector:
        """Unbooked capacity per axis (capacity minus booked claims),
        read off the node's claim ledger."""
        return self.node.headroom()

    def paging(self) -> bool:
        return self.mem_true > self.mem_cap


class Simulator:
    def __init__(self, jobs_spec: Optional[List[Tuple[AppProfile, float]]],
                 policy: "Policy", cfg: SimConfig, seed: int = 0,
                 arrivals: Optional[List] = None, tracer=None):
        """``jobs_spec`` is the closed batch (everything at t=0);
        ``arrivals`` (a list of ``repro.sched.arrivals.Arrival``) instead
        feeds the cluster as an open queueing system — turnaround is then
        measured from each job's arrival time.  ``tracer`` (a
        ``repro.obs.trace.Tracer``) collects job/executor lifecycle
        spans on the virtual clock; None (default) traces nothing and
        keeps results bit-identical."""
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.policy = policy
        bind = getattr(policy, "bind", None)
        if callable(bind):      # fix the config the policy predicts under
            bind(cfg)
        capacity = cfg.host_capacity()
        self.cluster = ClusterState.homogeneous(cfg.n_hosts, capacity)
        self.hosts = [Host(n.nid, cfg.host_mem_gb, capacity=capacity,
                           node=n) for n in self.cluster]
        # the shared event-driven substrate (repro.sched.cluster): the
        # runtime owns the clock + heap + node ledger; the simulator
        # registers its workload-specific handlers on it.  Simulator.run
        # is a thin shim over runtime.run — results are pinned
        # bit-identical to the pre-runtime loop by tests/test_cluster.py
        self.runtime = ClusterRuntime(self.cluster, tracer=tracer)
        self.tracer = self.runtime.tracer
        self.telemetry = self.runtime.telemetry
        self.topology = None
        if cfg.topology:
            from repro.sched.topology import get_topology
            self.topology = get_topology(
                cfg.topology, nodes=cfg.n_hosts,
                gbps=cfg.topology_gbps,
                latency_s=cfg.topology_latency_s).attach(self.runtime)
            self.runtime.topology = self.topology
        self.runtime.on("arrive", self._on_arrive)
        self.runtime.on("profiled", self._on_profiled)
        for kind in ("finish", "wake", "oom"):
            self.runtime.on(kind, self._make_exec_handler(kind))
        self.runtime.on("fail", self._on_fail)
        self.runtime.on("repair", self._on_repair)
        self.jobs: List[Job] = []
        if arrivals is not None:
            for jid, a in enumerate(sorted(arrivals, key=lambda a: a.t)):
                c_iso = a.items / (cfg.n_hosts * a.app.rate)
                self.jobs.append(Job(jid, a.app, a.items, c_iso,
                                     unassigned=a.items, arrival=a.t,
                                     tenant=getattr(a, "tenant", None)))
        else:
            for jid, (app, items) in enumerate(jobs_spec):
                c_iso = items / (cfg.n_hosts * app.rate)
                self.jobs.append(Job(jid, app, items, c_iso,
                                     unassigned=items))
        self.util_trace: List[Tuple[float, float]] = []
        self._eid = itertools.count()
        self.oom_count = 0
        self.paging_time = 0.0

    # --- event plumbing ---------------------------------------------------
    @property
    def t(self) -> float:
        """The virtual clock — owned by the runtime's event loop."""
        return self.runtime.t

    @property
    def binding_axes(self) -> Dict[str, int]:
        """Axis -> count of admission decisions it bound, aggregated
        over the cluster's nodes ("cap" = the Spark chunk /
        remaining-work cap bound before any resource)."""
        return self.cluster.binding_axes()

    def _push(self, t: float, kind: str, payload=None):
        self.runtime.push(t, kind, payload)

    def _rate(self, e: Executor) -> float:
        if self.t < e.delay_until or not e.host.up:
            return 0.0
        r = e.rate_base * e.straggle
        cpu = e.host.cpu_used
        if cpu > 1.0:
            r /= cpu
        if e.host.paging():
            r /= self.cfg.paging_slowdown
        return max(r, 1e-12)

    def _advance_host(self, host: Host):
        """Credit progress to now and re-time finish events."""
        for e in list(host.execs):
            dt = self.t - e.last_t
            if dt > 0:
                done = min(e.items_left, self._rate(e) * dt)
                e.items_left -= done
                e.job.done += done
                e.done_since_ckpt += done
                e.last_t = self.t
        for e in host.execs:
            e.version += 1
            rate = self._rate(e)
            if e.items_left <= 1e-12:
                self._push(self.t, "finish", (e, e.version))
            elif rate > 0:
                self._push(self.t + e.items_left / rate, "finish",
                           (e, e.version))
            elif e.delay_until > self.t:
                self._push(e.delay_until, "wake", (e, e.version))

    def _spawn(self, job: Job, host: Host, items: float, mem_true: float,
               mem_claimed: float, delay: float = 0.0,
               slowdown: float = 1.0, shrink_fraction: float = 1.0):
        """``slowdown`` > 1 charges a spill-aware shrunken grant into
        the executor's base rate (virtual time pays for the memory
        cut); ``shrink_fraction`` < 1 scales the side-car MEMORY-axis
        bookings by the granted fraction (the primary axis arrives
        pre-scaled in ``mem_claimed``).  Defaults are exact identities."""
        straggle = 1.0
        if self.cfg.straggler_prob > 0 and \
                self.rng.random() < self.cfg.straggler_prob:
            straggle = self.cfg.straggler_factor
        # full per-axis booking: the primary-axis claim, the executor's
        # average CPU load, and any secondary-axis demand at this split
        # — booked from the PREDICTED side-car curves when the job went
        # through an estimator (consistent with what admission decided
        # on), falling back to declared aux curves otherwise.  The
        # primary-axis-match guard mirrors Policy._demand_model: a job
        # estimated under a different primary axis was ADMITTED on the
        # declared curves, so it must book from them too
        de = job.demand_est
        if de is not None and \
                de.model.primary_axis == self.cfg.primary_axis:
            aux = {a: fn for a, fn in de.model.curves.items()
                   if a != self.cfg.primary_axis}
        else:
            aux = job.app.aux_demand
        axes = {a: float(fn(items)) for a, fn in aux.items()}
        if shrink_fraction != 1.0:
            from repro.sched.resources import MEMORY_AXES
            axes = {a: (v * shrink_fraction if a in MEMORY_AXES else v)
                    for a, v in axes.items()}
        axes[self.cfg.primary_axis] = mem_claimed
        axes["cpu"] = job.app.cpu_load
        e = Executor(next(self._eid), job, host, items, mem_true,
                     mem_claimed, job.app.rate / slowdown, self.t,
                     delay_until=self.t + delay, straggle=straggle,
                     claimed_vec=ResourceVector(**axes))
        if slowdown != 1.0:
            self.telemetry.inc("elastic.shrink")
            if self.tracer is not None:
                self.tracer.instant(
                    "shrink", self.t, process="cluster", thread="execs",
                    args={"eid": e.eid, "jid": job.jid, "host": host.hid,
                          "fraction": shrink_fraction,
                          "slowdown": slowdown})
        job.unassigned -= items
        job.active += 1
        host.execs.append(e)
        host.node.book(e.eid, e.claimed_vec)
        if self.tracer is not None:
            self.tracer.async_begin(
                "exec", self.t, e.eid, cat="exec", process="cluster",
                thread="execs",
                args={"jid": job.jid, "host": host.hid,
                      "items": items, "claimed_gb": mem_claimed})
        # OOM check: large overflow kills the executor after wasted time
        over = host.mem_true - host.mem_cap
        if over > self.cfg.oom_overflow_frac * host.mem_cap:
            self.oom_count += 1
            waste = (self.cfg.oom_waste_frac * items
                     / max(job.app.rate, 1e-12))
            self._push(self.t + waste, "oom", (e, e.version))
        self._stage_input(e, items)
        self._advance_host(host)
        return e

    def _stage_input(self, e: Executor, items: float) -> None:
        """With a topology bound, the executor's input chunk rides the
        fabric from the ingress before any item processes: park it
        (``delay_until = inf`` — ``_rate`` reads 0) until the staging
        Transmission's last byte lands, then release and re-time.  The
        parked wake-at-inf event is superseded by the version bump, the
        usual stale-event discipline."""
        if self.topology is None or self.cfg.stage_gb_per_item <= 0.0:
            return
        dst = f"n{e.host.hid}"
        if not self.topology.has_node(dst) \
                or self.topology.ingress is None:
            return
        e.delay_until = float("inf")

        def staged(t, tr, e=e):
            if e not in e.host.execs:
                return            # OOM-killed / failed while staging
            e.delay_until = t
            self._advance_host(e.host)

        self.topology.transmit(
            self.topology.ingress, dst,
            items * self.cfg.stage_gb_per_item, now=self.t,
            tag="stage", on_complete=staged)

    def _remove_exec(self, e: Executor, requeue_items: float):
        if e in e.host.execs:
            e.host.execs.remove(e)
            e.host.node.release(e.eid)
            e.job.active -= 1
            if self.tracer is not None:
                self.tracer.async_end(
                    "exec", self.t, e.eid, cat="exec",
                    process="cluster", thread="execs",
                    args={"requeued": requeue_items})
        e.job.unassigned += requeue_items
        self._advance_host(e.host)

    def _maybe_finish(self, job: Job, t: float):
        tol = max(1e-6, 1e-7 * job.items)
        if job.finish is None and job.done >= job.items - tol \
                and job.unassigned <= tol and job.active == 0:
            job.finish = t
            if self.tracer is not None:
                end_args = {"oom_count": job.oom_count}
                if job.tenant is not None:
                    end_args["tenant"] = job.tenant
                self.tracer.async_end(
                    "job", t, job.jid, cat="job", process="cluster",
                    thread="jobs", args=end_args)

    # --- event handlers (registered on the ClusterRuntime) ------------------
    def _on_arrive(self, t: float, payload) -> None:
        job, frac = payload
        if self.tracer is not None:
            span_args = {"items": job.items, "app": job.app.name}
            if job.tenant is not None:
                span_args["tenant"] = job.tenant
            self.tracer.async_begin(
                "job", t, job.jid, cat="job", process="cluster",
                thread="jobs", args=span_args)
        if frac is not None:
            # profiling runs while the job waits; its processed
            # items credit the job (paper: no cycle is wasted)
            t_prof = frac * job.c_iso
            if self.cfg.profile_single_host:
                credit = min(t_prof * job.app.rate, 0.15 * job.items)
            else:
                credit = 0.15 * job.items
            job.done += credit
            job.unassigned -= credit
            self._push(t + t_prof, "profiled", job)
        else:
            self._push(t, "profiled", job)

    def _on_profiled(self, t: float, job) -> None:
        job.profiled_at = t
        job.fn_hat, job.info = self.policy.predict(job, self.rng)
        if self.tracer is not None:
            self.tracer.instant(
                "profiled", t, process="cluster", thread="jobs",
                args={"jid": job.jid,
                      "family": getattr(job.fn_hat, "family", None)})
        self.policy.dispatch(self)

    def _make_exec_handler(self, kind: str):
        def handler(t: float, payload):
            e, version = payload
            if e not in e.host.execs:
                return False  # executor already gone (stale event)
            if kind != "oom" and e.version != version:
                return False  # stale re-timed event
            self._advance_host(e.host)
            if kind == "oom" and e.items_left > 1e-9:
                if self.tracer is not None:
                    self.tracer.instant(
                        "oom", t, process="cluster", thread="execs",
                        args={"eid": e.eid, "jid": e.job.jid,
                              "host": e.host.hid})
                self._remove_exec(e, e.items_left)
                # scheduler reaction (paper Section 2.3: re-run an
                # OOM-killed executor in isolation): escalate — halve
                # budgets, and after 2 OOMs only place this job on
                # empty hosts
                e.job.oom_count += 1
                self.policy.dispatch(self, [e.host])
            elif e.items_left <= 1e-9:
                self._remove_exec(e, 0.0)
                self._maybe_finish(e.job, t)
                self.policy.dispatch(self, [e.host])
        return handler

    def _on_fail(self, t: float, host) -> None:
        if host.up:
            host.up = False
            host.node.up = False
            # re-queue non-checkpointed work
            for e in list(host.execs):
                lost = min(e.done_since_ckpt, e.job.done)
                e.job.done -= lost
                self._remove_exec(e, e.items_left + lost)
            self._push(t + self.cfg.repair_time_s, "repair", host)
        self._push(t + self.rng.exponential(self.cfg.host_mtbf_s),
                   "fail", host)

    def _on_repair(self, t: float, host) -> None:
        host.up = True
        host.node.up = True
        self.policy.dispatch(self, [host])

    # --- deterministic failure plan (repro.sched.elastic) ----------------
    def _fail_host(self, t: float, idx: int) -> None:
        """FailureSchedule callback: the legacy ``fail`` body minus the
        Poisson re-arm and the repair push — the schedule owns both, so
        injecting a deterministic plan never touches the simulator RNG
        stream (seeded runs with ``failure_plan=None`` stay
        bit-identical)."""
        host = self.hosts[idx]
        if not host.up:
            return
        host.up = False
        host.node.up = False
        for e in list(host.execs):
            lost = min(e.done_since_ckpt, e.job.done)
            e.job.done -= lost
            self._remove_exec(e, e.items_left + lost)

    def _repair_host(self, t: float, idx: int) -> None:
        host = self.hosts[idx]
        if not host.up:
            self._on_repair(t, host)

    def _tick(self, t: float) -> None:
        self.util_trace.append(
            (t, sum(h.cpu_used for h in self.hosts if h.up)
             / max(len(self.hosts), 1)))

    # --- main loop ----------------------------------------------------------
    def run(self) -> Dict:
        """Thin shim over :meth:`ClusterRuntime.run`: seed the arrival
        (and failure) events, drain the loop, summarize.  Pinned
        bit-identical to the pre-runtime inline heap by the goldens in
        ``tests/test_cluster.py``."""
        cfg = self.cfg
        for job in self.jobs:
            # profile fraction drawn HERE (not at pop time) so the RNG
            # stream is identical between batch and open-arrival runs
            frac = self.rng.uniform(cfg.profile_frac_lo,
                                    cfg.profile_frac_hi) \
                if self.policy.uses_profiling else None
            self._push(job.arrival, "arrive", (job, frac))
        if cfg.failures and cfg.host_mtbf_s > 0:
            for h in self.hosts:
                self._push(self.rng.exponential(cfg.host_mtbf_s),
                           "fail", h)
        if cfg.failure_plan is not None:
            cfg.failure_plan.attach(
                self.runtime, on_fail=self._fail_host,
                on_repair=self._repair_host, n_targets=len(self.hosts))

        self.runtime.run(
            max_time=cfg.max_sim_time, tick=self._tick,
            until=lambda: all(j.finish is not None for j in self.jobs))

        # events drained: close out any numerically-finished jobs
        for job in self.jobs:
            self._maybe_finish(job, self.t)

        if not self.jobs:
            return {"stp": 0.0, "antt": 0.0, "antt_reduction": 0.0,
                    "makespan": 0.0, "c_cl": [], "c_is": [],
                    "arrivals": [], "finish_times": [], "unfinished": 0,
                    "oom_count": self.oom_count,
                    "binding_axes": dict(self.binding_axes),
                    "util_trace": self.util_trace}
        # turnaround is measured from each job's arrival (0 for batch);
        # unfinished jobs are CENSORED at the simulation cap, arrival-
        # relative and floored at c_iso. That is a LOWER bound on the
        # true turnaround, so STP/ANTT are optimistic bounds whenever
        # ``unfinished`` > 0 — compare policies on drained runs, or
        # check ``unfinished`` before trusting the aggregate.
        unfinished = sum(1 for j in self.jobs if j.finish is None)
        c_cl = np.asarray([j.finish - j.arrival if j.finish is not None
                           else max(cfg.max_sim_time - j.arrival, j.c_iso)
                           for j in self.jobs])
        c_is = np.asarray([j.c_iso for j in self.jobs])
        stp = float(np.sum(c_is / c_cl))
        antt = float(np.mean(c_cl / c_is))
        # the paper's Fig.6b baseline runs jobs ONE BY ONE: its turnaround
        # for job i includes waiting for jobs 1..i-1
        serial_turnaround = np.cumsum(c_is)
        antt_reduction = float(
            1.0 - np.mean(c_cl) / max(np.mean(serial_turnaround), 1e-12))
        return {"stp": stp, "antt": antt,
                "antt_reduction": antt_reduction,
                "makespan": float(np.max(c_cl)),
                "c_cl": c_cl.tolist(), "c_is": c_is.tolist(),
                "arrivals": [j.arrival for j in self.jobs],
                "finish_times": [j.finish for j in self.jobs],
                "unfinished": unfinished,
                "oom_count": self.oom_count,
                "binding_axes": dict(self.binding_axes),
                "util_trace": self.util_trace}


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class Policy:
    """Base: estimator-driven co-location (the paper's runtime).

    Demand estimation goes through the ``repro.sched.estimator``
    registry — selection order: the ``estimator`` constructor argument,
    else ``SimConfig.estimator``, else the policy's own ``predictor``
    wrapped in its faithful estimator (bit-identical to the
    pre-estimator code path).  Budget-inverse sizing and budget shading
    are delegated to the shared
    :class:`repro.sched.admission.AdmissionController` (the same object
    the serving driver admits request batches through); queue ordering
    and host-scan order come from the ``repro.sched.placement`` registry
    (``cfg.placement``)."""
    name = "base"
    uses_profiling = True
    #: whether ``SimConfig.estimator`` / ``benchmarks/run.py
    #: --estimator`` sweeps this policy's estimator.  Only the paper's
    #: own policy (OURS) is sweepable — baselines (oracle, quasar,
    #: pairwise, online-search) keep their defining predictors, so a
    #: sweep compares "OURS under estimator X" against stable baselines.
    estimator_sweepable = False

    def __init__(self, predictor=None,
                 admission: Optional["AdmissionController"] = None,
                 placement=None, estimator=None):
        """``placement`` (a name or PlacementPolicy instance) and
        ``estimator`` (a name or DemandEstimator instance) override
        ``SimConfig.placement`` / ``SimConfig.estimator`` for this
        policy only."""
        from repro.sched.estimator import resolve_estimator
        self._owns_admission = admission is None
        if admission is None:
            from repro.sched.admission import AdmissionController
            admission = AdmissionController()
        self.predictor = predictor
        self.admission = admission
        self.placement = get_placement(placement) \
            if isinstance(placement, str) else placement
        self._est_spec = estimator
        self._est = resolve_estimator(estimator, predictor=predictor)
        self._cfg: Optional[SimConfig] = None

    def bind(self, cfg: SimConfig) -> None:
        """Called by the Simulator before the run: fixes the config the
        policy predicts under (primary axis) and resolves the estimator
        (ctor arg > ``cfg.estimator`` > wrapped predictor)."""
        from repro.sched.estimator import resolve_estimator
        self._cfg = cfg
        spec = self._est_spec
        if spec is None and self.estimator_sweepable:
            spec = cfg.estimator or None
        self._est = resolve_estimator(spec, predictor=self.predictor)
        # keep the policy-owned controller's estimator in sync (a
        # re-bind under a different SimConfig.estimator must not leave
        # a stale handle); a caller-supplied shared controller is never
        # clobbered
        if self._owns_admission:
            self.admission.estimator = self._est

    def _placement(self, cfg: SimConfig):
        return self.placement if self.placement is not None \
            else get_placement(cfg.placement)

    def predict(self, job: Job, rng) -> Tuple[MemoryFunction, Dict]:
        """Estimate the job's full multi-axis demand (primary curve +
        predicted side-cars) and remember it on the job; returns the
        primary curve + info exactly like the pre-estimator API."""
        from repro.sched.estimator import JobTarget
        if self._est is None:                     # bare-predictor legacy
            return self.predictor.predict_function(job.app, job.items,
                                                   rng)
        primary = self._cfg.primary_axis if self._cfg is not None \
            else "host_ram"
        est = self._est.estimate(
            JobTarget(job.app, job.items, primary_axis=primary), rng=rng)
        job.demand_est = est
        if est.conservative:
            job.conservative = True
        return est.primary_fn, est.info

    def _demand_model(self, cfg: SimConfig, job: Job) -> DemandModel:
        """The job's per-axis demand: the estimated multi-axis model
        (calibrated primary curve + PREDICTED side-car curves) with the
        executor's average CPU load as a fixed gate (paper Section 6.8 —
        moved out of the dispatcher into the controller)."""
        est = job.demand_est
        if est is not None and est.model.primary_axis == cfg.primary_axis:
            return DemandModel(est.model.curves,
                               fixed={"cpu": job.app.cpu_load},
                               primary_axis=cfg.primary_axis)
        # legacy path (no estimate recorded): primary curve + DECLARED
        # side-car curves — deprecated since the estimator redesign
        curves = {cfg.primary_axis: job.fn_hat}
        if job.app.aux_demand:
            warnings.warn(
                "feeding declared AppProfile.aux_demand curves straight "
                "into admission is deprecated — route the job through a "
                "repro.sched.estimator DemandEstimator, which PREDICTS "
                "the side-car curves from aux probes",
                DeprecationWarning, stacklevel=2)
            curves.update(job.app.aux_demand)
        return DemandModel(curves, fixed={"cpu": job.app.cpu_load},
                           primary_axis=cfg.primary_axis)

    def _sized_items(self, sim, job, host, budget) -> Optional[float]:
        """Budget-inverse executor sizing, shared by every predictor-
        driven policy: items = min over budgeted axes of the demand
        inverse, capped by the Spark partition chunk D/H. The chunk
        cap preserves job-level parallelism (an executor that cached the
        whole input would serialize the job); the binding-axis inverse is
        the paper's mechanism, vectorized. On an EMPTY host at least a
        chunk is taken even if it won't fully fit in cache (spill ==
        paging penalty)."""
        chunk = job.items / (sim.cfg.n_hosts * sim.cfg.tasks_per_slot)
        dec = self.admission.admit(self._demand_model(sim.cfg, job),
                                   budget,
                                   cap=min(job.unassigned, chunk),
                                   book=False)
        n = dec.units
        # the empty-host override may only relax the PRIMARY memory
        # axis (or the cap): overshooting it spills, and spill ==
        # paging penalty is modeled.  A fixed gate (cpu slack) or a
        # bound secondary axis has no overrun consequence model, so
        # forcing a chunk past it would book beyond capacity silently
        if not host.execs and \
                dec.binding_axis in (sim.cfg.primary_axis, None):
            n = min(job.unassigned, max(n, chunk))
        # an executor below a quarter chunk isn't worth co-locating (and
        # unbounded micro-executors would storm the event loop); the tail
        # of a nearly-done job is always placeable
        if n < min(chunk * 0.25, job.unassigned) - 1e-12 or n <= 1e-9:
            return None
        axis = dec.binding_axis or "cap"
        host.node.record_binding(axis)
        return n

    def spawn_params(self, sim, job, host,
                     budget: ResourceVector) -> Optional[Tuple]:
        """-> (items, mem_true, mem_claimed, delay) or the 6-tuple
        (+ slowdown, shrink_fraction) from the spill-aware fallback, or
        None."""
        n = self._sized_items(sim, job, host, budget)
        if n is None:
            return self._shrink_params(sim, job, host, budget)
        mem_true = job.app.measure(n)
        mem_claimed = self.admission.book(
            job.fn_hat, n, budget.get(sim.cfg.primary_axis, np.inf))
        return n, mem_true, mem_claimed, 0.0

    def _shrink_params(self, sim, job, host,
                       budget: ResourceVector) -> Optional[Tuple]:
        """Spill-aware fallback when the chunk does NOT fit: walk the
        job's demand-vs-slowdown curve to the largest memory fraction
        the budget covers and, if the ElasticController prices it under
        the slowdown cap, grant the FULL chunk on the shrunken claim —
        the executor runs at ``rate / slowdown`` (spilled items re-read
        from disk cost time, not correctness).  Returns the extended
        spawn tuple or None (= today's wait)."""
        cfg = sim.cfg
        est = job.demand_est
        curve = getattr(est, "shrink", None) if est is not None else None
        if cfg.elastic is None or curve is None or not curve.shrinkable:
            return None
        if est.model.primary_axis != cfg.primary_axis:
            return None          # admitted on declared curves — no fit
        chunk = min(job.unassigned,
                    job.items / (cfg.n_hosts * cfg.tasks_per_slot))
        if chunk <= 1e-9:
            return None
        dec = self.admission.shrink_target(
            self._demand_model(cfg, job), budget, units=chunk,
            curve=curve, elastic=cfg.elastic, book=False)
        if not dec:
            return None
        sh = dec.info["shrink"]
        f, slow = float(sh["fraction"]), float(sh["slowdown"])
        if f >= 1.0 - 1e-12:
            # fits outright — _sized_items already declined (floor);
            # shrinking must not become a floor bypass
            return None
        host.node.record_binding(sh["axis"] or "cap")
        # the executor genuinely caps its resident set at the granted
        # fraction (the rest spills) — mis-prediction still bites: if
        # the true working set overshoots the predicted one, f * true
        # overshoots the claim and paging/OOM consequences apply
        mem_true = f * job.app.measure(chunk)
        mem_claimed = min(
            f * float(job.fn_hat(chunk)),
            budget.get(cfg.primary_axis, np.inf))
        return chunk, mem_true, mem_claimed, 0.0, slow, f

    def _tenant_order(self, sim: Simulator, jobs: List[Job]) -> List[Job]:
        """Progressive-filling DRF interleave across tenants for the
        host-scan loop (the serving side's ``pack_step`` analogue):
        repeatedly hand the scan slot to the tenant with the LOWEST
        dominant share of booked cluster capacity — live executor
        claims plus the primary-axis chunks already granted this pass —
        taking that tenant's first placement-ordered job.  Equal-weight
        DRF; jobs without a tenant form their own pseudo-tenant.  Only
        reached when some ready job carries a tenant, so untenanted
        runs stay bit-identical."""
        cfg = sim.cfg
        total = {a: v * cfg.n_hosts
                 for a, v in cfg.host_capacity().items()}
        usage: Dict[Optional[str], Dict[str, float]] = {}
        for h in sim.hosts:
            for e in h.execs:
                if e.claimed_vec is None:
                    continue
                u = usage.setdefault(e.job.tenant, {})
                for a, v in e.claimed_vec.items():
                    u[a] = u.get(a, 0.0) + v

        def share(ten) -> float:
            return max((v / total[a]
                        for a, v in usage.get(ten, {}).items()
                        if total.get(a, 0.0) > 0.0), default=0.0)

        queues: Dict[Optional[str], List[Job]] = {}
        order: List[Optional[str]] = []   # first-seen tie-break
        for j in jobs:
            if j.tenant not in queues:
                queues[j.tenant] = []
                order.append(j.tenant)
            queues[j.tenant].append(j)
        out: List[Job] = []
        while any(queues[t] for t in order):
            pick = min((t for t in order if queues[t]),
                       key=lambda t: (share(t), order.index(t)))
            job = queues[pick].pop(0)
            out.append(job)
            # charge the job's likely next grant (one primary-axis
            # chunk) so the NEXT slot goes to whoever is now behind —
            # this is what interleaves instead of draining one tenant
            chunk = min(job.unassigned,
                        job.items / (cfg.n_hosts * cfg.tasks_per_slot))
            if job.fn_hat is not None:
                u = usage.setdefault(pick, {})
                a = cfg.primary_axis
                u[a] = u.get(a, 0.0) + float(job.fn_hat(chunk))
        return out

    def dispatch(self, sim: Simulator, hosts=None):
        """Offer capacity to jobs in placement order. ``hosts`` narrows
        the scan to the hosts whose state changed (executor finish/OOM/
        repair) — a full cluster scan happens only when a new job
        becomes schedulable."""
        cfg = sim.cfg
        hosts = hosts if hosts is not None else sim.hosts
        placement = self._placement(cfg)
        ready = [j for j in sim.jobs
                 if j.fn_hat is not None and j.unassigned > 1e-9]
        ordered = placement.order_jobs(ready, now=sim.t)
        if any(j.tenant is not None for j in ordered):
            ordered = self._tenant_order(sim, ordered)
        for job in ordered:
            for host in placement.order_hosts(job, hosts,
                                              cfg.primary_axis):
                if not host.up or job.unassigned <= 1e-9:
                    continue
                if any(e.job is job for e in host.execs):
                    continue  # one executor per (job, host)
                if job.oom_count >= 2 and host.execs:
                    continue  # isolation re-run after repeated OOM
                free = host.free_vector()
                if free.get(cfg.primary_axis, 0.0) < cfg.min_alloc_gb:
                    continue
                # CPU admission lives in the controller now: the free
                # vector carries the cpu axis and the demand model's
                # fixed cpu load gates it inside admit()
                budget = self.admission.effective_budget(
                    free, safety_margin=cfg.safety_margin,
                    conservative=getattr(job, "conservative", False),
                    oom_count=job.oom_count)
                params = self.spawn_params(sim, job, host, budget)
                if params is None:
                    continue
                n, mt, mc, delay = params[:4]
                if len(params) > 4:      # spill-aware shrunken grant
                    sim._spawn(job, host, n, mt, mc, delay,
                               slowdown=params[4],
                               shrink_fraction=params[5])
                else:
                    sim._spawn(job, host, n, mt, mc, delay)


class OursPolicy(Policy):
    name = "ours"
    estimator_sweepable = True

    def __init__(self, predictor=None,
                 admission: Optional["AdmissionController"] = None,
                 refresher=None, placement=None, estimator=None):
        """``refresher`` (repro.sched.online.OnlineRefresher) folds each
        profiled arrival's calibration curve back into the estimator
        (``partial_update`` through the registry handle) — the
        open-arrival online-learning loop."""
        super().__init__(predictor, admission, placement, estimator)
        self.refresher = refresher

    def predict(self, job, rng):
        fn, info = super().predict(job, rng)
        if not info.get("confident", True):
            job.conservative = True
        if self.refresher is not None and info.get("calib"):
            xs, ys = zip(*info["calib"])
            info["refreshed"] = self.refresher.observe(
                job.app.features, xs, ys,
                confident=info.get("confident"))
        return fn, info


class QuasarPolicy(Policy):
    name = "quasar"


class OraclePolicy(Policy):
    """Prophetic memory prediction. Jobs flow through the same pipeline
    (same arrival staggering) — only the prediction is perfect, so Oracle
    is the schedule-dynamics-matched upper bound for OURS (the paper
    reports "% of Oracle performance" in exactly this sense)."""
    name = "oracle"
    uses_profiling = True


class OnlineSearchPolicy(Policy):
    """Descent-search for the right input size: probing overhead per
    executor launch + suboptimal final allocation (paper Section 6.5)."""
    name = "online"
    uses_profiling = False

    def __init__(self):
        super().__init__(None)

    def predict(self, job, rng):
        return job.app.true_fn, {"family": job.app.family}

    def spawn_params(self, sim, job, host, budget):
        n_opt = self._sized_items(sim, job, host, budget)
        if n_opt is None:
            return None
        qual = sim.rng.uniform(sim.cfg.online_alloc_lo, 1.0)
        n = n_opt * qual
        mem_true = job.app.measure(n)
        delay = sim.cfg.online_search_eta * n / max(job.app.rate, 1e-12)
        mem_claimed = self.admission.book(
            job.fn_hat, n, budget.get(sim.cfg.primary_axis, np.inf))
        return n, mem_true, mem_claimed, delay


class PairwisePolicy(Policy):
    """<=2 executors per host; the co-located one claims ALL free memory
    and takes a Spark-default item chunk (no memory model)."""
    name = "pairwise"
    uses_profiling = False

    def __init__(self):
        super().__init__(None)

    def predict(self, job, rng):
        return job.app.true_fn, {}  # never used for sizing

    def dispatch(self, sim: Simulator, hosts=None):
        cfg = sim.cfg
        hosts = hosts if hosts is not None else sim.hosts
        placement = self._placement(cfg)
        ready = [j for j in sim.jobs
                 if j.fn_hat is not None and j.unassigned > 1e-9]
        for job in placement.order_jobs(ready, now=sim.t):
            for host in placement.order_hosts(job, hosts,
                                              cfg.primary_axis):
                if not host.up or job.unassigned <= 1e-9:
                    continue
                if len(host.execs) >= 2:
                    continue
                if any(e.job is job for e in host.execs):
                    continue
                if job.oom_count >= 2 and host.execs:
                    continue  # isolation re-run after repeated OOM
                free = host.free_vector().get(cfg.primary_axis, 0.0)
                if free < cfg.min_alloc_gb:
                    continue
                # primary executor claims the Spark default heap; the
                # co-located one claims ALL remaining free memory (paper:
                # "sets the maximum heap size of the co-locating task to
                # the size of free memory") -> nothing beyond pairwise.
                claim = (cfg.pairwise_default_heap * host.mem_cap
                         if not host.execs else free)
                claim = min(claim, free)
                chunk = min(job.unassigned,
                            job.items / (cfg.n_hosts * cfg.tasks_per_slot))
                mem_true = job.app.measure(chunk)
                sim._spawn(job, host, chunk, mem_true, claim)


def make_policies(moe_predictor, ann_predictor) -> Dict[str, Policy]:
    from repro.core.predictor import OraclePredictor
    return {
        "ours": OursPolicy(moe_predictor),
        "quasar": QuasarPolicy(ann_predictor),
        "pairwise": PairwisePolicy(),
        "online": OnlineSearchPolicy(),
        "oracle": OraclePolicy(OraclePredictor()),
    }
