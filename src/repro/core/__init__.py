"""The paper's contribution: mixture-of-experts memory modeling + memory-
aware task co-location. See DESIGN.md for the TPU-fleet adaptation."""
from repro.core import experts  # noqa: F401
from repro.core.experts import MemoryFunction, calibrate_two_point  # noqa: F401
from repro.core.predictor import (  # noqa: F401
    ANNPredictor,
    MoEPredictor,
    OraclePredictor,
    UnifiedFamilyPredictor,
)
from repro.core.simulator import (  # noqa: F401
    OnlineSearchPolicy,
    OraclePolicy,
    OursPolicy,
    PairwisePolicy,
    QuasarPolicy,
    SimConfig,
    Simulator,
    make_policies,
)
from repro.core.workloads import (  # noqa: F401
    AppProfile,
    spark_sim_suite,
    tpu_jobs_suite,
    training_apps,
)
