"""Expert-selector classifiers (paper Table 5), from scratch in numpy.

KNN is the deployed selector (its distance doubles as a confidence
estimate and it needs no retraining when a new expert is added — paper
Section 6.9); the others exist for the Table 5 comparison:
Naive Bayes, SVM (linear, one-vs-rest hinge), MLP, Random Forest,
Decision Tree, ANN (deeper MLP).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class Classifier:
    def fit(self, X: np.ndarray, y: np.ndarray) -> "Classifier":
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def accuracy(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) == y))


@dataclass
class KNN(Classifier):
    k: int = 1
    X: Optional[np.ndarray] = None
    y: Optional[np.ndarray] = None

    def fit(self, X, y):
        self.X, self.y = np.asarray(X, float), np.asarray(y)
        return self

    def _dists(self, X):
        return np.sqrt(((X[:, None, :] - self.X[None]) ** 2).sum(-1))

    def predict(self, X):
        d = self._dists(np.asarray(X, float))
        idx = np.argsort(d, axis=1)[:, : self.k]
        votes = self.y[idx]
        out = []
        for row in votes:
            vals, counts = np.unique(row, return_counts=True)
            out.append(vals[np.argmax(counts)])
        return np.asarray(out)

    def predict_with_confidence(self, X) -> Tuple[np.ndarray, np.ndarray]:
        """(labels, nearest-neighbour distance). The distance is the
        paper's soundness guarantee: far from every training program ->
        fall back to a conservative policy."""
        d = self._dists(np.asarray(X, float))
        nn = np.argmin(d, axis=1)
        return self.y[nn], d[np.arange(len(X)), nn]


@dataclass
class GaussianNB(Classifier):
    stats: Dict = field(default_factory=dict)

    def fit(self, X, y):
        self.stats = {}
        X = np.asarray(X, float)
        for c in np.unique(y):
            Xc = X[y == c]
            self.stats[c] = (Xc.mean(0), Xc.var(0) + 1e-6,
                             np.log(len(Xc) / len(X)))
        return self

    def predict(self, X):
        X = np.asarray(X, float)
        classes = list(self.stats)
        ll = np.stack([
            self.stats[c][2]
            - 0.5 * np.sum(np.log(2 * np.pi * self.stats[c][1]))
            - 0.5 * np.sum((X - self.stats[c][0]) ** 2
                           / self.stats[c][1], axis=1)
            for c in classes], axis=1)
        return np.asarray(classes)[np.argmax(ll, axis=1)]


@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    label: Optional[object] = None


@dataclass
class DecisionTree(Classifier):
    max_depth: int = 8
    min_leaf: int = 1
    rng_seed: Optional[int] = None
    feature_frac: float = 1.0
    root: Optional[_Node] = None

    def fit(self, X, y):
        X, y = np.asarray(X, float), np.asarray(y)
        rng = np.random.default_rng(self.rng_seed)
        self.root = self._build(X, y, 0, rng)
        return self

    def _gini(self, y):
        _, counts = np.unique(y, return_counts=True)
        p = counts / len(y)
        return 1.0 - np.sum(p ** 2)

    def _build(self, X, y, depth, rng):
        if depth >= self.max_depth or len(np.unique(y)) == 1 \
                or len(y) <= self.min_leaf:
            vals, counts = np.unique(y, return_counts=True)
            return _Node(label=vals[np.argmax(counts)])
        d = X.shape[1]
        feats = rng.permutation(d)[: max(int(d * self.feature_frac), 1)]
        best = (np.inf, None, None)
        for f in feats:
            order = np.argsort(X[:, f])
            xs, ys = X[order, f], y[order]
            for i in range(self.min_leaf, len(y) - self.min_leaf):
                if xs[i] == xs[i - 1]:
                    continue
                g = (i * self._gini(ys[:i])
                     + (len(y) - i) * self._gini(ys[i:])) / len(y)
                if g < best[0]:
                    best = (g, f, (xs[i] + xs[i - 1]) / 2)
        if best[1] is None:
            vals, counts = np.unique(y, return_counts=True)
            return _Node(label=vals[np.argmax(counts)])
        f, t = best[1], best[2]
        lmask = X[:, f] <= t
        return _Node(feature=f, thresh=t,
                     left=self._build(X[lmask], y[lmask], depth + 1, rng),
                     right=self._build(X[~lmask], y[~lmask], depth + 1, rng))

    def predict(self, X):
        X = np.asarray(X, float)
        out = []
        for row in X:
            node = self.root
            while node.label is None:
                node = node.left if row[node.feature] <= node.thresh \
                    else node.right
            out.append(node.label)
        return np.asarray(out)


@dataclass
class RandomForest(Classifier):
    n_trees: int = 20
    max_depth: int = 8
    seed: int = 0
    trees: List[DecisionTree] = field(default_factory=list)

    def fit(self, X, y):
        X, y = np.asarray(X, float), np.asarray(y)
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for t in range(self.n_trees):
            idx = rng.integers(0, len(y), len(y))
            tree = DecisionTree(max_depth=self.max_depth,
                                rng_seed=int(rng.integers(1 << 31)),
                                feature_frac=0.7)
            tree.fit(X[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, X):
        votes = np.stack([t.predict(X) for t in self.trees], axis=1)
        out = []
        for row in votes:
            vals, counts = np.unique(row, return_counts=True)
            out.append(vals[np.argmax(counts)])
        return np.asarray(out)


@dataclass
class LinearSVM(Classifier):
    """One-vs-rest linear SVM, hinge loss, SGD."""
    lr: float = 0.05
    epochs: int = 300
    reg: float = 1e-3
    W: Optional[np.ndarray] = None
    b: Optional[np.ndarray] = None
    classes: Optional[np.ndarray] = None

    def fit(self, X, y):
        X = np.asarray(X, float)
        self.classes = np.unique(y)
        C, d = len(self.classes), X.shape[1]
        self.W = np.zeros((C, d))
        self.b = np.zeros(C)
        rng = np.random.default_rng(0)
        for ci, c in enumerate(self.classes):
            t = np.where(y == c, 1.0, -1.0)
            w, bb = np.zeros(d), 0.0
            for _ in range(self.epochs):
                order = rng.permutation(len(t))
                for i in order:
                    margin = t[i] * (X[i] @ w + bb)
                    if margin < 1:
                        w = (1 - self.lr * self.reg) * w \
                            + self.lr * t[i] * X[i]
                        bb += self.lr * t[i]
                    else:
                        w = (1 - self.lr * self.reg) * w
            self.W[ci], self.b[ci] = w, bb
        return self

    def predict(self, X):
        scores = np.asarray(X, float) @ self.W.T + self.b
        return self.classes[np.argmax(scores, axis=1)]


@dataclass
class MLP(Classifier):
    """Small fully-connected net, softmax CE, Adam. hidden=(32,) is the
    paper's MLP row; ANN uses a deeper variant (3 layers, backprop)."""
    hidden: Tuple[int, ...] = (32,)
    lr: float = 0.01
    epochs: int = 400
    seed: int = 0
    params: Optional[list] = None
    classes: Optional[np.ndarray] = None

    def fit(self, X, y):
        X = np.asarray(X, float)
        self.classes = np.unique(y)
        yid = np.searchsorted(self.classes, y)
        rng = np.random.default_rng(self.seed)
        sizes = [X.shape[1], *self.hidden, len(self.classes)]
        self.params = [
            (rng.normal(0, np.sqrt(2.0 / sizes[i]),
                        (sizes[i], sizes[i + 1])),
             np.zeros(sizes[i + 1]))
            for i in range(len(sizes) - 1)]
        mom = [(np.zeros_like(w), np.zeros_like(b),
                np.zeros_like(w), np.zeros_like(b))
               for w, b in self.params]
        onehot = np.eye(len(self.classes))[yid]
        for step in range(1, self.epochs + 1):
            acts = [X]
            for li, (w, b) in enumerate(self.params):
                z = acts[-1] @ w + b
                acts.append(np.maximum(z, 0)
                            if li < len(self.params) - 1 else z)
            z = acts[-1] - acts[-1].max(1, keepdims=True)
            p = np.exp(z)
            p /= p.sum(1, keepdims=True)
            delta = (p - onehot) / len(X)
            new_mom, grads = [], []
            for li in reversed(range(len(self.params))):
                w, b = self.params[li]
                gw = acts[li].T @ delta
                gb = delta.sum(0)
                grads.append((li, gw, gb))
                if li > 0:
                    delta = (delta @ w.T) * (acts[li] > 0)
            for li, gw, gb in grads:
                w, b = self.params[li]
                mw, mb, vw, vb = mom[li]
                mw = 0.9 * mw + 0.1 * gw
                mb = 0.9 * mb + 0.1 * gb
                vw = 0.999 * vw + 0.001 * gw ** 2
                vb = 0.999 * vb + 0.001 * gb ** 2
                mom[li] = (mw, mb, vw, vb)
                bc1 = 1 - 0.9 ** step
                bc2 = 1 - 0.999 ** step
                self.params[li] = (
                    w - self.lr * (mw / bc1)
                    / (np.sqrt(vw / bc2) + 1e-8),
                    b - self.lr * (mb / bc1)
                    / (np.sqrt(vb / bc2) + 1e-8))
            del new_mom
        return self

    def predict(self, X):
        a = np.asarray(X, float)
        for li, (w, b) in enumerate(self.params):
            a = a @ w + b
            if li < len(self.params) - 1:
                a = np.maximum(a, 0)
        return self.classes[np.argmax(a, axis=1)]


def make_table5_classifiers() -> Dict[str, Classifier]:
    return {
        "Naive Bayes": GaussianNB(),
        "SVM": LinearSVM(),
        "MLP": MLP(hidden=(32,)),
        "Random Forests": RandomForest(),
        "Decision Tree": DecisionTree(),
        "ANN": MLP(hidden=(64, 32)),
        "KNN": KNN(k=1),
    }
