"""Scenario runner + STP/ANTT aggregation (paper Section 5.2/5.3).

Scenarios L1..L10 mix 2..30 randomly-selected applications; each scenario
runs ``n_mixes`` different mixes; results are geometric-mean aggregated;
min/max preserved for the error bars of Fig. 6.

Open-arrival extension: :func:`run_open_scenario` feeds the simulator a
continuous (Poisson/trace) stream instead of a batch and
:func:`windowed_metrics` reports STP/ANTT per completion-time window, so
a long-running cluster's throughput can be watched over time rather than
summarized once at drain.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.simulator import SimConfig, Simulator
from repro.core.workloads import INPUT_SIZES_M_ITEMS, AppProfile

SCENARIOS = {  # paper Table 3
    "L1": 2, "L2": 6, "L3": 7, "L4": 9, "L5": 11,
    "L6": 13, "L7": 19, "L8": 23, "L9": 26, "L10": 30,
}


def gmean(xs) -> float:
    xs = np.asarray(xs, float)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def make_mix(apps: List[AppProfile], n_jobs: int,
             rng: np.random.Generator) -> List:
    """Random app mix with random input sizes (small/medium/large)."""
    chosen = rng.choice(len(apps), size=n_jobs,
                        replace=n_jobs > len(apps))
    sizes = list(INPUT_SIZES_M_ITEMS.values())
    return [(apps[i], float(sizes[rng.integers(len(sizes))]))
            for i in chosen]


@dataclass
class ScenarioResult:
    stp_gmean: float
    antt_gmean: float
    antt_reduction_mean: float   # vs the serial one-by-one baseline
    stp_min: float
    stp_max: float
    antt_min: float
    antt_max: float
    oom_total: int
    # axis -> count of admission decisions that axis bound ("cap" = the
    # Spark chunk / remaining-work cap), summed over mixes — the
    # observability hook for multi-axis (vector-budget) scenarios
    binding_axes: Dict[str, int] = None


def _merge_counts(total: Dict[str, int], part: Dict[str, int]) -> None:
    for k, v in part.items():
        total[k] = total.get(k, 0) + v


def run_scenario(apps: List[AppProfile], policy_factory, n_jobs: int,
                 n_mixes: int = 20, cfg: Optional[SimConfig] = None,
                 seed: int = 0) -> ScenarioResult:
    """policy_factory: (mix_seed) -> Policy (fresh per mix so predictors
    can be LOOCV-refit when needed)."""
    cfg = cfg or SimConfig()
    stps, antts, reds, ooms = [], [], [], 0
    binding: Dict[str, int] = {}
    for mix in range(n_mixes):
        rng = np.random.default_rng([seed, mix, n_jobs])
        jobs = make_mix(apps, n_jobs, rng)
        policy = policy_factory(mix)
        sim = Simulator(jobs, policy, cfg, seed=seed * 1000 + mix)
        out = sim.run()
        stps.append(out["stp"])
        antts.append(out["antt"])
        reds.append(out["antt_reduction"])
        ooms += out["oom_count"]
        _merge_counts(binding, out["binding_axes"])
    return ScenarioResult(
        stp_gmean=gmean(stps), antt_gmean=gmean(antts),
        antt_reduction_mean=float(np.mean(reds)),
        stp_min=float(np.min(stps)), stp_max=float(np.max(stps)),
        antt_min=float(np.min(antts)), antt_max=float(np.max(antts)),
        oom_total=ooms, binding_axes=binding)


def windowed_metrics(result: Dict, window_s: float) -> List[Dict]:
    """Per-window STP/ANTT over an (open-arrival) simulator result.

    Jobs are bucketed by COMPLETION time; each window reports the STP
    (sum of c_iso/turnaround) and ANTT (mean turnaround/c_iso) of the
    jobs it retired, plus the in-flight count at the window edge. The
    final window also carries an ``unfinished`` count (jobs that never
    completed before the run ended)."""
    if window_s <= 0:
        raise ValueError("window_s must be positive")
    arr = np.asarray(result["arrivals"], float)
    fin = np.asarray([np.nan if f is None else f
                      for f in result["finish_times"]], float)
    c_is = np.asarray(result["c_is"], float)
    if len(arr) == 0:
        return []
    # windows must span the LAST event of either kind — truncating at
    # the last completion would hide late arrivals from arrived/in_flight
    t_end = float(arr.max())
    if np.any(np.isfinite(fin)):
        t_end = max(t_end, float(np.nanmax(fin)))
    n_win = max(int(math.ceil((t_end + 1e-9) / window_s)), 1)
    out: List[Dict] = []
    for w in range(n_win):
        t0, t1 = w * window_s, (w + 1) * window_s
        done = np.isfinite(fin) & (fin >= t0) & \
            (fin < t1 if w < n_win - 1 else fin <= t1 + 1e-9)
        turn = fin[done] - arr[done]
        in_flight = int(np.sum((arr <= t1)
                               & (~np.isfinite(fin) | (fin > t1))))
        out.append({
            "t0": t0, "t1": t1, "completed": int(done.sum()),
            "stp": float(np.sum(c_is[done] / np.maximum(turn, 1e-12))),
            "antt": float(np.mean(turn / np.maximum(c_is[done], 1e-12)))
            if done.any() else 0.0,
            "arrived": int(np.sum((arr >= t0) & (arr < t1))),
            "in_flight": in_flight,
        })
    out[-1]["unfinished"] = int(np.sum(~np.isfinite(fin)))
    return out


def run_open_scenario(apps: List[AppProfile], policy_factory,
                      arrival_cfg, n_streams: int = 4,
                      cfg: Optional[SimConfig] = None, seed: int = 0,
                      window_s: Optional[float] = None) -> Dict:
    """Open-arrival counterpart of :func:`run_scenario`: ``n_streams``
    independent Poisson streams over the app universe, gmean-aggregated
    overall STP/ANTT plus (optionally) per-window traces."""
    from repro.sched.arrivals import poisson_arrivals
    cfg = cfg or SimConfig()
    stps, antts, ooms = [], [], 0
    windows: List[List[Dict]] = []
    binding: Dict[str, int] = {}
    unfinished = empty_streams = 0
    for stream in range(n_streams):
        # workload and simulator randomness must be INDEPENDENT — the
        # same integer would seed identical bitstreams for both
        arrivals = poisson_arrivals(apps, arrival_cfg,
                                    seed=[seed, stream])
        if not arrivals:
            # a horizon-truncated empty stream has no jobs to score;
            # folding its stp=0 into the gmean would collapse the
            # aggregate to ~0 for every policy
            empty_streams += 1
            continue
        policy = policy_factory(stream)
        sim = Simulator(None, policy, cfg, seed=seed * 1000 + stream,
                        arrivals=arrivals)
        res = sim.run()
        unfinished += res["unfinished"]
        stps.append(res["stp"])
        antts.append(res["antt"])
        ooms += res["oom_count"]
        _merge_counts(binding, res["binding_axes"])
        if window_s is not None:
            windows.append(windowed_metrics(res, window_s))
    if not stps:
        raise ValueError(
            f"all {n_streams} arrival streams were empty — raise "
            f"rate_per_s/n_jobs or widen horizon_s")
    return {"stp_gmean": gmean(stps), "antt_gmean": gmean(antts),
            "stp_min": float(np.min(stps)), "stp_max": float(np.max(stps)),
            "oom_total": ooms, "unfinished_total": unfinished,
            "empty_streams": empty_streams, "windows": windows,
            "binding_axes": binding}


def run_all_scenarios(apps, policy_factories: Dict[str, object],
                      scenarios: Optional[Sequence[str]] = None,
                      n_mixes: int = 20, cfg: Optional[SimConfig] = None,
                      seed: int = 0) -> Dict[str, Dict[str, ScenarioResult]]:
    """-> {policy: {scenario: ScenarioResult}}."""
    scenarios = list(scenarios or SCENARIOS)
    out: Dict[str, Dict[str, ScenarioResult]] = {}
    for pname, factory in policy_factories.items():
        out[pname] = {}
        for sc in scenarios:
            out[pname][sc] = run_scenario(
                apps, factory, SCENARIOS[sc], n_mixes, cfg, seed)
    return out
