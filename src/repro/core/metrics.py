"""Scenario runner + STP/ANTT aggregation (paper Section 5.2/5.3).

Scenarios L1..L10 mix 2..30 randomly-selected applications; each scenario
runs ``n_mixes`` different mixes; results are geometric-mean aggregated;
min/max preserved for the error bars of Fig. 6.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.simulator import SimConfig, Simulator
from repro.core.workloads import INPUT_SIZES_M_ITEMS, AppProfile

SCENARIOS = {  # paper Table 3
    "L1": 2, "L2": 6, "L3": 7, "L4": 9, "L5": 11,
    "L6": 13, "L7": 19, "L8": 23, "L9": 26, "L10": 30,
}


def gmean(xs) -> float:
    xs = np.asarray(xs, float)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def make_mix(apps: List[AppProfile], n_jobs: int,
             rng: np.random.Generator) -> List:
    """Random app mix with random input sizes (small/medium/large)."""
    chosen = rng.choice(len(apps), size=n_jobs,
                        replace=n_jobs > len(apps))
    sizes = list(INPUT_SIZES_M_ITEMS.values())
    return [(apps[i], float(sizes[rng.integers(len(sizes))]))
            for i in chosen]


@dataclass
class ScenarioResult:
    stp_gmean: float
    antt_gmean: float
    antt_reduction_mean: float   # vs the serial one-by-one baseline
    stp_min: float
    stp_max: float
    antt_min: float
    antt_max: float
    oom_total: int


def run_scenario(apps: List[AppProfile], policy_factory, n_jobs: int,
                 n_mixes: int = 20, cfg: Optional[SimConfig] = None,
                 seed: int = 0) -> ScenarioResult:
    """policy_factory: (mix_seed) -> Policy (fresh per mix so predictors
    can be LOOCV-refit when needed)."""
    cfg = cfg or SimConfig()
    stps, antts, reds, ooms = [], [], [], 0
    for mix in range(n_mixes):
        rng = np.random.default_rng([seed, mix, n_jobs])
        jobs = make_mix(apps, n_jobs, rng)
        policy = policy_factory(mix)
        sim = Simulator(jobs, policy, cfg, seed=seed * 1000 + mix)
        out = sim.run()
        stps.append(out["stp"])
        antts.append(out["antt"])
        reds.append(out["antt_reduction"])
        ooms += out["oom_count"]
    return ScenarioResult(
        stp_gmean=gmean(stps), antt_gmean=gmean(antts),
        antt_reduction_mean=float(np.mean(reds)),
        stp_min=float(np.min(stps)), stp_max=float(np.max(stps)),
        antt_min=float(np.min(antts)), antt_max=float(np.max(antts)),
        oom_total=ooms)


def run_all_scenarios(apps, policy_factories: Dict[str, object],
                      scenarios: Optional[Sequence[str]] = None,
                      n_mixes: int = 20, cfg: Optional[SimConfig] = None,
                      seed: int = 0) -> Dict[str, Dict[str, ScenarioResult]]:
    """-> {policy: {scenario: ScenarioResult}}."""
    scenarios = list(scenarios or SCENARIOS)
    out: Dict[str, Dict[str, ScenarioResult]] = {}
    for pname, factory in policy_factories.items():
        out[pname] = {}
        for sc in scenarios:
            out[pname][sc] = run_scenario(
                apps, factory, SCENARIOS[sc], n_mixes, cfg, seed)
    return out
