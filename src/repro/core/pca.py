"""PCA + varimax rotation (paper Sections 3.2, Figure 4) — numpy only."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Scaler:
    """Paper-style [0,1] min-max scaling; train-set bounds reused at
    deployment."""
    lo: np.ndarray
    hi: np.ndarray

    @classmethod
    def fit(cls, X: np.ndarray) -> "Scaler":
        return cls(lo=X.min(axis=0), hi=X.max(axis=0))

    def transform(self, X: np.ndarray) -> np.ndarray:
        span = np.maximum(self.hi - self.lo, 1e-12)
        return np.clip((X - self.lo) / span, -0.5, 1.5)


@dataclass
class PCA:
    mean: np.ndarray
    components: np.ndarray        # [k, d]
    explained_ratio: np.ndarray   # [k]

    @classmethod
    def fit(cls, X: np.ndarray, n_components: Optional[int] = None,
            variance: float = 0.95) -> "PCA":
        """Keep n_components, or enough PCs for ``variance`` of the total
        (the paper keeps the top 5 PCs ~ 95%)."""
        mean = X.mean(axis=0)
        Xc = X - mean
        _, s, vt = np.linalg.svd(Xc, full_matrices=False)
        var = s ** 2
        ratio = var / max(var.sum(), 1e-12)
        if n_components is None:
            n_components = int(np.searchsorted(np.cumsum(ratio),
                                               variance) + 1)
            n_components = min(n_components, len(ratio))
        return cls(mean=mean, components=vt[:n_components],
                   explained_ratio=ratio[:n_components])

    def transform(self, X: np.ndarray) -> np.ndarray:
        return (X - self.mean) @ self.components.T


def varimax(loadings: np.ndarray, gamma: float = 1.0, iters: int = 100,
            tol: float = 1e-8) -> np.ndarray:
    """Varimax rotation of a [d, k] loading matrix (paper Fig. 4b uses it
    to attribute PC variance back to raw features)."""
    d, k = loadings.shape
    R = np.eye(k)
    var_old = 0.0
    for _ in range(iters):
        L = loadings @ R
        u, s, vt = np.linalg.svd(
            loadings.T @ (L ** 3 - (gamma / d) * L
                          @ np.diag(np.sum(L ** 2, axis=0))))
        R = u @ vt
        var_new = float(np.sum(s))
        if var_new - var_old < tol:
            break
        var_old = var_new
    return loadings @ R


def feature_importance(pca: PCA) -> np.ndarray:
    """Per-raw-feature importance: |varimax-rotated loadings| weighted by
    explained variance. Returns [d] scores."""
    # components: [k, d] -> loadings [d, k]
    load = (pca.components * pca.explained_ratio[:, None]).T
    rot = varimax(load)
    return np.abs(rot).sum(axis=1)
