"""Runtime feature extraction from COMPILED JAX artifacts.

The paper extracts 22 perf-counter features (L1 miss rates, context
switches, IPC, ...) from a ~100 MB profiling run. On a TPU fleet the
equivalent observables come from the compiler: this module compiles a
job's step at a small probe shape and derives 22 features from
``cost_analysis`` / ``memory_analysis`` / the loop-aware HLO analysis —
deterministic, allocation-free, and available before the job runs
(DESIGN.md §2 maps each paper feature to its compiled analogue).

``extract_features`` returns the same 22-dim vector format the
spark-sim suite uses, so the MoE predictor pipeline (scaler -> PCA ->
KNN) is shared verbatim between universes.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

TPU_FEATURE_NAMES: List[str] = [
    "log_flops", "log_hbm_bytes", "arithmetic_intensity",
    "log_collective_bytes", "coll_allreduce_frac", "coll_allgather_frac",
    "coll_alltoall_frac", "coll_permute_frac", "coll_op_count",
    "log_param_bytes", "log_arg_bytes", "log_temp_bytes",
    "temp_to_arg_ratio", "log_output_bytes", "dot_count", "fusion_count",
    "while_count", "loop_trip_mean", "flops_per_token", "bytes_per_token",
    "compute_term_share", "memory_term_share",
]


def _safe_log(x: float) -> float:
    return float(np.log10(max(float(x), 1.0)))


def features_from_record(rec: Dict) -> np.ndarray:
    """22 features from a dry-run record (see launch/dryrun.lower_cell)."""
    rl = rec["roofline"]
    cost = rec["cost"]
    mem = rec["memory"]
    coll = rec["collectives"]
    flops = cost["flops_per_device"]
    hbm = cost.get("hbm_bytes_per_device", cost.get("bytes_per_device", 0))
    cb = coll.get("total_bytes", 0.0)
    by_kind = coll.get("bytes", {})
    counts = coll.get("counts", {})
    ops = rec.get("hlo_ops", {})
    loops = rec.get("loops", [])
    toks = max(rec.get("tokens", 1), 1)
    tot = max(rl["compute_s"] + rl["memory_s"] + rl["collective_s"], 1e-12)

    def frac(kind):
        return float(by_kind.get(kind, 0.0)) / max(cb, 1.0)

    vec = [
        _safe_log(flops),
        _safe_log(hbm),
        float(flops / max(hbm, 1.0)),
        _safe_log(cb),
        frac("all-reduce"),
        frac("all-gather"),
        frac("all-to-all"),
        frac("collective-permute"),
        _safe_log(sum(counts.values()) if counts else 0),
        _safe_log(rec.get("params_total", 0) * 2),
        _safe_log(mem["argument_bytes"]),
        _safe_log(mem["temp_bytes"]),
        float(mem["temp_bytes"] / max(mem["argument_bytes"], 1.0)),
        _safe_log(mem["output_bytes"]),
        _safe_log(ops.get("dot", 0)),
        _safe_log(ops.get("fusion", 0)),
        float(ops.get("while", len(loops))),
        float(np.mean([l["trip"] for l in loops]) if loops else 0.0),
        _safe_log(flops / toks),
        _safe_log(hbm / toks),
        float(rl["compute_s"] / tot),
        float(rl["memory_s"] / tot),
    ]
    assert len(vec) == len(TPU_FEATURE_NAMES)
    return np.asarray(vec, float)


def extract_features(cfg, shape_kind: str = "train", probe_seq: int = 64,
                     probe_batch: int = 2) -> np.ndarray:
    """Compile a small probe of the job's step on the current device and
    extract the 22 features (the 100MB-profiling-run analogue).

    Runs on whatever devices exist (1 on this container) — features are
    shape/structure descriptors, not wall-clock measurements."""
    import jax
    from repro.configs import input_specs
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.models import model as model_lib
    from repro.train import optim
    from repro.train.step import build_serve_step, build_train_step
    from repro.utils.hlo import count_ops
    from repro.utils.hlo_analyzer import analyze
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    shape = ShapeConfig("probe", shape_kind, probe_seq, probe_batch)
    specs = input_specs(cfg, shape)
    abstract_params = model_lib.abstract(cfg)
    if shape_kind == "train":
        tc = TrainConfig()
        step = build_train_step(cfg, tc)
        abstract_opt = optim.abstract_opt_state(abstract_params, tc)
        lowered = jax.jit(step).lower(abstract_params, abstract_opt, specs)
        tokens = probe_batch * probe_seq
    else:
        step = build_serve_step(cfg)
        lowered = jax.jit(step).lower(abstract_params, specs["token"],
                                      specs["cache"])
        tokens = probe_batch
    compiled = lowered.compile()
    hlo = compiled.as_text()
    hc = analyze(hlo)
    ma = compiled.memory_analysis()
    from repro.utils.tree import tree_bytes
    rec = {
        "roofline": {
            "compute_s": hc.flops / PEAK_FLOPS_BF16,
            "memory_s": hc.hbm_bytes / HBM_BW,
            "collective_s": hc.total_collective_bytes / ICI_BW,
        },
        "cost": {"flops_per_device": hc.flops,
                 "hbm_bytes_per_device": hc.hbm_bytes},
        "memory": {"argument_bytes": ma.argument_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes,
                   "output_bytes": ma.output_size_in_bytes},
        "collectives": {"total_bytes": hc.total_collective_bytes,
                        "bytes": hc.collective_bytes,
                        "counts": hc.collective_counts},
        "hlo_ops": count_ops(hlo, ("dot", "fusion", "while")),
        "loops": hc.loops,
        "params_total": sum(
            int(np.prod(x.shape)) for x in jax.tree.leaves(abstract_params)),
        "tokens": tokens,
    }
    return features_from_record(rec)
