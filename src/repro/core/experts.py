"""Memory-function experts (paper Table 1) + pluggable extensions.

Each expert is a 2-parameter family y = f_family(x; m, b) modeling an
application's memory footprint y as a function of input size x:

  power           y = m * x^b          (paper: "(piecewise) linear")
  exp_saturation  y = m * (1 - e^{-b x})
  log             y = m + b * ln(x)    (Napierian logarithmic)
  affine          y = m + b * x        [extension: SSM decode state is
                                        O(1) in KV length; weight-dominated
                                        footprints are constant + linear]

The paper's framework is explicitly designed for new experts to be added
(Section 1); `affine` is registered the same way a user would add one.

Calibration is the paper's two-point scheme: profile at 5% and 10% of the
input, solve (m, b) exactly. ``fit`` is the offline least-squares used
when learning which family describes a training program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

FAMILIES = ("power", "exp_saturation", "log", "affine")
PAPER_FAMILIES = ("power", "exp_saturation", "log")


def predict(family: str, m: float, b: float, x) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    if family == "power":
        return m * np.power(np.maximum(x, 1e-12), b)
    if family == "exp_saturation":
        return m * (1.0 - np.exp(-b * x))
    if family == "log":
        return m + b * np.log(np.maximum(x, 1e-12))
    if family == "affine":
        return m + b * x
    raise ValueError(f"unknown family {family!r}")


@dataclass(frozen=True)
class MemoryFunction:
    family: str
    m: float
    b: float

    def __call__(self, x):
        return predict(self.family, self.m, self.b, x)

    def inverse(self, y: float, x_hint: float = 1.0) -> float:
        """Largest x with f(x) <= y (items an executor can take under a
        memory budget). Monotone families -> closed forms / bisection."""
        m, b = self.m, self.b
        if self.family == "power":
            if m <= 0 or b == 0:
                return np.inf if predict("power", m, b, 1.0) <= y else 0.0
            base = y / m
            if base <= 0:
                return 0.0
            # log-space: base**(1/b) overflows float pow for near-flat
            # fits (tiny b), e.g. a power calibration of an almost-
            # constant footprint — saturate to inf (unbounded; callers
            # cap by chunk/unassigned)
            with np.errstate(over="ignore"):
                x = float(np.exp(np.log(base) / b)) * (1 - 1e-9)
            if not np.isfinite(x):
                return np.inf
            return x if x >= 1e-12 else 0.0  # below predict()'s x-clamp
        if self.family == "exp_saturation":
            if y >= m:  # saturates below budget -> unbounded
                return np.inf
            if y <= 0 or b <= 0:
                return 0.0
            return float(-np.log(1.0 - y / m) / b)
        if self.family == "log":
            if b <= 0:
                return np.inf if m <= y else 0.0
            # a budget far above the curve (e.g. a 4 TB HBM axis against
            # a tens-of-GB log curve) overflows exp — that IS unbounded
            with np.errstate(over="ignore"):
                x = float(np.exp((y - m) / b))
            return x if x >= 1e-12 else 0.0
        if self.family == "affine":
            if b <= 0:
                return np.inf if m <= y else 0.0
            return float(max((y - m) / b, 0.0))
        raise ValueError(self.family)


# ---------------------------------------------------------------------------
# Two-point calibration (the runtime path: 5% and 10% probes)
# ---------------------------------------------------------------------------

def calibrate_two_point(family: str, x1: float, y1: float,
                        x2: float, y2: float) -> MemoryFunction:
    assert 0 < x1 < x2, (x1, x2)
    y1 = max(float(y1), 1e-9)
    y2 = max(float(y2), y1 * (1 + 1e-9))
    if family == "power":
        b = np.log(y2 / y1) / np.log(x2 / x1)
        m = y1 / (x1 ** b)
        return MemoryFunction("power", float(m), float(b))
    if family == "log":
        b = (y2 - y1) / np.log(x2 / x1)
        m = y1 - b * np.log(x1)
        return MemoryFunction("log", float(m), float(b))
    if family == "affine":
        b = (y2 - y1) / (x2 - x1)
        m = y1 - b * x1
        return MemoryFunction("affine", float(m), float(b))
    if family == "exp_saturation":
        # Saturation guard: when the curve is already flat at the probe
        # sizes (y2 ~ y1), the two-equation solve is degenerate and noise
        # drives m to absurd values (observed: m ~ 4e11 GB -> the
        # scheduler books ~0 for a 20 GB executor -> OOM storm). A flat
        # probe pair means the footprint HAS saturated: model it as
        # m ~ y2, fast saturation.
        if y2 / y1 < 1.02:
            return MemoryFunction("exp_saturation", float(y2 * 1.05),
                                  float(10.0 / x1))
        # solve (1-e^{-b x1})/(1-e^{-b x2}) = y1/y2 by bisection on b
        ratio = y1 / y2

        def g(b):
            return ((1.0 - np.exp(-b * x1))
                    / max(1.0 - np.exp(-b * x2), 1e-300) - ratio)
        lo, hi = 1e-12 / x2, 500.0 / x1
        # g is increasing in b (ratio -> x1/x2 at b->0, -> 1 at b->inf)
        if g(lo) > 0:
            b = lo
        elif g(hi) < 0:
            b = hi
        else:
            for _ in range(200):
                mid = np.sqrt(lo * hi)
                if g(mid) < 0:
                    lo = mid
                else:
                    hi = mid
            b = np.sqrt(lo * hi)
        m = y1 / max(1.0 - np.exp(-b * x1), 1e-300)
        return MemoryFunction("exp_saturation", float(m), float(b))
    raise ValueError(family)


# ---------------------------------------------------------------------------
# Offline least-squares fits (training programs)
# ---------------------------------------------------------------------------

def fit(family: str, xs: Sequence[float], ys: Sequence[float]
        ) -> MemoryFunction:
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    if family == "power":
        lx, ly = np.log(np.maximum(xs, 1e-12)), np.log(np.maximum(ys, 1e-12))
        b, lm = np.polyfit(lx, ly, 1)
        return MemoryFunction("power", float(np.exp(lm)), float(b))
    if family == "log":
        b, m = np.polyfit(np.log(np.maximum(xs, 1e-12)), ys, 1)
        return MemoryFunction("log", float(m), float(b))
    if family == "affine":
        b, m = np.polyfit(xs, ys, 1)
        return MemoryFunction("affine", float(m), float(b))
    if family == "exp_saturation":
        # grid over b (log-spaced), closed-form m per b, pick best
        best = (np.inf, 1.0, 1.0)
        for b in np.geomspace(1e-6 / xs.max(), 100.0 / xs.min(), 200):
            phi = 1.0 - np.exp(-b * xs)
            denom = float(phi @ phi)
            if denom <= 0:
                continue
            m = float(phi @ ys) / denom
            err = float(np.sum((m * phi - ys) ** 2))
            if err < best[0]:
                best = (err, m, b)
        return MemoryFunction("exp_saturation", best[1], float(best[2]))
    raise ValueError(family)


def relative_error(fn: Callable, xs, ys) -> float:
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    pred = np.asarray(fn(xs), np.float64)
    return float(np.mean(np.abs(pred - ys) / np.maximum(np.abs(ys), 1e-12)))


def best_family(xs, ys, families: Sequence[str] = FAMILIES
                ) -> Tuple[MemoryFunction, Dict[str, float]]:
    """Try every family; return the best fit and per-family errors."""
    errs: Dict[str, float] = {}
    best_fn, best_err = None, np.inf
    for fam in families:
        fn = fit(fam, xs, ys)
        e = relative_error(fn, xs, ys)
        errs[fam] = e
        if e < best_err:
            best_fn, best_err = fn, e
    return best_fn, errs
