"""Workload universes for the co-location experiments.

* ``spark_sim_suite`` — the faithful reproduction: 44 applications named
  after the paper's four suites (16 HiBench+BigDataBench training apps,
  28 Spark-Perf/Spark-Bench test apps), each with a ground-truth memory
  curve from one of the paper's three families (+ measurement noise), a
  CPU load drawn from the paper's Fig.13 distribution, and a 22-dim
  runtime feature vector that clusters by family (paper Fig.16).

* ``tpu_jobs_suite`` — the beyond-paper universe: the assigned
  (arch x shape) cells as schedulable jobs whose memory curves come from
  the real model configs (param bytes + per-token activation/KV bytes)
  and whose duty cycles come from the dry-run roofline.

Units: x = input size in M-items (spark) or k-tokens (tpu); y = GB.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.experts import MemoryFunction

FEATURE_NAMES = [
    "L1_TCM", "L1_DCM", "vcache", "L1_STM", "bo", "L2_TCM", "L3_TCM", "cs",
    "FLOPs", "in", "L2_DCM", "L2_LDM", "L1_ICM", "swpd", "L2_STM", "IPC",
    "L1_LDM", "L2_ICM", "ID", "WA", "US", "SY",
]

# suite -> [(app, family)]
_HB = [("Sort", "exp_saturation"), ("TeraSort", "exp_saturation"),
       ("Wordcount", "exp_saturation"), ("PageRank", "log"),
       ("Kmeans", "power"), ("Join", "exp_saturation"),
       ("Scan", "exp_saturation"), ("Aggregation", "power"),
       ("Bayes", "power")]
_BDB = [("Sort", "exp_saturation"), ("Wordcount", "exp_saturation"),
        ("Grep", "exp_saturation"), ("PageRank", "log"),
        ("Kmeans", "power"), ("NaiveBayes", "power"),
        ("Join", "exp_saturation")]
_SP = [("Kmeans", "power"), ("glm-classification", "power"),
       ("glm-regression", "power"), ("Pca", "power"),
       ("NaiveBayes", "power"), ("DecisionTree", "power"),
       ("Spearman", "power"), ("Pearson", "power"), ("Chi-sq", "power"),
       ("Gmm", "power"), ("Sum.Statis", "power"),
       ("B.MatrixMult", "exp_saturation"), ("CoreRDD", "exp_saturation"),
       ("ALS", "log"), ("FPGrowth", "power")]
_SB = [("Hive", "exp_saturation"), ("SVD++", "log"), ("MatrixFact", "log"),
       ("LogRegre", "power"), ("RDDRelation", "exp_saturation"),
       ("SQL", "exp_saturation"), ("PageRank", "log"), ("SVM", "power"),
       ("TriangleCount", "log"), ("ConnectedComp", "log"),
       ("Terasort", "exp_saturation"), ("DecisionTree", "power"),
       ("PregelOp", "log")]

TRAIN_SUITES = ("HB", "BDB")
INPUT_SIZES_M_ITEMS = {"small": 0.3, "medium": 30.0, "large": 1000.0}


def size_class_of(items: float) -> str:
    """Nearest paper Table-4 size class for an input size (used for
    per-class reporting of open-arrival streams)."""
    classes = list(INPUT_SIZES_M_ITEMS)
    logs = np.log(np.asarray(list(INPUT_SIZES_M_ITEMS.values())))
    return classes[int(np.argmin(np.abs(
        logs - np.log(max(float(items), 1e-12)))))]

# family -> 22-dim cluster center in [0,1] feature space (three tight
# clusters; paper Fig.16 / Section 6.9: within-cluster corr > 0.9999)
_CENTER_SEED = 7


def _family_centers() -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(_CENTER_SEED)
    return {fam: rng.uniform(0.15, 0.85, len(FEATURE_NAMES))
            for fam in ("power", "exp_saturation", "log")}


@dataclass
class AppProfile:
    name: str
    suite: str
    family: str                 # ground-truth memory-function family
    true_fn: MemoryFunction     # GB as a function of M-items
    cpu_load: float             # average duty cycle in isolation (0..1)
    rate: float                 # M-items / s per executor (unit share)
    features: np.ndarray        # 22-dim raw feature vector
    noise: float = 0.02         # multiplicative measurement noise
    # GROUND-TRUTH secondary-axis demand curves (axis -> units->amount),
    # e.g. host staging RAM or interconnect bandwidth for an
    # HBM-resident TPU job.  Since the DemandEstimator redesign these
    # are a measurement source only (``measure_axis``): the runtime
    # PREDICTS the side-car curves from probes, and feeding declared
    # curves straight into admission is deprecated (DeprecationWarning
    # from the legacy path in ``core/simulator.py``).
    aux_demand: Dict[str, MemoryFunction] = field(default_factory=dict)

    def measure(self, x: float, rng: Optional[np.random.Generator] = None
                ) -> float:
        y = float(self.true_fn(x))
        if rng is not None:
            y *= float(1.0 + rng.normal(0, self.noise))
        return max(y, 1e-3)

    def measure_axis(self, axis: str, x: float,
                     rng: Optional[np.random.Generator] = None) -> float:
        """Measure a side-car axis at input size ``x`` (the aux-probe
        counterpart of :meth:`measure`, same noise model).  This is how
        estimators *predict* aux curves instead of reading the declared
        ground truth."""
        y = float(self.aux_demand[axis](x))
        if rng is not None:
            y *= float(1.0 + rng.normal(0, self.noise))
        return max(y, 1e-6)


def _make_fn(fam: str, rng: np.random.Generator) -> MemoryFunction:
    """Parameter ranges chosen so a Spark-partition chunk of a large input
    (~6-25 M-items) has a 10-45 GB footprint — memory is the binding
    co-location constraint, as in the paper (64 GB hosts, executors sized
    to tens of GB)."""
    if fam == "power":
        return MemoryFunction("power", float(rng.uniform(7.0, 18.0)),
                              float(rng.uniform(0.35, 0.6)))
    if fam == "exp_saturation":
        return MemoryFunction("exp_saturation",
                              float(rng.uniform(45.0, 120.0)),
                              float(rng.uniform(0.01, 0.05)))
    if fam == "log":
        return MemoryFunction("log", float(rng.uniform(16.0, 36.0)),
                              float(rng.uniform(2.0, 5.0)))
    raise ValueError(fam)


def spark_sim_suite(seed: int = 0) -> List[AppProfile]:
    rng = np.random.default_rng(seed)
    centers = _family_centers()
    apps: List[AppProfile] = []
    for suite, entries in (("HB", _HB), ("BDB", _BDB), ("SP", _SP),
                           ("SB", _SB)):
        for name, fam in entries:
            fn = _make_fn(fam, rng)
            # Fig 13: CPU load mostly < 40%; compute-heavy apps higher
            heavy = name in ("Aggregation", "Kmeans", "Gmm",
                             "glm-classification", "SVM", "FPGrowth")
            cpu = float(np.clip(rng.normal(0.45 if heavy else 0.28, 0.08),
                                0.08, 0.75))
            feat = np.clip(
                centers[fam] + rng.normal(0, 0.015, len(FEATURE_NAMES)),
                0, 1)
            apps.append(AppProfile(
                name=f"{suite}.{name}", suite=suite, family=fam,
                true_fn=fn, cpu_load=cpu,
                rate=float(rng.uniform(0.02, 0.12)), features=feat))
    assert len(apps) == 44, len(apps)
    return apps


def training_apps(apps: List[AppProfile]) -> List[AppProfile]:
    return [a for a in apps if a.suite in TRAIN_SUITES]


def loocv_training_set(apps: List[AppProfile], target: AppProfile
                       ) -> List[AppProfile]:
    """Leave-one-out + exclude equivalent implementations in other suites
    (paper Section 5.2: testing HB.Sort excludes BDB.Sort too)."""
    base = target.name.split(".", 1)[1].lower()
    return [a for a in training_apps(apps)
            if a.name != target.name
            and a.name.split(".", 1)[1].lower() != base]


# ---------------------------------------------------------------------------
# TPU-jobs universe (beyond paper): assigned cells as schedulable jobs
# ---------------------------------------------------------------------------

def tpu_jobs_suite(dryrun_results: Optional[dict] = None, seed: int = 0
                   ) -> List[AppProfile]:
    """Jobs = assigned (arch x shape) cells. Memory curve per job:
    y(GB) = weight GB + per-ktoken GB * x  (affine ground truth — exactly
    the degenerate case the paper's 3-family library cannot express,
    motivating the pluggable `affine` expert). Duty cycle = roofline
    compute-term share from the dry-run when available."""
    from repro.configs import ARCH_IDS, get_config, applicable_shapes
    from repro.models import model as model_lib
    from repro.utils.tree import tree_bytes

    rng = np.random.default_rng(seed)
    centers = _family_centers()
    ssm_center = np.clip(
        np.random.default_rng(_CENTER_SEED + 1).uniform(
            0.15, 0.85, len(FEATURE_NAMES)), 0, 1)
    jobs: List[AppProfile] = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        pb = tree_bytes(model_lib.abstract(cfg)) / 2 ** 30  # GB
        d = cfg.d_model
        for shape_name in applicable_shapes(cfg):
            # per-ktoken activation/KV GB (order-of-magnitude model:
            # activations ~ layers * d * bytes; KV ~ layers * kv * hd)
            if shape_name.startswith("decode") or shape_name.startswith(
                    "long"):
                per_tok = (cfg.num_layers * cfg.num_kv_heads
                           * max(cfg.head_dim, 1) * 2 * 2) / 2 ** 30 * 1000
                fam = "affine" if cfg.family in ("ssm", "hybrid") \
                    else "affine"
            else:
                per_tok = (cfg.num_layers * d * 4 * 2) / 2 ** 30 * 1000
                fam = "affine"
            duty = 0.35
            key = f"{arch}|{shape_name}|single"
            if dryrun_results and key in dryrun_results \
                    and dryrun_results[key].get("ok"):
                r = dryrun_results[key]["roofline"]
                tot = max(r["compute_s"] + r["memory_s"]
                          + r["collective_s"], 1e-9)
                duty = float(np.clip(r["compute_s"] / tot, 0.05, 0.95))
            fn = MemoryFunction("affine", float(pb), float(per_tok))
            feat = np.clip(
                (ssm_center if cfg.family in ("ssm", "hybrid")
                 else centers["power"])
                + rng.normal(0, 0.015, len(FEATURE_NAMES)), 0, 1)
            jobs.append(AppProfile(
                name=f"{arch}:{shape_name}", suite="TPU", family=fam,
                true_fn=fn, cpu_load=duty,
                rate=float(rng.uniform(0.02, 0.12)), features=feat))
    return jobs
