"""The serving loop: continuous batching driven by step-level admission.

Two modes over the same queue, demand model, budget, and backend:

* ``continuous`` — the tentpole: every decode step re-plans batch
  membership through :class:`~repro.serve.batcher.ContinuousBatcher`
  (joins when the binding-axis inverse says the KV fits, immediate
  retirement, evict-and-requeue preemption when decode growth would
  breach the budget).
* ``wave``       — the legacy ``launch/serve.py`` behaviour for
  comparison: admission once per wave via ``admit_batch`` against the
  worst-case (full-context) footprint, no joins until the whole wave
  drains — finished requests idle in their slots, which is exactly the
  throughput continuous batching reclaims.

Time is virtual (backend cost model), so identical seeds give identical
schedules and metrics on any machine; the jax backend's real compute
rides inside those steps.

Termination is structural, not best-effort: every loop iteration either
decodes one token of at least one request (and tokens, once decoded,
survive preemption via recompute) or consumes a future arrival, so the
loop runs at most ``sum(max_new_tokens) + len(requests)`` iterations —
a preemption storm cannot live-lock.  ``max_steps`` is an assertion
backstop on that bound, not a tuning knob.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from repro.core.experts import MemoryFunction
from repro.sched.admission import AdmissionController
from repro.sched.resources import DemandModel, ResourceVector
from repro.serve.backends import Backend, SimBackend
from repro.serve.batcher import (ContinuousBatcher, ServingDemand,
                                 StepDecision)
from repro.serve.metrics import ServingMetrics
from repro.serve.queue import RequestQueue
from repro.serve.request import Request, RequestState

MODES = ("continuous", "wave")


class Engine:
    """Drives a request population to completion under a resource budget.

    ``run()`` returns the metrics summary; the step-by-step record stays
    on ``engine.metrics`` for the invariant tests and benchmarks.
    """

    def __init__(self, requests: Sequence[Request],
                 demand: ServingDemand,
                 budget: Union[float, ResourceVector],
                 backend: Optional[Backend] = None,
                 mode: str = "continuous",
                 placement: str = "fcfs",
                 max_batch: int = 16,
                 controller: Optional[AdmissionController] = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r} (choose from {MODES})")
        if not isinstance(budget, ResourceVector):
            budget = ResourceVector(hbm=float(budget))
        self.mode = mode
        self.demand = demand
        self.budget = budget
        self.backend = backend or SimBackend()
        self.controller = controller or AdmissionController()
        self.max_batch = int(max_batch)
        self.requests = list(requests)
        max_len = getattr(self.backend, "max_len", None)
        if max_len is not None:
            for r in self.requests:
                if r.prompt_len + r.max_new_tokens > max_len:
                    raise ValueError(
                        f"request {r.rid}: prompt+new "
                        f"{r.prompt_len + r.max_new_tokens} exceeds the "
                        f"backend's max_len {max_len}")
        self.queue = RequestQueue(self.requests, placement=placement)
        self.batcher = ContinuousBatcher(
            demand, budget, controller=self.controller,
            placement=self.queue.placement, max_batch=self.max_batch)
        self.metrics = ServingMetrics()
        for r in self.requests:
            self.metrics.record_request(r)
        # structural bound: one decoded token per step minimum, plus one
        # idle-advance per arrival (see module docstring)
        self.max_steps = sum(r.max_new_tokens for r in self.requests) \
            + len(self.requests) + 8

    # --- candidate filtering ---------------------------------------------
    def _candidates(self, now: float) -> List[Request]:
        """Pending requests the backend can physically join right now
        (position/window constraints), in placement order."""
        pending = self.queue.pending(now)
        if self.backend.position and \
                self.backend.position % self.backend.join_stride:
            return []  # joins quantize to the backend's sync points
        if self.backend.empty:
            # empty batch restarts: greedy cohort whose shared position
            # window fits everyone (max prefill + max remaining <= cap)
            max_len = getattr(self.backend, "max_len", None)
            if max_len is None:
                return pending
            out, maxp, maxr = [], 0, 0
            for r in pending:
                p = max(maxp, r.prefill_len)
                n = max(maxr, r.remaining_new)
                if p + n <= max_len:
                    out.append(r)
                    maxp, maxr = p, n
            return out
        return [r for r in pending if self.backend.joinable(r)]

    # --- shared step application -----------------------------------------
    def _apply(self, plan: StepDecision, running: List[Request],
               by_rid: Dict[int, Request], now: float) -> float:
        """Evict, requeue, join.  Returns the join (prefill) cost."""
        evicted = [by_rid[rid] for rid in plan.preempted]
        if evicted:
            self.backend.remove(evicted)
            for r in evicted:
                r.preemptions += 1
                running.remove(r)
                self.queue.requeue(r)
        joined = [by_rid[rid] for rid in plan.admitted]
        dt = 0.0
        if joined:
            self.queue.take(joined)
            dt = self.backend.join(joined, now)
            for r in joined:
                r.admissions += 1
                r.state = RequestState.RUNNING
            running.extend(joined)
        return dt

    def _retire(self, running: List[Request], now: float) -> None:
        done = [r for r in running if r.done]
        if done:
            self.backend.remove(done)
            for r in done:
                r.state = RequestState.FINISHED
                r.finish_t = now
                running.remove(r)

    # --- the loops --------------------------------------------------------
    def run(self) -> Dict:
        t = self._run_continuous() if self.mode == "continuous" \
            else self._run_wave()
        return self.metrics.summary(elapsed=t)

    def _run_continuous(self) -> float:
        t, step = 0.0, 0
        running: List[Request] = []
        by_rid = {r.rid: r for r in self.requests}
        while running or not self.queue.drained:
            self.queue.release(t)
            cands = self._candidates(t)
            if not running and not cands:
                nxt = self.queue.next_arrival()
                if nxt is None:
                    # pending exists but nothing can join (should be
                    # impossible: empty batch accepts any valid request)
                    raise RuntimeError("serving deadlock: pending "
                                       "requests but no candidates")
                t = nxt
                continue
            plan = self.batcher.plan_step(running, cands, t, step)
            dt = self._apply(plan, running, by_rid, t)
            dt += self.backend.decode(running)
            t += dt
            step += 1
            for r in running:
                if r.first_token_t is None:
                    r.first_token_t = t
            self._retire(running, t)
            self.metrics.record_step(plan, dt)
            if step > self.max_steps:
                raise RuntimeError(
                    f"engine exceeded its structural step bound "
                    f"({self.max_steps}) — termination invariant broken")
        return t

    def _wave_admission(self, cands: Sequence[Request]):
        """Once-per-wave admission against the worst-case footprint:
        every slot booked at the wave's longest full context (the
        pre-engine ``launch/serve.py`` behaviour)."""
        lmax = max(r.prefill_len + r.remaining_new for r in cands)
        curves = {"hbm": MemoryFunction(
            "affine", self.demand.weights_gb,
            self.demand.kv_gb_per_token * lmax)}
        for axis, per_req in self.demand.per_request_axes().items():
            curves[axis] = MemoryFunction("affine", 0.0, per_req)
        dm = DemandModel(curves, primary_axis="hbm")
        return self.controller.admit_batch(
            dm, self.budget, min_batch=1,
            max_batch=min(self.max_batch, len(cands)))

    def _run_wave(self) -> float:
        t, step = 0.0, 0
        by_rid = {r.rid: r for r in self.requests}
        while not self.queue.drained:
            self.queue.release(t)
            cands = self._candidates(t)
            if not cands:
                nxt = self.queue.next_arrival()
                if nxt is None:
                    raise RuntimeError("serving deadlock in wave mode")
                t = nxt
                continue
            dec = self._wave_admission(cands)
            wave = cands[:int(dec.units)]
            plan = StepDecision(
                step=step, t=t, admitted=tuple(r.rid for r in wave),
                preempted=(), batch=len(wave),
                booked=self.demand.booked(wave, 0), budget=self.budget,
                binding_axis=dec.binding_axis,
                forced=bool(dec.info.get("forced")),
                forced_axes=tuple(dec.info.get("forced_axes", ())))
            dt = self._apply(plan, [], by_rid, t)
            wave_live = [by_rid[rid] for rid in plan.admitted]
            self.metrics.record_step(plan, dt)
            step += 1            # step ids stay unique and monotone
            t += dt
            for r in wave_live:  # the wave's prefill emitted one token
                if r.first_token_t is None and r.tokens_decoded:
                    r.first_token_t = t
            # drain the whole wave: finished requests idle in their
            # slots (full-occupancy step cost) until the last finishes
            while any(not r.done for r in wave_live):
                sdt = self.backend.decode(wave_live)
                t += sdt
                for r in wave_live:
                    if r.first_token_t is None and r.tokens_decoded:
                        r.first_token_t = t
                self.metrics.record_step(StepDecision(
                    step=step, t=t, admitted=(), preempted=(),
                    batch=len(wave_live),
                    booked=self.demand.booked(wave_live, 0),
                    budget=self.budget, binding_axis=None,
                    forced=plan.forced,
                    forced_axes=plan.forced_axes), sdt)
                step += 1
                if step > self.max_steps:
                    raise RuntimeError("wave mode exceeded its "
                                       "structural step bound")
            for r in wave_live:
                r.state = RequestState.FINISHED
                r.finish_t = t
            self.backend.remove(wave_live)
        return t
