"""The serving loop: continuous batching as step events on replica Nodes.

Two modes over the same queue, demand model, budget, and backend:

* ``continuous`` — the default: the engine runs on the shared
  :class:`~repro.sched.cluster.ClusterRuntime` substrate.  Each of the
  1..N replicas is a :class:`~repro.sched.cluster.Node` (per-replica
  budget capacity, a live ledger of in-flight request footprints) with
  its own backend and :class:`~repro.serve.batcher.ContinuousBatcher`;
  every decode step is a ``step`` event on the runtime's virtual clock,
  so replicas advance independently and interleave in time order.
  Released requests are routed to a replica by the ``Router`` registry
  (``single`` / ``least-loaded`` / ``net-aware``) using their predicted
  multi-axis demand vector — ``net-aware`` spreads load over the
  replicas' ``net`` headroom, which is what makes multi-replica serving
  routing over the net axis real.  Preempted requests requeue on their
  own replica (their recomputable KV is local state) — unless a
  ``topology`` is bound and ``migrate=True``, in which case eviction
  compares the MODELED KV-transfer time (live paged footprint over the
  bottleneck link's residual fair share) against the local recompute
  cost and, when the wire wins, ships the KV to an adoptable replica as
  a real :class:`~repro.sched.topology.Transmission` on the same event
  loop; the destination seats it with ``backend.adopt`` (no prefill
  reruns).  With ``ingress_gb_per_token > 0`` routed requests also ride
  the fabric from the topology's ingress before they can join, so a
  shared narrow uplink costs real TTFT.  ``topology=None`` (default)
  keeps every schedule bit-identical to the pre-topology engine.
* ``wave``       — the legacy ``launch/serve.py`` behaviour for
  comparison: single replica, admission once per wave via
  ``admit_batch`` against the worst-case (full-context) footprint, no
  joins until the whole wave drains.

With one replica the event loop degenerates to the exact pre-runtime
sequential loop — schedules and metrics are pinned bit-identical by the
goldens in ``tests/test_cluster.py``.

Time is virtual (backend cost model), so identical seeds give identical
schedules and metrics on any machine; the jax backend's real compute
rides inside those steps.

Termination is structural, not best-effort: every planned step decodes
one token — or, on chunked-prefill backends, advances one prefill
chunk — of at least one request (and tokens, once decoded, survive
preemption via recompute), and every idle wake either consumes a future
arrival or ends that replica's event chain, so the loop runs at most
``sum(max_new_tokens) + replicas * len(requests)`` planned steps
(scaled by the worst per-admission chunk count when a backend prefills
in chunks) — a preemption storm cannot live-lock.  ``max_steps`` is an
assertion backstop on that bound, not a tuning knob.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from repro.core.experts import MemoryFunction
from repro.obs.telemetry import sample_node
from repro.sched.admission import AdmissionController
from repro.sched.cluster import ClusterRuntime, ClusterState, Node, Router
from repro.sched.elastic import Autoscaler, pick_spawn_node
from repro.sched.resources import DemandModel, ResourceVector
from repro.sched.tenancy import Tenant, TenantRegistry
from repro.sched.topology import Topology
from repro.serve.backends import Backend, SimBackend
from repro.serve.batcher import (ContinuousBatcher, ServingDemand,
                                 StepDecision)
from repro.serve.metrics import ServingMetrics
from repro.serve.queue import RequestQueue
from repro.serve.request import Request, RequestState

MODES = ("continuous", "wave")

#: the per-node ledger key for the resident model weights (booked once
#: per replica; requests book their own growing KV/side-car vectors)
_WEIGHTS_KEY = "__weights__"


class Engine:
    """Drives a request population to completion under a resource budget.

    ``budget`` is PER REPLICA (each replica Node gets the full vector as
    its capacity); ``replicas``/``router`` select the cluster shape and
    the routing policy.  ``run()`` returns the metrics summary; the
    step-by-step record stays on ``engine.metrics`` for the invariant
    tests and benchmarks.
    """

    def __init__(self, requests: Sequence[Request],
                 demand: ServingDemand,
                 budget: Union[float, ResourceVector],
                 backend: Optional[Backend] = None,
                 mode: str = "continuous",
                 placement: str = "fcfs",
                 max_batch: int = 16,
                 controller: Optional[AdmissionController] = None,
                 replicas: int = 1,
                 router: Union[str, Router] = "single",
                 backends: Optional[Sequence[Backend]] = None,
                 topology=None,
                 migrate: bool = False,
                 ingress_gb_per_token: float = 0.0,
                 budgets: Optional[Sequence[ResourceVector]] = None,
                 tracer=None,
                 tenants: Union[TenantRegistry, Sequence[Tenant],
                                None] = None,
                 elastic=None,
                 failures=None,
                 autoscaler=None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r} (choose from {MODES})")
        if not isinstance(budget, ResourceVector):
            budget = ResourceVector(hbm=float(budget))
        if mode != "continuous" and (elastic is not None
                                     or failures is not None
                                     or autoscaler is not None):
            raise ValueError("elastic / failures / autoscaler run on "
                             "the continuous engine (wave is the "
                             "legacy shim)")
        self.replicas = int(replicas)
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        #: the elastic runtime (all default-off, bit-identical when
        #: unset): ``elastic`` (ElasticController) turns on spill-aware
        #: shrunken joins in the batchers; ``failures``
        #: (FailureSchedule) injects deterministic replica fail/repair
        #: events; ``autoscaler`` (Autoscaler) spawns/drains replicas
        #: from queue-depth and SLO-attainment trends.  With an
        #: autoscaler the fleet is PRE-PROVISIONED to ``max_replicas``
        #: — the spares exist as down Nodes (no capacity, invisible to
        #: the router) until a scale-up flips them live.
        self.elastic = elastic
        self.failures = failures
        self.autoscaler = autoscaler
        self._initial_replicas = self.replicas
        if autoscaler is not None:
            self.replicas = max(self.replicas,
                                int(autoscaler.max_replicas))
        if mode == "wave" and self.replicas != 1:
            raise ValueError("wave mode is the single-replica legacy "
                             "path — use mode='continuous' with "
                             "replicas > 1")
        if mode == "wave" and (topology is not None
                               or budgets is not None):
            raise ValueError("topology / heterogeneous budgets need "
                             "mode='continuous' (wave is the legacy "
                             "shim)")
        if migrate and topology is None:
            raise ValueError("migrate=True needs a topology — KV moves "
                             "over modeled links")
        self.mode = mode
        self.demand = demand
        self.budget = budget
        # one backend per replica: an explicit list or a single backend
        # instance (one replica only); default SimBackends
        if backends is not None and backend is not None:
            raise ValueError("pass either backend= or backends=, "
                             "not both")
        if backends is not None:
            self.backends = list(backends)
            if len(self.backends) != self.replicas:
                raise ValueError(
                    f"got {len(self.backends)} backends for "
                    f"{self.replicas} replicas")
        elif backend is not None:
            if self.replicas != 1:
                raise ValueError("pass backends=[...] (one per replica) "
                                 "when replicas > 1")
            self.backends = [backend]
        else:
            self.backends = [SimBackend() for _ in range(self.replicas)]
        self.backend = self.backends[0]
        self.controller = controller or AdmissionController()
        self.max_batch = int(max_batch)
        self.requests = list(requests)
        for be in self.backends:
            max_len = getattr(be, "max_len", None)
            if max_len is None:
                continue
            for r in self.requests:
                if r.prompt_len + r.max_new_tokens > max_len:
                    raise ValueError(
                        f"request {r.rid}: prompt+new "
                        f"{r.prompt_len + r.max_new_tokens} exceeds the "
                        f"backend's max_len {max_len}")
        self.queue = RequestQueue(self.requests, placement=placement)
        # the shared substrate: one Node per replica, capacity = the
        # per-replica budget (or an explicit per-replica vector when the
        # cell is heterogeneous), weights booked once on each
        if budgets is not None:
            budgets = list(budgets)
            if len(budgets) != self.replicas:
                raise ValueError(f"got {len(budgets)} budgets for "
                                 f"{self.replicas} replicas")
            cluster = ClusterState(
                [Node(i, b) for i, b in enumerate(budgets)])
        else:
            cluster = ClusterState.homogeneous(self.replicas, budget)
        self.budgets = budgets
        for node in cluster:
            node.book(_WEIGHTS_KEY, ResourceVector(hbm=demand.weights_gb))
        # autoscaler spares start DOWN: routers skip them, no steps run
        # on them, and a scale-up flips one live
        for nid in range(self._initial_replicas, self.replicas):
            cluster[nid].up = False
        #: None (the default) keeps the legacy FIFO-prefix plan and
        #: routing bit-identical; a registry (or plain Tenant list)
        #: turns on weighted-DRF fairness in the router, the batchers'
        #: knapsack joins, and per-tenant metrics
        if tenants is None or isinstance(tenants, TenantRegistry):
            self.tenancy = tenants
        else:
            self.tenancy = TenantRegistry(tenants)
        if self.tenancy is not None:
            for r in self.requests:
                if r.tenant is not None:
                    self.tenancy.ensure(r.tenant)
        self.runtime = ClusterRuntime(cluster, router=router,
                                      topology=topology, tracer=tracer,
                                      tenancy=self.tenancy)
        #: None by default — every span/instant below is gated on it,
        #: so untraced runs stay bit-identical to the pre-obs engine
        self.tracer = self.runtime.tracer
        self.telemetry = self.runtime.telemetry
        self.topology = self.runtime.topology
        self.migrate = bool(migrate)
        self.ingress_gb_per_token = float(ingress_gb_per_token)
        self.batchers = [ContinuousBatcher(
            demand, budgets[r] if budgets is not None else budget,
            controller=self.controller,
            placement=self.queue.placement, max_batch=self.max_batch,
            node=r, tenancy=self.tenancy,
            elastic=elastic) for r in range(self.replicas)]
        self.batcher = self.batchers[0]
        self.metrics = ServingMetrics()
        for r in self.requests:
            self.metrics.record_request(r)
        # structural bound: one decoded token per planned step minimum,
        # plus one idle-advance per (arrival, replica) pair.  Chunked
        # prefill relaxes "one token per step" to "one token OR one
        # prefill chunk per step": between productive units a request
        # consumes at most ceil(context / chunk) chunk-only steps, so
        # the bound scales by that factor.
        base_bound = sum(r.max_new_tokens for r in self.requests) \
            + self.replicas * len(self.requests) + 8
        chunk_mult = 1
        for be in self.backends:
            chunk = getattr(be, "prefill_chunk", 0)
            if chunk and self.requests:
                worst = max(-(-(r.prompt_len + r.max_new_tokens) // chunk)
                            for r in self.requests)
                chunk_mult = max(chunk_mult, 1 + worst)
        self.max_steps = base_bound * chunk_mult
        if failures is not None or autoscaler is not None:
            # fail/repair and scale events add idle wakes and recompute
            # churn beyond the structural bound; slacken the backstop
            # (still an assertion against live-lock, not a knob)
            self.max_steps = self.max_steps * 4 + 256
        # per-replica scheduling state (continuous mode)
        self._pending: List[List[Request]] = \
            [[] for _ in range(self.replicas)]
        self._running: List[List[Request]] = \
            [[] for _ in range(self.replicas)]
        self._clocks: List[float] = [0.0] * self.replicas
        self._by_rid: Dict[int, Request] = {r.rid: r for r in
                                            self.requests}
        self._step_no = 0
        # topology state: requests riding a Transmission toward replica
        # d sit in _in_transit[d] (committed load, not yet joinable);
        # rids whose KV-cache landed via migration adopt instead of
        # recomputing on their next join
        self._in_transit: List[List[Request]] = \
            [[] for _ in range(self.replicas)]
        self._kv_ready: set = set()
        self._step_gen: List[int] = [0] * self.replicas
        #: replicas currently failed (failure injection): their step
        #: chains die on arrival and repair pushes a fresh one.  A
        #: scaled-DOWN replica is NOT in here — it keeps stepping until
        #: its running set drains.
        self._failed: set = set()

    # --- routing ----------------------------------------------------------
    def _route_released(self, now: float) -> None:
        """Move arrived requests into a replica's pending list, chosen
        by the router from the request's predicted demand vector against
        per-node headroom.  The routed request books its demand on the
        node IMMEDIATELY (a queued request is committed load: it will
        run there), so a burst of simultaneous arrivals sees shrinking
        headroom and spreads across replicas instead of piling onto the
        first node."""
        for req in self.queue.drain_released(now):
            vec = self.demand.request_vector(req)
            node = self.runtime.route(vec, now=now, tenant=req.tenant)
            node.book(req.rid, vec)
            if self.tenancy is not None:
                # the routed request is committed tenant load NOW, so a
                # burst sees each other's growing shares and spreads
                # (the fairness analogue of the node booking above)
                self.tenancy.add_usage(req.tenant, node.nid, vec)
            if self.tracer is not None:
                span_args = {"node": node.nid, "prompt": req.prompt_len}
                if req.tenant is not None:
                    span_args["tenant"] = req.tenant
                self.tracer.async_begin(
                    "req", now, req.rid, cat="request",
                    process="requests", thread="lifecycle",
                    args=span_args)
            if not self._ingress_transfer(req, node.nid, now):
                self._pending[node.nid].append(req)

    def _ingress_transfer(self, req: Request, dst: int,
                          now: float) -> bool:
        """When a topology with an ingress is bound and prompts cost
        bytes, a routed request rides a Transmission from the ingress
        and only becomes pending when its last byte lands — a shared
        narrow uplink now costs real TTFT instead of being invisible to
        a per-node net counter."""
        topo = self.topology
        if (topo is None or topo.ingress is None
                or self.ingress_gb_per_token <= 0.0):
            return False
        name = Topology.replica_name(dst)
        if not topo.has_node(name):
            return False
        self._in_transit[dst].append(req)
        topo.transmit(
            topo.ingress, name,
            req.prompt_len * self.ingress_gb_per_token, now=now,
            tag="ingress",
            on_complete=lambda t, tr, rid=req.rid, d=dst:
                self._on_delivered(t, rid, d))
        return True

    def _on_delivered(self, t: float, rid: int, dst: int) -> None:
        req = self._by_rid[rid]
        self._in_transit[dst].remove(req)
        self._pending[dst].append(req)
        self._push_step(max(t, self._clocks[dst]), dst)

    # --- candidate filtering ---------------------------------------------
    def _candidates_for(self, ridx: int, now: float) -> List[Request]:
        """Replica ``ridx``'s pending requests its backend can
        physically join right now (position/window constraints), in
        placement order."""
        backend = self.backends[ridx]
        pending = self.queue.placement.order_jobs(
            list(self._pending[ridx]), now=now)
        if backend.position and \
                backend.position % backend.join_stride:
            return []  # joins quantize to the backend's sync points
        if backend.empty:
            # empty batch restarts: the backend picks the cohort that
            # can physically restart together (dense: greedy shared
            # position window; paged: page reservations)
            return backend.restart_cohort(pending)
        return backend.filter_joinable(pending)

    # --- KV migration (topology-bound clusters) ---------------------------
    def _live_kv_gb(self, ridx: int, req: Request) -> float:
        """The request's LIVE KV footprint on this backend — the paged
        ledger's allocated pages when there is one (what would actually
        move over the wire), the raw context length otherwise."""
        alloc = getattr(self.backends[ridx], "alloc", None)
        tokens = req.context_len
        if alloc is not None:
            try:
                tokens = len(alloc.pages_of(req.rid)) * alloc.page_size
            except KeyError:
                pass
        return self.demand.kv_gb(tokens)

    def _plan_migrations(self, evicted: Sequence[Request], ridx: int,
                         now: float) -> Dict[int, tuple]:
        """migrate-vs-recompute: for each evicted request, pick the
        adoptable replica with the cheapest MODELED transfer (path
        latency + KV bytes over the bottleneck link's residual fair
        share at current contention) and migrate iff that beats
        rebuilding the context locally.  Sized from the live paged
        footprint BEFORE the backend releases the pages.  Returns
        ``rid -> (dst nid, kv GB)``."""
        out: Dict[int, tuple] = {}
        topo = self.topology
        backend = self.backends[ridx]
        src = Topology.replica_name(ridx)
        if not topo.has_node(src):
            return out
        for r in evicted:
            recompute_s = backend.recompute_cost(r)
            if recompute_s is None:
                continue
            kv_gb = self._live_kv_gb(ridx, r)
            best = None
            for n in self.runtime.cluster:
                if n.nid == ridx or not n.up:
                    continue
                if not self.backends[n.nid].can_adopt:
                    continue
                name = Topology.replica_name(n.nid)
                if not topo.has_node(name):
                    continue
                est = topo.estimate_transfer_s(src, name, kv_gb)
                if best is None or (est, n.nid) < best[:2]:
                    best = (est, n.nid)
            if best is not None and best[0] < recompute_s:
                out[r.rid] = (best[1], kv_gb)
        return out

    def _start_migration(self, req: Request, src: int, dst: int,
                         kv_gb: float, now: float) -> None:
        self._in_transit[dst].append(req)
        node = self.runtime.cluster[dst]
        vec = self.demand.request_vector(req)
        if req.rid in node:
            node.rebook(req.rid, vec)
        else:
            node.book(req.rid, vec)   # committed load on the new home
        self.topology.transmit(
            Topology.replica_name(src), Topology.replica_name(dst),
            kv_gb, now=now, tag="kv-migration",
            on_complete=lambda t, tr, rid=req.rid, d=dst:
                self._on_kv_arrived(t, rid, d, tr))

    def _on_kv_arrived(self, t: float, rid: int, dst: int,
                       transmission) -> None:
        req = self._by_rid[rid]
        self._in_transit[dst].remove(req)
        self._kv_ready.add(rid)
        self._pending[dst].append(req)
        self.metrics.record_migration(transmission.duration_s)
        self._push_step(max(t, self._clocks[dst]), dst)

    # --- shared step application -----------------------------------------
    def _apply(self, plan: StepDecision, ridx: int, now: float) -> float:
        """Evict, requeue (same replica, or migrate the KV when the
        wire is cheaper than recompute), join/adopt.  Returns the join
        (prefill) cost."""
        running = self._running[ridx]
        batcher = self.batchers[ridx]
        # register shrink grants BEFORE joins run: the frozen granted
        # vector is sized at the plan-time context, and the backend's
        # join/prefill may advance it
        for rid, frac, slow in plan.shrunk:
            batcher.register_shrunk(self._by_rid[rid], frac, slow)
            if self.tracer is not None:
                self.tracer.instant(
                    "shrink", now, process=f"replica{ridx}",
                    thread="events",
                    args={"rid": rid, "fraction": frac,
                          "slowdown": slow})
        evicted = [self._by_rid[rid] for rid in plan.preempted]
        if evicted:
            moves = self._plan_migrations(evicted, ridx, now) \
                if (self.migrate and self.topology is not None) else {}
            self.backends[ridx].remove(evicted)
            for r in evicted:
                r.preemptions += 1
                running.remove(r)
                r.state = RequestState.QUEUED
                batcher.shrunk.pop(r.rid, None)
                if r.rid in moves:
                    dst, kv_gb = moves[r.rid]
                    self._start_migration(r, ridx, dst, kv_gb, now)
                else:
                    self._pending[ridx].append(r)
        joined = [self._by_rid[rid] for rid in plan.admitted]
        dt = 0.0
        if joined:
            taken = {id(r) for r in joined}
            self._pending[ridx] = [r for r in self._pending[ridx]
                                   if id(r) not in taken]
            adopted = [r for r in joined if r.rid in self._kv_ready]
            fresh = [r for r in joined if r.rid not in self._kv_ready]
            if adopted:
                # KV already landed over the wire: seat without prefill
                dt += self.backends[ridx].adopt(adopted, now)
                for r in adopted:
                    self._kv_ready.discard(r.rid)
            if fresh:
                dt += self.backends[ridx].join(fresh, now)
            for r in joined:
                r.admissions += 1
                r.state = RequestState.RUNNING
                if self.tracer is not None:
                    self.tracer.instant(
                        "join", now, process=f"replica{ridx}",
                        thread="events", args={"rid": r.rid})
            running.extend(joined)
        return dt

    def _retire(self, ridx: int, now: float) -> None:
        running = self._running[ridx]
        done = [r for r in running if r.done]
        if done:
            self.backends[ridx].remove(done)
            for r in done:
                r.state = RequestState.FINISHED
                r.finish_t = now
                running.remove(r)
                self.batchers[ridx].shrunk.pop(r.rid, None)
                if self.tenancy is not None:
                    self.tenancy.observe_request(r)
                if self.autoscaler is not None:
                    self.autoscaler.observe_finished(r.meets_slo())
                self._trace_req_end(r, now)

    def _trace_req_end(self, r: Request, now: float) -> None:
        """Close the request's async lifecycle span.  ``t1`` carries the
        raw virtual seconds so the trace report can recompute goodput
        (tokens / elapsed) bit-identically — the µs timestamp alone
        loses float precision on the round-trip."""
        if self.tracer is not None:
            end_args = {"tokens": r.tokens_decoded, "t1": now}
            if r.tenant is not None:
                end_args["tenant"] = r.tenant
            self.tracer.async_end(
                "req", now, r.rid, cat="request", process="requests",
                thread="lifecycle", args=end_args)

    def _sync_node(self, ridx: int) -> None:
        """Reconcile the replica Node's claim ledger with its committed
        load — the running set plus the locally-queued set (queued
        requests booked at route time; preempted ones requeue locally
        and stay booked).  After every step the node's booked vector ==
        weights + sum of committed request demands (the conservation
        invariant ``tests/test_cluster.py`` pins)."""
        node = self.runtime.cluster[ridx]
        live = {r.rid: r for r in self._running[ridx]}
        for r in self._pending[ridx]:
            live[r.rid] = r
        for r in self._in_transit[ridx]:
            live[r.rid] = r           # inbound KV/prompt: committed load
        for key in node.keys():
            if key != _WEIGHTS_KEY and key not in live:
                node.release(key)
        by_tenant: Dict[Optional[str], ResourceVector] = {}
        shrunk = self.batchers[ridx].shrunk
        for rid, r in live.items():
            fs = shrunk.get(rid)
            # a live shrink grant books its FROZEN granted vector (the
            # spilled remainder is off-budget by construction)
            vec = fs[2] if fs is not None \
                else self.demand.request_vector(r)
            if rid in node:
                node.rebook(rid, vec)
            else:
                node.book(rid, vec)
            if self.tenancy is not None:
                by_tenant[r.tenant] = \
                    by_tenant.get(r.tenant, ResourceVector()) + vec
        if self.tenancy is not None:
            # registry ledger follows the node ledger exactly
            self.tenancy.set_node_usage(ridx, by_tenant)

    # --- elastic runtime: failures and autoscaling ------------------------
    def _fail_replica(self, t: float, ridx: int) -> None:
        """Failure injection: the replica goes dark.  Its live requests
        drain through the existing migrate-vs-recompute path (a
        controlled drain ships KV when the wire beats recompute;
        otherwise the request requeues and recomputes), its queued
        requests re-route to live replicas as requeue-origin work, and
        its step chain dies until repair."""
        if ridx in self._failed or ridx >= self.replicas:
            return
        self._failed.add(ridx)
        node = self.runtime.cluster[ridx]
        node.up = False
        self.metrics.record_replica_event("fail")
        running = self._running[ridx]
        if running:
            moves = self._plan_migrations(running, ridx, t) \
                if (self.migrate and self.topology is not None) else {}
            self.backends[ridx].remove(running)
            batcher = self.batchers[ridx]
            for r in list(running):
                r.preemptions += 1
                r.state = RequestState.QUEUED
                batcher.shrunk.pop(r.rid, None)
                if r.rid in moves:
                    dst, kv_gb = moves[r.rid]
                    self._start_migration(r, ridx, dst, kv_gb, t)
                else:
                    self._pending[ridx].append(r)
            running.clear()
        self._drain_pending(ridx, t)
        self._sync_node(ridx)

    def _repair_replica(self, t: float, ridx: int) -> None:
        """The failed replica comes back empty (weights resident, no
        KV) and re-enters routing; a fresh step chain re-admits
        whatever parked on it while everything else was down."""
        if ridx not in self._failed:
            return
        self._failed.discard(ridx)
        self.runtime.cluster[ridx].up = True
        self.metrics.record_replica_event("repair")
        self._push_step(max(t, self._clocks[ridx]), ridx)

    def _drain_pending(self, ridx: int, t: float) -> None:
        """Re-route a down replica's queued requests to live replicas
        (requeue-origin re-admission: they keep their admission /
        preemption history).  Routers fall back to down nodes when
        nothing is up, so a candidate that routes back to a down node
        parks locally and re-enters service on repair."""
        stranded = list(self._pending[ridx])
        if not stranded:
            return
        self._pending[ridx] = []
        woken = set()
        for req in stranded:
            vec = self.demand.request_vector(req)
            node = self.runtime.route(vec, now=t, tenant=req.tenant)
            if not node.up or node.nid == ridx \
                    or node.nid in self._failed:
                self._pending[ridx].append(req)   # nowhere to go
                continue
            self._pending[node.nid].append(req)
            woken.add(node.nid)
        for nid in sorted(woken):
            self._sync_node(nid)
            self._push_step(max(t, self._clocks[nid]), nid)

    def _on_autoscale(self, t: float, _payload) -> Optional[bool]:
        """One autoscaler tick: observe queue depth and SLO attainment,
        spawn a spare (topology-aware: the rack with the most ingress
        uplink headroom) or drain the emptiest autoscaled replica, then
        re-arm — until no work remains anywhere."""
        aus = self.autoscaler
        depth = sum(len(p) for p in self._pending) \
            + sum(len(x) for x in self._in_transit)
        busy = any(self._running)
        if depth == 0 and not busy \
                and self.queue.next_arrival() is None:
            return False          # drained for good: stop the re-arm
        active = [n.nid for n in self.runtime.cluster
                  if n.up and n.nid not in self._failed]
        action = aus.observe(t, queue_depth=float(depth),
                             active=len(active))
        if action == "up":
            spares = [n.nid for n in self.runtime.cluster
                      if not n.up and n.nid not in self._failed]
            nid = pick_spawn_node(spares, self.topology)
            if nid is not None:
                self.runtime.cluster[nid].up = True
                self.metrics.record_replica_event("scale_up")
                if self.tracer is not None:
                    self.tracer.instant(
                        "scale-up", t, process="autoscaler",
                        thread="events", args={"node": nid})
                self._push_step(max(t, self._clocks[nid]), nid)
        elif action == "down":
            # only autoscaled replicas drain; the base fleet persists
            cands = [nid for nid in active
                     if nid >= self._initial_replicas]
            if cands:
                nid = min(cands, key=lambda n: (
                    len(self._running[n]) + len(self._pending[n])
                    + len(self._in_transit[n]), -n))
                self.runtime.cluster[nid].up = False
                self.metrics.record_replica_event("scale_down")
                if self.tracer is not None:
                    self.tracer.instant(
                        "scale-down", t, process="autoscaler",
                        thread="events", args={"node": nid})
                # queued work re-routes now; running work finishes on
                # the draining replica (its step chain keeps going)
                self._drain_pending(nid, t)
                self._sync_node(nid)
        self.runtime.push(t + aus.interval_s, Autoscaler.KIND, None)

    # --- the loops --------------------------------------------------------
    def run(self) -> Dict:
        t = self._run_continuous() if self.mode == "continuous" \
            else self._run_wave()
        if self.topology is not None:
            self.metrics.record_link_stats(
                self.topology.link_stats(now=t, elapsed=t))
        return self.metrics.summary(elapsed=t)

    # --- continuous mode: step events on the ClusterRuntime ---------------
    def _push_step(self, t: float, ridx: int) -> None:
        """Schedule replica ``ridx``'s next step.  With no topology the
        payload is the bare replica index — the exact legacy event
        stream, bit-identical.  With one, transmission completions can
        wake a replica that already has a step outstanding, so payloads
        carry a generation and each push supersedes the previous event
        (at most one LIVE step per replica — the same stale-event
        discipline as the simulator's re-timed finishes).  Failure
        injection and autoscaling wake replicas the same way (repair,
        scale-up), so they force generation payloads too."""
        if self.topology is None and self.failures is None \
                and self.autoscaler is None:
            self.runtime.push(t, "step", ridx)
        else:
            self._step_gen[ridx] += 1
            self.runtime.push(t, "step", (ridx, self._step_gen[ridx]))

    def _on_step(self, t: float, payload):
        """One decode step on a replica — or an idle wake that consumes
        the next arrival.  Exactly the body of the pre-runtime
        sequential loop, dispatched per replica by the event clock."""
        if isinstance(payload, tuple):
            ridx, gen = payload
            if gen != self._step_gen[ridx]:
                return False          # superseded by a delivery wake
        else:
            ridx = payload
        if ridx in self._failed:
            return False  # failed replica: chain dies; repair re-pushes
        self._route_released(t)
        running = self._running[ridx]
        cands = self._candidates_for(ridx, t)
        if not running and not cands:
            nxt = self.queue.next_arrival()
            if nxt is None:
                if self._pending[ridx]:
                    # pending exists but nothing can join (should be
                    # impossible: empty batch accepts any valid request)
                    raise RuntimeError("serving deadlock: pending "
                                       "requests but no candidates")
                return False  # replica idle for good: chain ends
            self._push_step(nxt, ridx)
            return False      # idle wake, not a planned step
        plan = self.batchers[ridx].plan_step(running, cands, t,
                                             self._step_no)
        dt_join = self._apply(plan, ridx, t)
        dt_decode = self.backends[ridx].decode(running)
        shrunk = self.batchers[ridx].shrunk
        if shrunk:
            # a decode step is lockstep across the batch: the slowest
            # member — the deepest shrink grant, paying its modeled
            # spill slowdown — sets the step time
            dt_decode *= max((shrunk[r.rid][1] for r in running
                              if r.rid in shrunk), default=1.0)
        dt = dt_join + dt_decode
        t_end = t + dt
        self._step_no += 1
        for r in running:
            # chunked-prefill backends keep a request running before it
            # has emitted anything; TTFT stamps only once a token exists
            if r.first_token_t is None and r.tokens_decoded:
                r.first_token_t = t_end
        self._retire(ridx, t_end)
        self._sync_node(ridx)
        self.metrics.record_step(plan, dt)
        if self.tenancy is not None:
            self._observe_tenancy(plan, ridx)
        if self.tracer is not None:
            self._trace_step(plan, ridx, t, t_end, dt_join)
        if self._step_no > self.max_steps:
            raise RuntimeError(
                f"engine exceeded its structural step bound "
                f"({self.max_steps}) — termination invariant broken")
        self._clocks[ridx] = t_end
        self._push_step(t_end, ridx)

    def _observe_tenancy(self, plan: StepDecision, ridx: int) -> None:
        """Fold one step into the fairness state: per-tenant reject
        signals (requeue-vs-new, so preemption churn doesn't read as
        demand mis-prediction) into the registry's credit windows and
        the metrics' per-tenant counters, plus a dominant-share sample
        per named tenant on the stepping node."""
        reg = self.tenancy
        for rid in plan.rejected_rids:
            r = self._by_rid[rid]
            origin = "requeue" if (r.admissions > 0
                                   or r.preemptions > 0) else "new"
            reg.observe_reject(r.tenant, origin, now=plan.t)
            self.metrics.record_tenant_reject(r.tenant, origin)
        node = self.runtime.cluster[ridx]
        for name in reg.names():
            if name is None:
                continue
            self.metrics.record_tenant_share(
                name, reg.dominant_share(reg.usage(name, ridx),
                                         node.capacity))

    def _trace_step(self, plan: StepDecision, ridx: int, t: float,
                    t_end: float, dt_join: float) -> None:
        """One 'step' span per planned step on the replica's track,
        split into prefill/decode sub-phases, with preempt/forced
        instants and per-axis node utilization counter samples.  The
        span args carry raw virtual seconds ('t0'/'t1') so the report's
        busy-time integral is float-exact, not a µs round-trip."""
        proc = f"replica{ridx}"
        tr = self.tracer
        tr.complete("step", t, t_end, process=proc, thread="steps",
                    cat="serving",
                    args={"step": plan.step, "batch": plan.batch,
                          "admitted": len(plan.admitted),
                          "preempted": len(plan.preempted),
                          "binding": plan.binding_axis,
                          "t0": t, "t1": t_end})
        if dt_join > 0.0:
            tr.complete("prefill", t, t + dt_join, process=proc,
                        thread="phases", cat="serving",
                        args={"t0": t, "t1": t + dt_join})
        if t_end > t + dt_join:
            tr.complete("decode", t + dt_join, t_end, process=proc,
                        thread="phases", cat="serving",
                        args={"t0": t + dt_join, "t1": t_end})
        for rid in plan.preempted:
            tr.instant("preempt", t, process=proc, thread="events",
                       args={"rid": rid})
        if plan.forced:
            tr.instant("forced", t, process=proc, thread="events",
                       args={"rids": list(plan.forced_rids)})
        node = self.runtime.cluster[ridx]
        tr.counter(f"node{ridx}:util", t_end,
                   {axis: node.utilization(axis)
                    for axis in node.capacity.axes}, process=proc)
        sample_node(self.telemetry, node, t_end)

    def _run_continuous(self) -> float:
        self.runtime.on("step", self._on_step)
        if self.failures is not None:
            # failures target the base fleet; autoscaled spares are the
            # relief capacity
            self.failures.attach(
                self.runtime, on_fail=self._fail_replica,
                on_repair=self._repair_replica,
                n_targets=self._initial_replicas)
        if self.autoscaler is not None:
            self.runtime.on(Autoscaler.KIND, self._on_autoscale)
            self.runtime.push(self.autoscaler.interval_s,
                              Autoscaler.KIND, None)
        for ridx in range(self._initial_replicas):
            self._push_step(0.0, ridx)
        self.runtime.run()
        return max(self._clocks)

    # --- wave mode (legacy, single replica) -------------------------------
    def _wave_admission(self, cands: Sequence[Request]):
        """Once-per-wave admission against the worst-case footprint:
        every slot booked at the wave's longest full context (the
        pre-engine ``launch/serve.py`` behaviour)."""
        lmax = max(r.prefill_len + r.remaining_new for r in cands)
        curves = {"hbm": MemoryFunction(
            "affine", self.demand.weights_gb,
            self.demand.kv_gb(lmax))}
        for axis, per_req in self.demand.per_request_axes().items():
            curves[axis] = MemoryFunction("affine", 0.0, per_req)
        dm = DemandModel(curves, primary_axis="hbm")
        return self.controller.admit_batch(
            dm, self.budget, min_batch=1,
            max_batch=min(self.max_batch, len(cands)))

    def _run_wave(self) -> float:
        t, step = 0.0, 0
        while self.queue.next_arrival() is not None or self._pending[0]:
            self._route_released(t)
            cands = self._candidates_for(0, t)
            if not cands:
                nxt = self.queue.next_arrival()
                if nxt is None:
                    raise RuntimeError("serving deadlock in wave mode")
                t = nxt
                continue
            dec = self._wave_admission(cands)
            wave = cands[:int(dec.units)]
            forced = bool(dec.info.get("forced"))
            plan = StepDecision(
                step=step, t=t, admitted=tuple(r.rid for r in wave),
                preempted=(), batch=len(wave),
                booked=self.demand.booked(wave, 0), budget=self.budget,
                binding_axis=dec.binding_axis,
                forced=forced,
                forced_axes=tuple(dec.info.get("forced_axes", ())),
                # the unified record shape: a forced wave names every
                # request it force-admitted, like the batcher's floor
                forced_rids=tuple(r.rid for r in wave) if forced else ())
            dt = self._apply(plan, 0, t)
            wave_live = [self._by_rid[rid] for rid in plan.admitted]
            self.metrics.record_step(plan, dt)
            step += 1            # step ids stay unique and monotone
            t += dt
            for r in wave_live:  # the wave's prefill emitted one token
                if r.first_token_t is None and r.tokens_decoded:
                    r.first_token_t = t
            self._sync_node(0)
            # drain the whole wave: finished requests idle in their
            # slots (full-occupancy step cost) until the last finishes
            while any(not r.done for r in wave_live):
                sdt = self.backend.decode(wave_live)
                t += sdt
                for r in wave_live:
                    if r.first_token_t is None and r.tokens_decoded:
                        r.first_token_t = t
                self.metrics.record_step(StepDecision(
                    step=step, t=t, admitted=(), preempted=(),
                    batch=len(wave_live),
                    booked=self.demand.booked(wave_live, 0),
                    budget=self.budget, binding_axis=None,
                    forced=plan.forced,
                    forced_axes=plan.forced_axes,
                    forced_rids=plan.forced_rids), sdt)
                step += 1
                if step > self.max_steps:
                    raise RuntimeError("wave mode exceeded its "
                                       "structural step bound")
            for r in wave_live:
                r.state = RequestState.FINISHED
                r.finish_t = t
                self._running[0].remove(r)
                if self.tenancy is not None:
                    self.tenancy.observe_request(r)
                self._trace_req_end(r, t)
            self.backend.remove(wave_live)
            self._sync_node(0)
        return t
