"""Arrival-fed request queue with pluggable placement ordering.

Bridges :mod:`repro.sched.arrivals` (timed streams over an application
universe) to the serving engine: :func:`requests_from_arrivals` maps each
:class:`~repro.sched.arrivals.Arrival` to a :class:`Request` whose prompt
length derives from the arrival's input size, and :class:`RequestQueue`
releases requests as virtual time passes, handing the engine a pending
list ordered by a :class:`~repro.sched.placement.PlacementPolicy`
(fcfs / sjf / best-fit / arrival-aware — the same registry the cluster
simulator uses).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.sched.arrivals import Arrival
from repro.sched.placement import PlacementPolicy, get_placement
from repro.serve.request import Request, RequestState


def requests_from_arrivals(arrivals: Sequence[Arrival], *,
                           max_new_tokens: int = 32,
                           prompt_scale: float = 1.0,
                           min_prompt: int = 1,
                           max_prompt: Optional[int] = None,
                           seed: int = 0,
                           vary_new: bool = True) -> List[Request]:
    """Turn a sched arrival stream into serving requests.

    ``items`` (M-items in the cluster universes) becomes the prompt
    length via ``prompt_scale`` (clamped to ``[min_prompt, max_prompt]``);
    ``max_new_tokens`` is drawn uniformly from ``[max_new/2, max_new]``
    per request when ``vary_new`` (heterogeneous decode lengths are what
    make continuous batching beat waves), else fixed.
    """
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    for i, a in enumerate(sorted(arrivals, key=lambda x: x.t)):
        plen = int(round(float(a.items) * prompt_scale))
        plen = max(plen, min_prompt)
        if max_prompt is not None:
            plen = min(plen, max_prompt)
        new = int(rng.integers(max(max_new_tokens // 2, 1),
                               max_new_tokens + 1)) if vary_new \
            else int(max_new_tokens)
        out.append(Request(rid=i, prompt_len=plen, max_new_tokens=new,
                           arrival=float(a.t),
                           tenant=getattr(a, "tenant", None)))
    return out


class RequestQueue:
    """Time-gated pending queue over a fixed request population.

    ``release(now)`` moves arrived requests into the pending set;
    ``pending(now)`` returns them in placement order (re-ordered every
    call — arrival-aware urgency changes as time passes); ``requeue``
    returns a preempted request.  The queue never drops a request: every
    request handed in is eventually surfaced by ``pending`` until the
    engine marks it FINISHED.
    """

    def __init__(self, requests: Sequence[Request],
                 placement: Union[str, PlacementPolicy] = "fcfs"):
        self.placement = get_placement(placement) \
            if isinstance(placement, str) else placement
        self._future: List[Request] = sorted(requests,
                                             key=lambda r: (r.arrival, r.rid))
        self._pending: List[Request] = []

    # --- time ------------------------------------------------------------
    def release(self, now: float) -> int:
        """Move requests with ``arrival <= now`` into the pending set."""
        out = self.drain_released(now)
        self._pending.extend(out)
        return len(out)

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival if self._future else None

    def drain_released(self, now: float) -> List[Request]:
        """Pop requests with ``arrival <= now`` and return them in
        arrival order WITHOUT entering the pending set — the
        multi-replica engine routes each released request to a node's
        own pending list instead (``repro.sched.cluster`` Router)."""
        out: List[Request] = []
        while self._future and self._future[0].arrival <= now + 1e-12:
            out.append(self._future.pop(0))
        return out

    # --- pending ---------------------------------------------------------
    def pending(self, now: float = 0.0,
                joinable: Optional[Callable[[Request], bool]] = None
                ) -> List[Request]:
        """Released-but-not-running requests in placement order, optionally
        filtered by a backend joinability predicate."""
        reqs = self.placement.order_jobs(list(self._pending), now=now)
        if joinable is not None:
            reqs = [r for r in reqs if joinable(r)]
        return reqs

    def take(self, reqs: Sequence[Request]) -> None:
        """Remove admitted requests from the pending set."""
        admitted = {id(r) for r in reqs}
        self._pending = [r for r in self._pending
                         if id(r) not in admitted]

    def requeue(self, req: Request) -> None:
        """Return a preempted request (keeps its generated tokens; its KV
        will be recomputed on re-admission)."""
        req.state = RequestState.QUEUED
        self._pending.append(req)

    # --- bookkeeping ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._future) + len(self._pending)

    @property
    def drained(self) -> bool:
        return not self._future and not self._pending
