"""Serving-side metrics: TTFT, TPOT, goodput, preemption rate, and the
per-step binding-axis view.

The cluster simulator reports STP/ANTT per job; a serving system is
judged per *token*:

* **TTFT** — time to first token: first decoded token's timestamp minus
  arrival (queueing + prefill; preemption does not reset it).
* **TPOT** — time per output token after the first (decode cadence,
  averaged over each request's stream).
* **goodput** — completed requests' generated tokens per second of
  engine time: tokens of requests that never finished do not count, so
  over-admission that thrashes shows up as a goodput LOSS even though
  raw step throughput looks busy.
* **preemption rate** — evictions per admission (an admission is the
  first join or any re-join after eviction).
* **binding axes** — which resource axis bound each step's join inverse,
  histogrammed exactly like the simulator's per-axis counters, plus
  forced-step and occupancy accounting.  Forced admissions are counted
  from the unified ``StepDecision.forced_rids`` record (the continuous
  floor and the legacy wave path fill the same field).
* **SLO goodput** — tokens per second from completed requests that met
  BOTH their declared deadlines (``Request.ttft_deadline`` /
  ``tpot_deadline``): raw goodput that blows latency targets does not
  count, which is the serving analogue of counting only useful work.
* **node steps** — planned decode steps per replica
  :class:`~repro.sched.cluster.Node` (router observability).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.batcher import StepDecision
from repro.serve.request import Request, RequestState


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) \
        else 0.0


class ServingMetrics:
    """Accumulates per-step decisions and per-request lifecycles; the
    engine owns the timestamps (virtual time, so identical seeds give
    identical metrics)."""

    def __init__(self):
        self.steps: List[StepDecision] = []
        self.step_times: List[float] = []
        self.requests: List[Request] = []
        self._admissions = 0
        self._preemptions = 0
        self._forced_steps = 0
        self._forced_admissions = 0
        self.binding_axes: Dict[str, int] = {}
        self.node_steps: Dict[int, int] = {}
        #: completed KV-migration transfer durations (topology runs)
        self.kv_transfer_s: List[float] = []
        #: join candidates declined, histogrammed by the axis that
        #: bound the join inverse ("cap" when no axis was recorded)
        self.rejects_by_axis: Dict[str, int] = {}
        self._rejected_joins = 0
        #: the same declines split by provenance: "new" = first-offer
        #: work that didn't fit (demand mis-prediction), "requeue" =
        #: preempted work bouncing off re-admission (scheduler churn)
        self.rejects_by_origin: Dict[str, int] = {}
        #: per-link utilization (topology runs; see Topology.link_stats)
        self.link_stats: Dict[str, Dict] = {}
        #: per-tenant fairness accounting (tenancy runs; empty dicts
        #: otherwise, so untenanted summaries stay shape-stable)
        self._tenant_shares: Dict[str, List[float]] = {}
        self._tenant_rejects: Dict[str, Dict[str, int]] = {}
        #: elastic-runtime accounting (shrink grants, replica
        #: fail/repair, autoscale up/down).  All zero outside elastic
        #: runs, and the summary only carries an ``elastic`` section
        #: when something fired — flags-off summaries stay
        #: bit-identical to the pre-elastic shape.
        self._shrunk_joins = 0
        self._replica_events: Dict[str, int] = {}

    # --- recording --------------------------------------------------------
    def record_step(self, dec: StepDecision, dt: float) -> None:
        self.steps.append(dec)
        self.step_times.append(float(dt))
        self._admissions += len(dec.admitted)
        self._preemptions += len(dec.preempted)
        if dec.forced:
            self._forced_steps += 1
            # the unified per-request record: which rids ran over budget
            self._forced_admissions += len(dec.forced_rids)
        if dec.binding_axis is not None and dec.admitted:
            self.binding_axes[dec.binding_axis] = \
                self.binding_axes.get(dec.binding_axis, 0) + 1
        rejected = getattr(dec, "rejected", 0)
        if rejected:
            self._rejected_joins += rejected
            axis = getattr(dec, "reject_axis", None) or "cap"
            self.rejects_by_axis[axis] = \
                self.rejects_by_axis.get(axis, 0) + rejected
            new = getattr(dec, "rejected_new", 0)
            requeue = getattr(dec, "rejected_requeue", 0)
            if new or requeue:
                if new:
                    self.rejects_by_origin["new"] = \
                        self.rejects_by_origin.get("new", 0) + new
                if requeue:
                    self.rejects_by_origin["requeue"] = \
                        self.rejects_by_origin.get("requeue", 0) + requeue
        self.node_steps[dec.node] = self.node_steps.get(dec.node, 0) + 1
        shrunk = getattr(dec, "shrunk", ())
        if shrunk:
            self._shrunk_joins += len(shrunk)

    def record_request(self, req: Request) -> None:
        self.requests.append(req)

    def record_migration(self, duration_s: Optional[float]) -> None:
        """A preempted request's KV landed on another replica after
        riding a Transmission for ``duration_s`` virtual seconds."""
        if duration_s is not None:
            self.kv_transfer_s.append(float(duration_s))

    def record_link_stats(self, stats: Dict[str, Dict]) -> None:
        """Attach the topology's end-of-run per-link ledger (busy
        seconds/fraction, GB moved, peak concurrent flows)."""
        self.link_stats = {name: dict(st) for name, st in stats.items()}

    def record_replica_event(self, kind: str) -> None:
        """One elastic-runtime replica event: ``fail`` / ``repair``
        (failure injection) or ``scale_up`` / ``scale_down``
        (autoscaler)."""
        self._replica_events[kind] = self._replica_events.get(kind, 0) + 1

    def record_tenant_share(self, tenant: str, share: float) -> None:
        """One dominant-share sample (usage fraction of the binding
        axis) for a named tenant — the engine samples once per planned
        step on the stepping node."""
        self._tenant_shares.setdefault(tenant, []).append(float(share))

    def record_tenant_reject(self, tenant: Optional[str],
                             origin: str) -> None:
        """One declined join candidate attributed to its tenant, split
        by requeue-vs-new origin (untenanted requests bucket under
        ``""``)."""
        by = self._tenant_rejects.setdefault(tenant or "", {})
        by[origin] = by.get(origin, 0) + 1

    # --- summary ----------------------------------------------------------
    def summary(self, elapsed: Optional[float] = None) -> Dict:
        done = [r for r in self.requests
                if r.state == RequestState.FINISHED]
        elapsed = float(elapsed if elapsed is not None
                        else (self.steps[-1].t + self.step_times[-1]
                              if self.steps else 0.0))
        ttft = [r.first_token_t - r.arrival for r in done
                if r.first_token_t is not None]
        tpot = [(r.finish_t - r.first_token_t) / (r.tokens_decoded - 1)
                for r in done
                if r.finish_t is not None and r.first_token_t is not None
                and r.tokens_decoded > 1]
        good_tokens = sum(r.tokens_decoded for r in done)
        slo_done = [r for r in done if r.meets_slo()]
        slo_tokens = sum(r.tokens_decoded for r in slo_done)
        batches = [d.batch for d in self.steps if d.batch > 0]
        # per-tenant fairness view: goodput / SLO attainment / dominant
        # share per named tenant (empty when no request carries one)
        tnames = sorted({r.tenant for r in self.requests
                         if r.tenant is not None}
                        | set(self._tenant_shares)
                        | {k for k in self._tenant_rejects if k})
        tenants: Dict[str, Dict] = {}
        for name in tnames:
            treqs = [r for r in self.requests if r.tenant == name]
            tdone = [r for r in treqs
                     if r.state == RequestState.FINISHED]
            tslo = [r for r in tdone if r.meets_slo()]
            tgood = sum(r.tokens_decoded for r in tdone)
            tslo_tok = sum(r.tokens_decoded for r in tslo)
            shares = self._tenant_shares.get(name, [])
            tenants[name] = {
                "requests": len(treqs),
                "completed": len(tdone),
                "good_tokens": tgood,
                "goodput_tok_s": tgood / max(elapsed, 1e-12),
                "slo_good_tokens": tslo_tok,
                "slo_goodput_tok_s": tslo_tok / max(elapsed, 1e-12),
                "slo_attainment": len(tslo) / max(len(tdone), 1),
                "dominant_share_mean": float(np.mean(shares))
                if shares else 0.0,
                "dominant_share_peak": float(np.max(shares))
                if shares else 0.0,
                "rejects": dict(self._tenant_rejects.get(name, {})),
            }
        out = {
            "requests": len(self.requests),
            "completed": len(done),
            "steps": len(self.steps),
            "elapsed_s": elapsed,
            "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
            "ttft_p95_s": _pct(ttft, 95),
            "tpot_mean_s": float(np.mean(tpot)) if tpot else 0.0,
            "goodput_tok_s": good_tokens / max(elapsed, 1e-12),
            "goodput_req_s": len(done) / max(elapsed, 1e-12),
            "good_tokens": good_tokens,
            # SLO goodput: only tokens of requests that met BOTH
            # deadlines count (requests with no deadlines always do)
            "slo_goodput_tok_s": slo_tokens / max(elapsed, 1e-12),
            "slo_good_tokens": slo_tokens,
            "slo_attainment": len(slo_done) / max(len(done), 1),
            "admissions": self._admissions,
            "preemptions": self._preemptions,
            "preemption_rate": self._preemptions
            / max(self._admissions, 1),
            "forced_steps": self._forced_steps,
            "forced_admissions": self._forced_admissions,
            "mean_batch": float(np.mean(batches)) if batches else 0.0,
            "binding_axes": dict(self.binding_axes),
            "node_steps": dict(self.node_steps),
            "migrations": len(self.kv_transfer_s),
            "kv_transfer_p99_s": _pct(self.kv_transfer_s, 99),
            # structured join-reject accounting (satellite of the obs
            # PR): deterministic, so goldens may pin these too
            "rejected_joins": self._rejected_joins,
            "rejects_by_axis": dict(self.rejects_by_axis),
            "rejects_by_origin": dict(self.rejects_by_origin),
            "links": {name: dict(st)
                      for name, st in self.link_stats.items()},
            "tenants": tenants,
        }
        if self._shrunk_joins or self._replica_events:
            out["elastic"] = {
                "shrunk_joins": self._shrunk_joins,
                "replica_events": dict(self._replica_events),
            }
        return out

    def format_summary(self, s: Optional[Dict] = None) -> str:
        s = s or self.summary()
        axes = " ".join(f"{a}:{n}" for a, n in
                        sorted(s["binding_axes"].items())) or "-"
        return (f"{s['completed']}/{s['requests']} requests in "
                f"{s['elapsed_s']:.2f}s ({s['steps']} steps, mean batch "
                f"{s['mean_batch']:.1f}) | goodput "
                f"{s['goodput_tok_s']:.1f} tok/s | TTFT "
                f"{s['ttft_mean_s'] * 1e3:.0f}ms (p95 "
                f"{s['ttft_p95_s'] * 1e3:.0f}ms) | TPOT "
                f"{s['tpot_mean_s'] * 1e3:.1f}ms | preemptions "
                f"{s['preemptions']} ({s['preemption_rate']:.2f}/adm) | "
                f"forced {s['forced_steps']} | binding [{axes}]")
