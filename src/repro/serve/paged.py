"""Page-granular KV backends: block allocation + chunked prefill.

The dense backends in ``repro.serve.backends`` keep ONE shared cache
position — every joiner left-pads to it, ``joinable`` demands
``prefill_len <= position``, and a join prefills the whole prompt in one
stall.  The backends here lift all three at once:

* :class:`PageAllocator` — a free-list over fixed-size token pages with
  two ledgers: live pages (exactly ``ceil(context / page)`` per request
  at every step — the conservation invariant the tests pin) and
  worst-case reservations made at join time, so on-demand page growth
  can never fail mid-decode (the paged analogue of the dense backend's
  ``position + remaining <= max_len`` join gate).
* :class:`PagedSimBackend` — the virtual-time cost model with paged
  residency accounting and chunked prefill; what the benchmarks and
  tier-1 invariant tests run.
* :class:`DenseSimBackend` — a virtual-time twin of ``JaxBackend``'s
  dense-cache semantics (shared sync-strided position, bucketed batch,
  full-prompt prefill at the padded length, ``max_len`` slot residency)
  so goodput-per-HBM comparisons against the paged backend need no jax.
* :class:`PagedJaxBackend` — the real thing: drives
  ``build_prefill_chunk_step`` / ``build_paged_decode_step`` (and
  through them the paged-attention kernel path) over a shared page pool
  with per-request page tables and lengths.

Joining never depends on a shared position (``join_stride == 1``,
``position == 0``): a request joins whenever its worst-case pages fit
the pool, and its prompt prefills in ``prefill_chunk``-token slices
interleaved with the running batch's decode steps — TTFT of incumbents
stops stalling on a long joining prompt.

Residency accounting: each backend samples ``(resident, live)`` KV
tokens at every decode step; ``waste_ratio()`` is the padding waste the
benchmarks compare (dense residency counts the full ``bucket(batch) *
max_len`` slot grid; paged residency counts allocated pages only).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.backends import (PAD_ID, Backend, SimBackend, _bucket,
                                  _shrink_bucket)
from repro.serve.request import Request


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV entries."""
    return -(-max(int(tokens), 0) // int(page_size))


class PageAllocator:
    """Free-list allocator over a fixed pool of KV pages.

    Page 0 is the scratch page — padding rows and parked table slots
    point at it so every gather hits a valid page — and is never handed
    out.  ``reserve`` admits a request's worst-case page count up front;
    ``grow_to`` then allocates live pages on demand as its context
    crosses page boundaries, guaranteed to succeed because live pages
    never exceed reservations and reservations never exceed the pool.
    """

    def __init__(self, num_pages: int, page_size: int):
        if int(num_pages) < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is the "
                             "scratch page)")
        if int(page_size) < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # pop() from the tail hands out page 1 first — deterministic
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._reserved: Dict[int, int] = {}     # rid -> worst-case pages
        self._live: Dict[int, List[int]] = {}   # rid -> live page ids

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return sum(len(p) for p in self._live.values())

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved.values())

    def can_reserve(self, pages: int) -> bool:
        return self.reserved_pages + pages <= self.usable_pages

    def reserve(self, rid: int, pages: int) -> None:
        if rid in self._reserved:
            raise RuntimeError(f"request {rid} already reserved")
        if not self.can_reserve(pages):
            raise RuntimeError(
                f"reservation of {pages} pages for request {rid} "
                f"exceeds the pool ({self.reserved_pages} reserved of "
                f"{self.usable_pages})")
        self._reserved[rid] = int(pages)
        self._live[rid] = []

    def grow_to(self, rid: int, tokens: int) -> List[int]:
        """Grow ``rid``'s live pages to cover ``tokens`` context tokens;
        returns its (ordered) page list."""
        need = pages_for(tokens, self.page_size)
        pages = self._live[rid]
        assert need <= self._reserved[rid], \
            (rid, tokens, need, self._reserved[rid])
        while len(pages) < need:
            pages.append(self._free.pop())
        return pages

    def pages_of(self, rid: int) -> List[int]:
        return self._live[rid]

    def release(self, rid: int) -> None:
        pages = self._live.pop(rid, [])
        self._free.extend(reversed(pages))
        self._reserved.pop(rid, None)


class _PagedScheduler:
    """The scheduling state machine both paged backends share: join
    reservations, per-request prefill progress, which rows chunk vs
    decode each step, and residency sampling.  Subclasses implement the
    actual chunk/decode compute (synthetic or jax)."""

    def __init__(self, num_pages: int, page_size: int,
                 prefill_chunk: int, timer: SimBackend):
        self.alloc = PageAllocator(num_pages, page_size)
        self.page_size = int(page_size)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        #: one request's table can span the whole usable pool — the
        #: engine validates prompt + max_new against this
        self.max_len = self.alloc.usable_pages * self.page_size
        self._timer = timer
        self._slots: List[Request] = []        # join order
        self._progress: Dict[int, int] = {}    # rid -> prefilled tokens
        # Request.prefill_len tracks context_len, which GROWS as tokens
        # decode — the prefill target must be frozen at join time
        self._target: Dict[int, int] = {}      # rid -> tokens to prefill
        self._resident_sum = 0
        self._live_sum = 0

    # --- joinability ------------------------------------------------------
    @property
    def empty(self) -> bool:
        return not self._slots

    @property
    def position(self) -> int:
        return 0        # no shared position: joins any step

    def _worst_pages(self, req: Request) -> int:
        return pages_for(req.prefill_len + req.remaining_new,
                         self.page_size)

    def joinable(self, req: Request) -> bool:
        return self.alloc.can_reserve(self._worst_pages(req))

    def filter_joinable(self, pending: Sequence[Request]
                        ) -> List[Request]:
        """Greedy cumulative reservation check: the pool is a collective
        constraint, so each accepted candidate shrinks what the next one
        can reserve (any prefix of the result fits together — the
        batcher admits prefixes)."""
        out: List[Request] = []
        extra = 0
        for r in pending:
            p = self._worst_pages(r)
            if self.alloc.reserved_pages + extra + p \
                    <= self.alloc.usable_pages:
                out.append(r)
                extra += p
        return out

    def restart_cohort(self, pending: Sequence[Request]
                       ) -> List[Request]:
        # no shared position window: the restart rule IS the join rule
        return self.filter_joinable(pending)

    # --- residency accounting ---------------------------------------------
    def _live_tokens(self, req: Request) -> int:
        """KV tokens this request holds: prefill progress while
        mid-prefill, the full (growing) context once complete."""
        prog = self._progress[req.rid]
        return prog if prog < self._target[req.rid] else req.context_len

    def kv_resident_tokens(self) -> int:
        return self.alloc.allocated_pages * self.page_size

    def kv_live_tokens(self) -> int:
        return sum(self._live_tokens(r) for r in self._slots)

    def _sample_residency(self) -> None:
        self._resident_sum += self.kv_resident_tokens()
        self._live_sum += self.kv_live_tokens()

    def waste_ratio(self) -> float:
        """Fraction of step-summed resident KV slots that held no live
        token (the HBM padding waste the benchmarks compare)."""
        if self._resident_sum <= 0:
            return 0.0
        return 1.0 - self._live_sum / self._resident_sum

    # --- the step machine -------------------------------------------------
    def join(self, reqs: Sequence[Request], now: float) -> float:
        """Reserve worst-case pages and run each joiner's FIRST prefill
        chunk (short prompts complete immediately and emit their first
        token, like a dense join)."""
        reqs = list(reqs)
        if not reqs:
            return 0.0
        for r in reqs:
            self.alloc.reserve(r.rid, self._worst_pages(r))
            self._progress[r.rid] = 0
            self._target[r.rid] = r.prefill_len
            self._slots.append(r)
            self._register(r)
        return self._advance_chunks(reqs)

    def decode(self, running: Sequence[Request]) -> float:
        assert set(id(r) for r in running) == \
            set(id(r) for r in self._slots), "engine/backend slot drift"
        incomplete = [r for r in self._slots
                      if self._progress[r.rid] < self._target[r.rid]]
        decoding = [r for r in self._slots
                    if self._progress[r.rid] >= self._target[r.rid]
                    and not r.done]
        cost = 0.0
        if incomplete:
            cost += self._advance_chunks(incomplete)
        if decoding:
            cost += self._decode_rows(decoding)
            for r in decoding:
                self.alloc.grow_to(r.rid, r.context_len)
        self._sample_residency()
        return cost

    def remove(self, reqs: Sequence[Request]) -> None:
        drop = {id(r) for r in reqs}
        self._slots = [r for r in self._slots if id(r) not in drop]
        for r in reqs:
            self.alloc.release(r.rid)
            self._progress.pop(r.rid, None)
            self._target.pop(r.rid, None)
            self._unregister(r)

    # --- KV migration -----------------------------------------------------
    def adopt(self, reqs: Sequence[Request], now: float) -> float:
        """Seat requests whose KV arrived over the wire: reserve pages,
        mark the prefill already complete (progress == target), and grow
        the live pages to the transferred context — no chunks run and no
        token is emitted (the next decode produces one)."""
        for r in reqs:
            self.alloc.reserve(r.rid, self._worst_pages(r))
            self._progress[r.rid] = r.prefill_len
            self._target[r.rid] = r.prefill_len
            self._slots.append(r)
            self._register(r)
            self.alloc.grow_to(r.rid, r.context_len)
        return 0.0

    def recompute_cost(self, req: Request) -> float:
        return self._timer.t_prefill_per_token * req.prefill_len

    def _advance_chunks(self, reqs: Sequence[Request]) -> float:
        """One prefill chunk for each request; completions emit their
        first generated token.  Returns the virtual-time cost."""
        work = []          # (req, start, chunk_len)
        for r in reqs:
            start = self._progress[r.rid]
            cl = min(self.prefill_chunk, self._target[r.rid] - start)
            assert cl > 0, (r.rid, start, self._target[r.rid])
            self.alloc.grow_to(r.rid, start + cl)
            work.append((r, start, cl))
        emitted = self._prefill_rows(work)
        for (r, start, cl), tok in zip(work, emitted):
            self._progress[r.rid] = start + cl
            if start + cl >= self._target[r.rid] and not r.done:
                r.tokens.append(tok)
                # the emitted token's KV slot is written by its decode
                self.alloc.grow_to(r.rid, r.context_len)
        return self._timer.t_prefill_per_token * sum(
            cl for _, _, cl in work)

    # --- compute hooks ----------------------------------------------------
    def _register(self, req: Request) -> None:
        pass

    def _unregister(self, req: Request) -> None:
        pass

    def _prefill_rows(self, work) -> List[int]:
        """Run the chunks in ``work``; return one would-be first token
        per entry (only consumed for rows whose prefill completed)."""
        raise NotImplementedError

    def _decode_rows(self, decoding: Sequence[Request]) -> float:
        """Decode one token for every complete-prefill request; append
        tokens and return the step cost."""
        raise NotImplementedError


class PagedSimBackend(_PagedScheduler, Backend):
    """Virtual-time paged backend: SimBackend's deterministic cost model
    and synthetic token stream over page-granular residency + chunked
    prefill.  Token streams match :class:`SimBackend` exactly (same
    ``(rid, tokens_decoded)`` synthesis), so conservation goldens can
    compare dense and paged schedules token-for-token."""

    join_stride = 1
    can_adopt = True   # synthetic KV: a transferred cache just IS pages

    def __init__(self, num_pages: int, page_size: int = 16,
                 prefill_chunk: int = 32,
                 t_decode_base: float = 5e-3,
                 t_decode_per_seq: float = 1e-3,
                 t_prefill_per_token: float = 2e-4):
        super().__init__(num_pages, page_size, prefill_chunk,
                         SimBackend(t_decode_base, t_decode_per_seq,
                                    t_prefill_per_token))

    def _prefill_rows(self, work) -> List[int]:
        return [SimBackend._synth_token(r) for r, _, _ in work]

    def _decode_rows(self, decoding: Sequence[Request]) -> float:
        for r in decoding:
            r.tokens.append(SimBackend._synth_token(r))
        return self._timer.step_cost(len(decoding))


class DenseSimBackend(Backend):
    """Virtual-time twin of :class:`~repro.serve.backends.JaxBackend`'s
    dense-cache semantics — shared sync-strided position, bucketed batch
    capacity with shrink hysteresis, full-prompt prefill charged at the
    padded position, every slot resident at ``max_len`` — emitting
    :class:`SimBackend`'s synthetic tokens.  The waste/goodput baseline
    the paged backends are benchmarked against, with no jax in the
    loop."""

    def __init__(self, max_len: int, sync: int = 16,
                 shrink_patience: int = 4,
                 t_decode_base: float = 5e-3,
                 t_decode_per_seq: float = 1e-3,
                 t_prefill_per_token: float = 2e-4):
        self.max_len = int(max_len)
        self.join_stride = max(int(sync), 1)
        self.shrink_patience = max(int(shrink_patience), 1)
        self._timer = SimBackend(t_decode_base, t_decode_per_seq,
                                 t_prefill_per_token)
        self._slots: List[Request] = []
        self._pos = 0
        self._cap = 0
        self._shrink_streak = 0
        self._resident_sum = 0
        self._live_sum = 0

    @property
    def empty(self) -> bool:
        return not self._slots

    @property
    def position(self) -> int:
        return self._pos

    def joinable(self, req: Request) -> bool:
        if not self._slots:
            return True
        return (req.prefill_len <= self._pos
                and self._pos + req.remaining_new <= self.max_len)

    def join(self, reqs: Sequence[Request], now: float) -> float:
        reqs = list(reqs)
        if not reqs:
            return 0.0
        if not self._slots:
            need = max(r.prefill_len for r in reqs)
            maxr = max(r.remaining_new for r in reqs)
            pos = -(-need // self.join_stride) * self.join_stride
            self._pos = max(min(pos, self.max_len - maxr), need)
        else:
            assert all(self.joinable(r) for r in reqs)
        self._slots.extend(reqs)
        self._cap = max(self._cap, _bucket(len(self._slots)))
        self._shrink_streak = 0
        for r in reqs:
            if not r.done:
                r.tokens.append(SimBackend._synth_token(r))
        # every row prefills to the shared padded position
        return self._timer.t_prefill_per_token * self._pos * len(reqs)

    def decode(self, running: Sequence[Request]) -> float:
        assert set(id(r) for r in running) == \
            set(id(r) for r in self._slots), "engine/backend slot drift"
        assert self._pos < self.max_len, "decode past max_len"
        for r in self._slots:
            if not r.done:
                r.tokens.append(SimBackend._synth_token(r))
        self._pos += 1
        self._resident_sum += self._cap * self.max_len
        self._live_sum += sum(r.context_len for r in self._slots)
        return self._timer.step_cost(len(self._slots))

    def remove(self, reqs: Sequence[Request]) -> None:
        drop = {id(r) for r in reqs}
        self._slots = [r for r in self._slots if id(r) not in drop]
        if not self._slots:
            self._pos, self._cap, self._shrink_streak = 0, 0, 0
            return
        self._cap, self._shrink_streak = _shrink_bucket(
            self._cap, len(self._slots), self._shrink_streak,
            self.shrink_patience)

    def kv_resident_tokens(self) -> int:
        return self._cap * self.max_len

    def kv_live_tokens(self) -> int:
        return sum(r.context_len for r in self._slots)

    def waste_ratio(self) -> float:
        if self._resident_sum <= 0:
            return 0.0
        return 1.0 - self._live_sum / self._resident_sum


class PagedJaxBackend(_PagedScheduler, Backend):
    """Real chunked prefill + paged decode over a shared page pool.

    The KV pools (``[L, P, page, Hkv, hd]``) are allocated ONCE and
    never reshaped — batch membership churn only changes the small
    per-row page table / length / token arrays, whose batch axis rounds
    up to a power of two, so compile count is bounded by
    O(log(max_batch)) shapes and page churn recompiles nothing (the
    guarantee the dense backend could only approximate).

    Rows are sticky: a request keeps its row until it is removed, and
    freed rows are reused (no compaction gathers).  Host mirrors of the
    page tables and lengths are authoritative; the device cache's
    ``table``/``lens`` entries are rebuilt from them before every call.
    """

    join_stride = 1

    def __init__(self, cfg, params=None, num_pages: int = 64,
                 page_size: int = 16, prefill_chunk: int = 32,
                 seed: int = 0, step_time: Optional[SimBackend] = None):
        import jax
        from repro.models import model as model_lib
        from repro.train.step import (build_paged_decode_step,
                                      build_prefill_chunk_step)
        super().__init__(num_pages, page_size, prefill_chunk,
                         step_time or SimBackend())
        self._jax = jax
        self.cfg = cfg
        self.params = params if params is not None \
            else model_lib.init(cfg, jax.random.key(seed))
        self._model_lib = model_lib
        self._decode = jax.jit(build_paged_decode_step(cfg),
                               donate_argnums=(1,))
        self._chunk = jax.jit(build_prefill_chunk_step(cfg),
                              donate_argnums=(1,))
        self._rng = np.random.default_rng(seed)
        self._cache = None
        self._cap = 0
        self._rows: Dict[int, int] = {}     # rid -> row index
        self._row_free: List[int] = []
        self._last: Dict[int, int] = {}     # rid -> last sampled token
        self._maxp = self.alloc.usable_pages
        self._table_np = np.zeros((0, self._maxp), np.int32)

    # --- row / cache management -------------------------------------------
    def _ensure_capacity(self, extra_rows: int) -> None:
        need = len(self._rows) + extra_rows
        cap = max(_bucket(need), self._cap)
        if self._cache is None:
            self._cache = self._model_lib.init_paged_cache(
                self.cfg, cap, self.alloc.num_pages, self.page_size)
        if cap > self._cap:
            self._row_free.extend(range(self._cap, cap))
            pad = np.zeros((cap - self._cap, self._maxp), np.int32)
            self._table_np = np.concatenate([self._table_np, pad])
            self._cap = cap

    def _register(self, req: Request) -> None:
        if req.prompt is None:
            req.prompt = list(self._rng.integers(
                PAD_ID, self.cfg.vocab_size, req.prompt_len))
        row = self._row_free.pop(0)
        self._rows[req.rid] = row
        self._table_np[row] = 0

    def _unregister(self, req: Request) -> None:
        row = self._rows.pop(req.rid)
        self._table_np[row] = 0
        self._row_free.append(row)
        self._row_free.sort()
        self._last.pop(req.rid, None)

    def join(self, reqs: Sequence[Request], now: float) -> float:
        self._ensure_capacity(len(list(reqs)))
        return super().join(reqs, now)

    def _sync_tables(self) -> np.ndarray:
        """Refresh the host page-table mirror from the allocator (parked
        slots stay on scratch page 0) and per-row KV lengths."""
        lens = np.zeros((self._cap,), np.int32)
        for r in self._slots:
            row = self._rows[r.rid]
            pages = self.alloc.pages_of(r.rid)
            self._table_np[row, :len(pages)] = pages
            self._table_np[row, len(pages):] = 0
            lens[row] = self._live_tokens(r)
        return lens

    def _push_cache(self, lens: np.ndarray) -> None:
        import jax.numpy as jnp
        self._cache["table"] = jnp.asarray(self._table_np)
        self._cache["lens"] = jnp.asarray(lens)

    # --- compute hooks ----------------------------------------------------
    def _prefill_rows(self, work) -> List[int]:
        import jax.numpy as jnp
        C = self.prefill_chunk
        tokens = np.full((self._cap, C), PAD_ID, np.int32)
        start = np.zeros((self._cap,), np.int32)
        chunk_lens = np.zeros((self._cap,), np.int32)
        active = np.zeros((self._cap,), bool)
        for r, s, cl in work:
            row = self._rows[r.rid]
            seq = list(r.prompt) + list(r.tokens)    # recompute view
            tokens[row, :cl] = seq[s:s + cl]
            start[row], chunk_lens[row], active[row] = s, cl, True
        lens = self._sync_tables()
        # mid-chunk rows carry their pre-chunk progress; grow_to already
        # covered the chunk's pages, so the device tables are current
        for r, s, cl in work:
            lens[self._rows[r.rid]] = s
        self._push_cache(lens)
        logits, self._cache = self._chunk(
            self.params, self._cache, jnp.asarray(tokens),
            jnp.asarray(start), jnp.asarray(chunk_lens),
            jnp.asarray(active))
        toks = np.asarray(jnp.argmax(logits, -1)[:, 0])
        return [int(toks[self._rows[r.rid]]) for r, _, _ in work]

    def _decode_rows(self, decoding: Sequence[Request]) -> float:
        import jax.numpy as jnp
        token = np.full((self._cap, 1), PAD_ID, np.int32)
        active = np.zeros((self._cap,), bool)
        for r in decoding:
            row = self._rows[r.rid]
            token[row, 0] = r.tokens[-1]
            active[row] = True
        lens = self._sync_tables()
        # the decode step writes the input token's KV at position len
        # and attends len + 1 entries: pass len EXCLUDING that token
        for r in decoding:
            lens[self._rows[r.rid]] = r.context_len - 1
        self._push_cache(lens)
        logits, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(token),
            jnp.asarray(active))
        toks = np.asarray(jnp.argmax(logits, -1)[:, 0])
        for r in decoding:
            r.tokens.append(int(toks[self._rows[r.rid]]))
        return self._timer.step_cost(len(decoding))
