"""Step-level vector admission for continuous batching.

``launch/serve.py``'s wave mode asks the admission controller ONCE —
"how many requests fit?" — and serves fixed waves.  This module asks the
same question **every decode step**, through the same
:class:`~repro.sched.admission.AdmissionController` /
:class:`~repro.sched.resources.DemandModel` /
:class:`~repro.sched.resources.ResourceVector` machinery:

* per-request demand is a calibrated curve over the *live* context
  length ``prompt_len + tokens_decoded`` — weights amortized once,
  KV-cache growing one token per step (:class:`ServingDemand`);
* joins go through the controller's binding-axis inverse: the marginal
  demand of admitting the first ``u`` pending requests is a monotone
  :class:`PrefixCurve` per axis, wrapped in a :class:`DemandModel` and
  inverted under the step's *headroom* vector — exactly the
  ``admit_batch`` code path, so the decision records the binding axis
  and ``forced`` the same way;
* when next step's KV growth would breach the budget, the batcher
  preempts lowest-priority running requests (last in placement order,
  evict-and-requeue with recompute) until the step fits — or flags the
  step ``forced`` when even a single request is over budget (a server
  must make progress).

The booked footprint here is the *modeled* demand — the paged-KV view
where a request occupies ``kv(context)`` — which is what admission
decides on; a dense-cache execution backend may additionally round
capacity up to its padding bucket (see ``serve/backends.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.experts import MemoryFunction
from repro.sched.admission import AdmissionController
from repro.sched.elastic import shrink_vector
from repro.sched.placement import PlacementPolicy, get_placement
from repro.sched.resources import DemandModel, ResourceVector
from repro.sched.tenancy import (TenantRegistry, pack_step,
                                 request_origin)
from repro.serve.request import Request

_EPS = 1e-9


class PrefixCurve:
    """Monotone piecewise-linear curve through the cumulative demand of
    an *ordered* candidate list: ``fn(u)`` is the demand of admitting the
    first ``u`` candidates (linear between whole requests), ``inverse(y)``
    the largest ``u`` whose prefix fits ``y``.  Duck-types
    :class:`~repro.core.experts.MemoryFunction` so it plugs straight into
    :class:`~repro.sched.resources.DemandModel` and the controller's
    binding-axis inverse."""

    family = "prefix"

    def __init__(self, costs: Sequence[float]):
        costs = [float(c) for c in costs]
        if any(c < 0 for c in costs):
            raise ValueError("per-request demands must be >= 0")
        self._cum = np.concatenate([[0.0], np.cumsum(costs)])

    def __call__(self, u) -> float:
        u = float(np.clip(u, 0.0, len(self._cum) - 1))
        return float(np.interp(u, np.arange(len(self._cum)), self._cum))

    def inverse(self, y: float, x_hint: float = 1.0) -> float:
        y = float(y)
        if y < 0:
            return 0.0
        if y >= self._cum[-1] - _EPS:
            # every candidate fits: the curve is exhausted, not unbounded
            return float(len(self._cum) - 1)
        k = int(np.searchsorted(self._cum, y + _EPS, side="right") - 1)
        span = self._cum[k + 1] - self._cum[k]
        frac = (y - self._cum[k]) / span if span > _EPS else 0.0
        return float(k + min(max(frac, 0.0), 1.0 - 1e-12))


#: axes ServingDemand computes itself — an estimator must not leak
#: these through ``extra_axes`` (it would silently overwrite the KV
#: and staging terms in ``request_vector``)
RESERVED_AXES = ("hbm", "host_ram")


@dataclass
class ServingDemand:
    """Per-request serving footprint derived from a calibrated demand
    model (the ``kv-growth`` estimator in ``repro.sched.estimator``):
    the affine footprint-vs-batch fit at ``max_len`` gives weights
    (intercept, amortized across the batch) and KV at full length
    (slope), from which the per-token KV slice follows.  ``extra_axes``
    carries any other per-request side-car constants (e.g. ``net``
    egress bandwidth) the estimate predicted.

    ``page_size > 1`` books **page-quantized** KV — a request holding
    ``c`` context tokens occupies ``ceil(c / page) * page`` KV slots,
    matching the paged backends' physical allocation granularity
    (``page_size = 1`` is the exact dense-token model and keeps every
    pre-paging schedule bit-identical)."""

    weights_gb: float           # resident once, however many requests
    kv_gb_per_token: float      # per request, per context token
    host_ram_per_req_gb: float = 0.0  # pinned host staging per request
    extra_axes: Dict[str, float] = field(default_factory=dict)
    page_size: int = 1          # KV allocation granularity in tokens
    #: demand-vs-slowdown curve for spill-aware shrunken joins
    #: (:class:`~repro.sched.elastic.SlowdownCurve`); None = not
    #: shrinkable.  ``from_estimate`` carries the estimator's curve
    #: through; direct constructions opt in explicitly.
    shrink: Optional[object] = None

    def __post_init__(self):
        leaked = sorted(set(self.extra_axes) & set(RESERVED_AXES))
        if leaked:
            raise ValueError(
                f"extra_axes must not carry reserved axes {leaked} — "
                f"hbm/host_ram are computed from kv_gb_per_token and "
                f"host_ram_per_req_gb; a leaking estimator would "
                f"silently overwrite them")
        if int(self.page_size) < 1:
            raise ValueError(f"page_size must be >= 1, "
                             f"got {self.page_size}")
        self.page_size = int(self.page_size)

    @classmethod
    def from_demand_model(cls, dm: DemandModel, max_len: int,
                          page_size: int = 1) -> "ServingDemand":
        fn = dm.primary_fn
        if fn is None or getattr(fn, "family", None) != "affine":
            raise ValueError(
                "ServingDemand needs an affine footprint-vs-batch fit "
                "on the primary axis (the kv-growth estimator)")
        host = dm.curves.get("host_ram")
        extra = {a: float(c.b) for a, c in dm.curves.items()
                 if a not in (dm.primary_axis, "host_ram")}
        return cls(weights_gb=float(fn.m),
                   kv_gb_per_token=float(fn.b) / float(max_len),
                   host_ram_per_req_gb=float(host.b)
                   if host is not None else 0.0,
                   extra_axes=extra, page_size=page_size)

    @classmethod
    def from_estimate(cls, estimate, max_len: int) -> "ServingDemand":
        """Build from a :class:`~repro.sched.estimator.DemandEstimate`
        (the registry path: ``get_estimator("kv-growth").estimate(
        ModelTarget(cfg, max_len, ...))``).  The estimator's declared
        page size carries through, so booked demand is quantized the
        way the paged backend actually allocates."""
        sd = cls.from_demand_model(
            estimate.model, max_len,
            page_size=int(estimate.info.get("page_size", 1)))
        sd.shrink = getattr(estimate, "shrink", None)
        return sd

    def kv_gb(self, tokens: int) -> float:
        """KV footprint of ``tokens`` context tokens, rounded up to the
        allocation granularity (whole pages)."""
        pages = -(-max(int(tokens), 0) // self.page_size)
        return self.kv_gb_per_token * pages * self.page_size

    def per_request_axes(self) -> Dict[str, float]:
        """Per-request side-car constants on every non-KV axis (what a
        request pins regardless of its context length)."""
        axes = dict(self.extra_axes)
        if self.host_ram_per_req_gb > 0.0:
            axes["host_ram"] = self.host_ram_per_req_gb
        return axes

    def request_vector(self, req: Request, extra_tokens: int = 0
                       ) -> ResourceVector:
        """Marginal demand of ``req`` holding ``context + extra_tokens``
        KV slots (weights excluded — they are booked once, below)."""
        axes = {"hbm": self.kv_gb(req.context_len + extra_tokens)}
        if self.host_ram_per_req_gb > 0.0:
            axes["host_ram"] = self.host_ram_per_req_gb
        axes.update(self.extra_axes)
        return ResourceVector(**axes)

    def booked(self, running: Sequence[Request], extra_tokens: int = 0
               ) -> ResourceVector:
        """Total modeled footprint of the running set after each request
        grows by ``extra_tokens``."""
        total = ResourceVector(hbm=self.weights_gb)
        for r in running:
            total = total + self.request_vector(r, extra_tokens)
        return total


@dataclass(frozen=True)
class StepDecision:
    """What the batcher decided for one decode step — the step-level
    analogue of :class:`~repro.sched.admission.AdmissionDecision`."""
    step: int
    t: float
    admitted: Tuple[int, ...]       # rids joining this step
    preempted: Tuple[int, ...]      # rids evicted-and-requeued
    batch: int                      # running batch size after the plan
    booked: ResourceVector          # modeled footprint after the plan
    budget: ResourceVector
    binding_axis: Optional[str]     # axis that bound the join inverse
    forced: bool                    # step proceeds over budget
    forced_axes: Tuple[str, ...] = ()
    #: rids running over budget under the progress floor — the ONE
    #: forced-admission record shape shared by the continuous batcher
    #: and the legacy wave path (which used to flag the step without
    #: saying which requests were forced)
    forced_rids: Tuple[int, ...] = ()
    node: int = 0                   # replica Node the step ran on
    #: candidates that wanted to join this step but were declined —
    #: with the axis that bound the join inverse and how far short the
    #: headroom fell of admitting ONE more (the structured reject
    #: reason; 0 / None / 0.0 when everything offered was admitted)
    rejected: int = 0
    reject_axis: Optional[str] = None
    reject_deficit: float = 0.0
    #: declined candidates by rid, and the requeue-vs-new origin split
    #: (a declined candidate that has run before is preemption churn,
    #: not fresh demand — per-tenant reject accounting needs the two
    #: apart; ``rejected == rejected_new + rejected_requeue``)
    rejected_rids: Tuple[int, ...] = ()
    rejected_new: int = 0
    rejected_requeue: int = 0
    #: spill-aware shrunken joins this step: ``(rid, fraction,
    #: slowdown)`` per request admitted below its full memory demand —
    #: the engine registers these grants (the request keeps the
    #: fraction until it retires or is evicted) and charges the
    #: modeled slowdown into the step's decode time
    shrunk: Tuple[Tuple[int, float, float], ...] = ()

    @property
    def over_budget(self) -> bool:
        return not self.booked.fits(self.budget)


class ContinuousBatcher:
    """Re-decides batch membership every decode step.

    ``plan_step`` is pure planning — it mutates nothing; the engine
    applies the returned :class:`StepDecision` (evictions, joins) to the
    queue and the execution backend.  Invariants (pinned by
    ``tests/test_serve.py``):

    * the booked footprint never exceeds the budget on any axis at any
      step unless the decision is ``forced``;
    * ``forced`` only ever covers the single-request floor — a forced
      step runs exactly one request (the progress guarantee of
      ``admit_batch(min_batch=1)``);
    * planning is deterministic given (running, pending, now).
    """

    def __init__(self, demand: ServingDemand, budget: ResourceVector,
                 controller: Optional[AdmissionController] = None,
                 placement: Union[str, PlacementPolicy] = "fcfs",
                 max_batch: int = 64, node: int = 0,
                 tenancy: Optional[TenantRegistry] = None,
                 elastic: Optional[object] = None):
        if "hbm" not in budget:
            raise ValueError("serving budget must carry the hbm axis")
        if budget["hbm"] <= 0:
            raise ValueError("hbm budget must be positive")
        self.demand = demand
        self.budget = budget
        self.controller = controller or AdmissionController()
        self.placement = get_placement(placement) \
            if isinstance(placement, str) else placement
        self.max_batch = int(max_batch)
        self.node = int(node)       # replica id stamped on decisions
        #: with a TenantRegistry bound, joins run the weighted-DRF
        #: knapsack (sched.tenancy.pack_step) and evictions pick the
        #: highest-weighted-share tenant's lowest-priority request;
        #: None (the default) keeps the legacy FIFO-prefix plan
        #: bit-identical
        self.tenancy = tenancy
        #: an :class:`~repro.sched.elastic.ElasticController` enables
        #: spill-aware shrunken joins on the legacy FIFO path: a
        #: declined candidate may be admitted at a memory fraction the
        #: demand's shrink curve prices under the slowdown cap.  None
        #: (the default) keeps every plan bit-identical.
        self.elastic = elastic
        #: live shrink grants — rid -> (fraction, slowdown, granted
        #: vector).  The granted vector is FROZEN at admission: as the
        #: request's context grows, it spills more (the modeled
        #: slowdown already paid for spill) instead of pressuring the
        #: budget — a growing grant sized to exact headroom would be
        #: evicted the very next step.  Owned by this batcher but
        #: MUTATED by the engine: grants from a plan's ``shrunk`` tuple
        #: are registered on apply (see ``register_shrunk``) and
        #: dropped on eviction / retirement / replica failure.
        self.shrunk: Dict[int, Tuple[float, float, ResourceVector]] = {}

    def register_shrunk(self, req: Request, fraction: float,
                        slowdown: float) -> None:
        """Freeze a plan's shrink grant: book ``fraction`` x the join
        vector at the admission-time context for as long as the request
        runs.  Called by the engine when applying a plan."""
        self.shrunk[req.rid] = (float(fraction), float(slowdown),
                                shrink_vector(self._join_vector(req),
                                              float(fraction)))

    # --- planning ---------------------------------------------------------
    def plan_step(self, running: Sequence[Request],
                  pending: Sequence[Request], now: float, step: int
                  ) -> StepDecision:
        """Plan the next decode step: evictions first (KV growth must
        fit), then joins through the controller's binding-axis inverse
        under the remaining headroom.  ``pending`` must already be in
        placement order (the queue's job)."""
        running = list(running)
        preempted: List[int] = []
        forced = False
        forced_axes: Tuple[str, ...] = ()
        forced_rids: Tuple[int, ...] = ()

        # 1. next step's KV growth: evict until it fits.  Untenanted:
        # lowest-priority first (reverse placement order).  With a
        # registry bound, the highest-weighted-share tenant pays first
        # and placement picks WHICH of its requests (its lowest
        # priority) — recomputed per eviction, since shares shift as
        # usage shrinks.
        victims = list(reversed(self.placement.order_jobs(running,
                                                          now=now)))
        while running and not self._booked(running, 1).fits(
                self.budget):
            if len(running) == 1:
                # the progress floor: one request runs even over budget
                forced = True
                forced_axes = self._violated(running, 1)
                forced_rids = (running[0].rid,)
                break
            v = victims.pop(0) if self.tenancy is None \
                else self._drf_victim(running, now)
            running.remove(v)
            preempted.append(v.rid)

        # 2. join new prefills under the post-eviction headroom
        admitted: List[int] = []
        binding: Optional[str] = None
        rejected = 0
        reject_axis: Optional[str] = None
        reject_deficit = 0.0
        rejected_rids: Tuple[int, ...] = ()
        rejected_new = 0
        rejected_requeue = 0
        shrunk_new: List[Tuple[int, float, float]] = []
        slots = self.max_batch - len(running)
        # running and pending are disjoint by contract (a victim is only
        # requeued AFTER the plan is applied), so a just-evicted request
        # can never be re-admitted within the same plan
        assert not preempted or \
            not {r.rid for r in pending} & set(preempted)
        # the knapsack sees the WHOLE pending set (it may skip an
        # oversized head and admit smaller work behind it); the legacy
        # prefix inverse only ever looks at the first ``slots``
        if self.tenancy is not None and slots > 0:
            cands = list(pending)
        else:
            cands = list(pending)[:slots] if slots > 0 else []
        if cands and not forced and self.tenancy is not None:
            headroom = self.budget.headroom(
                self._booked(running, 1))
            usage = self._tenant_usage(running)
            picked, skips = pack_step(
                self.tenancy, cands, headroom, self.budget, usage,
                self._join_vector, slots)
            if not picked and not running and pending:
                # nothing runs and nothing fits: forced single admission
                # of the first candidate the DRF order offered (the
                # lowest-share tenant's head), same progress floor as
                # the legacy path
                frid = skips[0].rid if skips else cands[0].rid
                first = next(r for r in cands if r.rid == frid)
                picked = [first]
                skips = [s for s in skips if s.rid != frid]
                forced = True
                forced_axes = self._violated([first], 2)
                forced_rids = (first.rid,)
            admitted = [r.rid for r in picked]
            running.extend(picked)
            rejected = len(skips)
            if skips:
                top = max(
                    (s for s in skips if s.axis is not None),
                    key=lambda s: s.deficit, default=None)
                reject_axis = top.axis if top else None
                reject_deficit = top.deficit if top else 0.0
                rejected_rids = tuple(s.rid for s in skips)
                rejected_new = sum(1 for s in skips
                                   if s.origin == "new")
                rejected_requeue = rejected - rejected_new
        elif cands and not forced:
            headroom = self.budget.headroom(
                self._booked(running, 1))
            jd = self._join_demand(cands)
            dec = self.controller.admit(
                jd, headroom, cap=float(len(cands)), book=False)
            n = int(np.floor(dec.units + 1e-9))
            binding = dec.binding_axis
            admitted = [r.rid for r in cands[:n]]
            running.extend(cands[:n])
            if not running and pending:
                # nothing runs and nothing fits: forced single admission
                # (admit_batch's min_batch=1 progress guarantee)
                first = cands[0]
                running.append(first)
                admitted = [first.rid]
                forced = True
                forced_axes = self._violated(running, 2)
                forced_rids = (first.rid,)
            rejected = max(len(cands) - len(admitted), 0)
            if rejected and self.elastic is not None and not forced:
                # spill-aware second chance: walk the declined suffix
                # and admit what the shrink curve prices under the
                # slowdown cap (appends to admitted/running in place).
                # Room is the PRE-join headroom minus the admitted
                # prefix's join demand — the inverse charged joiners at
                # context+2, so charging them through _booked (which
                # sees them at +1) would overshoot the budget.
                used = jd.demand(float(len(admitted)))
                room = ResourceVector(**{
                    a: max(headroom[a] - used.get(a, 0.0), 0.0)
                    for a in headroom.axes})
                shrunk_new = self._shrink_joins(
                    running, cands[len(admitted):], admitted, room)
                rejected = len(cands) - len(admitted)
            if rejected:
                # reject reason: axis and deficit of admitting ONE more
                # candidate than actually joined, against the headroom
                # the inverse saw
                need = jd.demand(float(len(admitted) + 1))
                overs = {a: float(v - headroom[a])
                         for a, v in need.items()
                         if a in headroom and v > headroom[a] + _EPS}
                reject_axis = dec.binding_axis or (
                    max(overs, key=overs.get) if overs else None)
                reject_deficit = overs.get(reject_axis, 0.0)
                taken = set(admitted)
                declined = [r for r in cands if r.rid not in taken]
                rejected_rids = tuple(r.rid for r in declined)
                rejected_new = sum(1 for r in declined
                                   if request_origin(r) == "new")
                rejected_requeue = rejected - rejected_new
        elif cands:
            # the eviction floor forced the step: every offered
            # candidate was declined without running the join inverse
            rejected = len(cands)
            reject_axis = forced_axes[0] if forced_axes else None
            rejected_rids = tuple(r.rid for r in cands)
            rejected_new = sum(1 for r in cands
                               if request_origin(r) == "new")
            rejected_requeue = rejected - rejected_new

        # end-of-step footprint: incumbents grow one token; joiners gain
        # two (the prefill-emitted token plus the decode-step token).
        # Live shrink grants (and the ones planned just above) book the
        # granted fraction of the modeled vector.
        joined = set(admitted)
        newly = {rid: f for rid, f, _ in shrunk_new}
        booked = ResourceVector(hbm=self.demand.weights_gb)
        for r in running:
            f = newly.get(r.rid)
            if f is not None:
                # just granted: the frozen vector the engine will book
                vec = shrink_vector(self._join_vector(r), f)
            elif r.rid in self.shrunk:
                vec = self.shrunk[r.rid][2]
            else:
                vec = self.demand.request_vector(
                    r, 2 if r.rid in joined else 1)
            booked = booked + vec
        return StepDecision(
            step=step, t=now, admitted=tuple(admitted),
            preempted=tuple(preempted), batch=len(running),
            booked=booked, budget=self.budget, binding_axis=binding,
            forced=forced, forced_axes=forced_axes,
            forced_rids=forced_rids, node=self.node,
            rejected=rejected, reject_axis=reject_axis,
            reject_deficit=reject_deficit,
            rejected_rids=rejected_rids,
            rejected_new=rejected_new,
            rejected_requeue=rejected_requeue,
            shrunk=tuple(shrunk_new))

    # --- helpers ----------------------------------------------------------
    def _booked(self, running: Sequence[Request], extra_tokens: int
                ) -> ResourceVector:
        """Booked footprint honouring live shrink grants: a request
        admitted at fraction ``f`` occupies ``f`` x its modeled memory
        (the spilled remainder lives off-budget at the modeled slowdown
        price).  With no grants outstanding this is exactly the legacy
        ``demand.booked`` total."""
        if not self.shrunk:
            return self.demand.booked(running, extra_tokens)
        total = ResourceVector(hbm=self.demand.weights_gb)
        for r in running:
            fs = self.shrunk.get(r.rid)
            vec = fs[2] if fs is not None \
                else self.demand.request_vector(r, extra_tokens)
            total = total + vec
        return total

    def _shrink_joins(self, running: List[Request],
                      declined: Sequence[Request],
                      admitted: List[int],
                      headroom: ResourceVector
                      ) -> List[Tuple[int, float, float]]:
        """Walk the declined candidates in placement order and admit
        each at the largest memory fraction the remaining headroom
        covers, when the demand's shrink curve prices that fraction
        under the elastic controller's slowdown cap — the serving twin
        of the simulator's shrunken executors, through the same
        :meth:`AdmissionController.shrink_target` walk.  Mutates
        ``running``/``admitted`` in place; returns the ``(rid,
        fraction, slowdown)`` grants for the engine to register."""
        curve = getattr(self.demand, "shrink", None)
        if curve is None or not getattr(curve, "shrinkable", False):
            return []
        out: List[Tuple[int, float, float]] = []
        for r in declined:
            if len(running) >= self.max_batch:
                break
            need = self._join_vector(r)
            dm = DemandModel(
                {a: MemoryFunction("affine", 0.0, v)
                 for a, v in need.items()},
                primary_axis="hbm")
            dec = self.controller.shrink_target(
                dm, headroom, units=1.0, curve=curve,
                elastic=self.elastic, book=False)
            sh = dec.info.get("shrink") if dec else None
            if not dec or sh is None or \
                    sh["fraction"] >= 1.0 - 1e-12:
                continue
            out.append((r.rid, float(sh["fraction"]),
                        float(sh["slowdown"])))
            admitted.append(r.rid)
            running.append(r)
            grant = shrink_vector(need, float(sh["fraction"]))
            headroom = ResourceVector(**{
                a: max(headroom[a] - grant.get(a, 0.0), 0.0)
                for a in headroom.axes})
        return out

    def _join_demand(self, cands: Sequence[Request]) -> DemandModel:
        """Marginal demand of admitting the first ``u`` ordered
        candidates, as per-axis prefix curves the controller can invert.
        Joiners are charged their full post-step context: the prefill
        emits one token and the decode step a second.  Every per-request
        side-car axis (host staging RAM, net egress) joins as a linear
        curve so it can bind the inverse too."""
        curves: Dict[str, object] = {"hbm": PrefixCurve(
            [self.demand.kv_gb(r.context_len + 2) for r in cands])}
        for axis, per_req in self.demand.per_request_axes().items():
            curves[axis] = MemoryFunction("affine", 0.0, per_req)
        return DemandModel(curves, primary_axis="hbm")

    def _join_vector(self, r: Request) -> ResourceVector:
        """Marginal join demand of one candidate as a single vector:
        post-step KV at ``context + 2`` (the prefill-emitted token plus
        the decode-step token) plus every per-request side-car axis —
        the same costs the prefix curve charges, in the form the
        knapsack subtracts from headroom."""
        axes = {"hbm": self.demand.kv_gb(r.context_len + 2)}
        axes.update(self.demand.per_request_axes())
        return ResourceVector(**axes)

    def _tenant_usage(self, running: Sequence[Request]
                      ) -> Dict[Optional[str], ResourceVector]:
        """This node's per-tenant booked footprint (requests at next
        step's context), the usage the DRF shares score against."""
        usage: Dict[Optional[str], ResourceVector] = {}
        for r in running:
            usage[r.tenant] = usage.get(r.tenant, ResourceVector()) \
                + self.demand.request_vector(r, 1)
        return usage

    def _drf_victim(self, running: Sequence[Request],
                    now: float) -> Request:
        """Eviction choice under tenancy: the request of the tenant
        with the highest weighted dominant share on this node, breaking
        within that tenant (and between tied tenants) toward the last
        request in placement order — fairness picks who pays, placement
        picks which of theirs."""
        order = self.placement.order_jobs(list(running), now=now)
        usage = self._tenant_usage(running)
        shares = {t: self.tenancy.weighted_share_of(t, v, self.budget)
                  for t, v in usage.items()}
        return max(enumerate(order),
                   key=lambda iv: (shares[iv[1].tenant], iv[0]))[1]

    def _violated(self, running: Sequence[Request],
                  extra_tokens: int) -> Tuple[str, ...]:
        booked = self._booked(running, extra_tokens)
        return tuple(a for a, v in booked.items()
                     if a in self.budget and v > self.budget[a] + _EPS)
