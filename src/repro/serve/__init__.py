"""Continuous-batching serving engine driven by step-level vector
admission — the serving-side runtime of the paper's co-location scheme.

Where the cluster simulator asks "how many tasks fit on this host", the
serving engine asks "how many requests fit in this decode step": the
KV-cache is the growing memory footprint, and admission is re-decided
every step through the SAME
:class:`~repro.sched.admission.AdmissionController` /
:class:`~repro.sched.resources.DemandModel` /
:class:`~repro.sched.resources.ResourceVector` machinery the simulator
and ``launch/serve.py`` use.

* ``request`` — :class:`Request` lifecycle (queued/running/finished,
  evict-and-requeue-with-recompute preemption), duck-typed for the
  placement registry.
* ``queue``   — :class:`RequestQueue` over ``sched.arrivals`` streams
  (Poisson or trace) with pluggable placement ordering;
  :func:`requests_from_arrivals` adapts cluster arrival streams.
* ``batcher`` — :class:`ContinuousBatcher`: per-step vector admission
  (calibrated KV-growth demand curve, binding-axis join inverse via
  :class:`PrefixCurve`, lowest-priority preemption, ``forced`` progress
  floor) producing :class:`StepDecision` records.
* ``backends`` — :class:`SimBackend` (virtual-time cost model for
  benchmarks/tests) and :class:`JaxBackend` (the deprecated dense shim:
  ``build_prefill_step``/``build_decode_step`` over a slot-compacted KV
  cache with bucketed padding and shrink hysteresis, golden-pinned).
* ``paged``   — page-granular KV backends: :class:`PageAllocator`
  (free-list over fixed token pages, reservation + live ledgers),
  :class:`PagedSimBackend` / :class:`DenseSimBackend` (virtual-time
  paged-vs-dense residency comparison), and :class:`PagedJaxBackend`
  (``build_prefill_chunk_step``/``build_paged_decode_step`` over a
  shared page pool — chunked prefill interleaved with decode, joins at
  any step, no shared position).
* ``engine``  — :class:`Engine`: the serving loop as ``step`` events on
  the shared :class:`~repro.sched.cluster.ClusterRuntime` — 1..N
  replica Nodes (per-replica budget + backend, heterogeneous via
  ``budgets=``) with arrivals routed by the ``Router`` registry
  (``single``/``least-loaded``/``net-aware``/``topo-aware``);
  ``continuous`` (default) or legacy single-replica ``wave`` mode over
  the same budget/demand/backend.  With a
  :class:`~repro.sched.topology.Topology` bound, prompts ride real
  ingress Transmissions and preempted requests may MIGRATE their paged
  KV to another replica (migrate-vs-recompute on modeled transfer
  time) instead of requeueing locally.
* ``metrics`` — :class:`ServingMetrics`: TTFT / TPOT / goodput /
  SLO-goodput (``Request.ttft_deadline``/``tpot_deadline``) /
  preemption rate / per-step binding-axis and per-node histograms,
  plus per-tenant goodput / SLO-attainment / dominant-share when the
  engine runs with a :class:`~repro.sched.tenancy.TenantRegistry`
  (``Engine(tenants=...)`` turns on weighted-DRF routing via
  ``router="drf"``, knapsack joins in the batcher, and credit-scored
  fairness; ``tenants=None`` stays bit-identical to the untenanted
  engine).
"""
from repro.serve.request import Request, RequestState  # noqa: F401
from repro.serve.queue import (  # noqa: F401
    RequestQueue,
    requests_from_arrivals,
)
from repro.serve.batcher import (  # noqa: F401
    ContinuousBatcher,
    PrefixCurve,
    ServingDemand,
    StepDecision,
)
from repro.serve.backends import (  # noqa: F401
    Backend,
    JaxBackend,
    SimBackend,
)
from repro.serve.paged import (  # noqa: F401
    DenseSimBackend,
    PageAllocator,
    PagedJaxBackend,
    PagedSimBackend,
    pages_for,
)
from repro.serve.engine import MODES, Engine  # noqa: F401
from repro.serve.metrics import ServingMetrics  # noqa: F401
