"""Execution backends for the serving engine.

The engine's scheduling loop (queue -> batcher -> step) is backend
agnostic; a backend owns *how* a step actually runs and how long it
takes, behind three operations::

    join(reqs, now)   # (re)compute KV for joining requests -> seconds
    decode(running)   # one token for every running request  -> seconds
    remove(reqs)      # release finished/preempted slots

* :class:`SimBackend` — virtual-time cost model, no jax import.  Step
  cost is ``base + per_seq * batch``; prefill cost is per token.  This is
  what the benchmark sweep and the tier-1 invariant tests run on: fully
  deterministic, thousands of steps per second.

* :class:`JaxBackend` — the real thing: drives
  ``train.step.build_prefill_step`` / ``build_decode_step`` (and through
  them the decode_attention kernel path) over a slot-compacted KV cache.
  Re-batching uses **bucketed padding** so membership churn does not
  recompile every step: batch capacity rounds up to a power of two and
  join positions quantize to ``sync`` steps, so compile count is bounded
  by O(log(max_batch) * max_len / sync) shapes instead of one per step.

Dense-cache alignment: the model's cache keeps ONE shared position
counter, so a joiner's context is left-padded to the running position
(its tokens occupy the tail).  Joining is therefore only possible while
``prefill_len <= position`` and ``position + remaining_new <= max_len``
— the ``joinable`` predicate the engine passes to the queue.  The
page-granular backends in ``repro.serve.paged`` lift this constraint
(per-request lengths, chunked prefill); ``JaxBackend`` remains the
deprecated dense shim, golden-pinned per the standing contract.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serve.request import Request

PAD_ID = 3  # matches launch/serve.py's filler token


class Backend:
    """Interface; see module docstring.  ``join_stride`` quantizes the
    engine's join opportunities (1 = any step)."""

    join_stride: int = 1

    @property
    def empty(self) -> bool:
        """True when no request occupies a slot — the engine applies the
        restart cohort rules instead of the mid-stream ``joinable``
        filter.  Stateless backends are always 'empty'."""
        return True

    def joinable(self, req: Request) -> bool:
        return True

    def filter_joinable(self, pending: Sequence[Request]
                        ) -> List[Request]:
        """Pending requests this backend can join mid-stream, in the
        given (placement) order.  Backends with a *collective* join
        constraint (e.g. a shared page pool) override this; the default
        applies the per-request ``joinable`` predicate."""
        return [r for r in pending if self.joinable(r)]

    def restart_cohort(self, pending: Sequence[Request]
                       ) -> List[Request]:
        """Empty-backend restart: the greedy prefix of ``pending`` that
        can restart together.  The dense default packs a shared position
        window (max prefill + max remaining <= max_len); stateless
        backends take everything."""
        max_len = getattr(self, "max_len", None)
        if max_len is None:
            return list(pending)
        out: List[Request] = []
        maxp = maxr = 0
        for r in pending:
            p = max(maxp, r.prefill_len)
            n = max(maxr, r.remaining_new)
            if p + n <= max_len:
                out.append(r)
                maxp, maxr = p, n
        return out

    def join(self, reqs: Sequence[Request], now: float) -> float:
        raise NotImplementedError

    def decode(self, running: Sequence[Request]) -> float:
        raise NotImplementedError

    def remove(self, reqs: Sequence[Request]) -> None:
        pass

    # --- KV migration (repro.sched.topology) ------------------------------
    #: True when this backend can take over a request whose KV arrived
    #: over the network (migration target).  Real-cache backends that
    #: cannot materialize foreign KV leave this False — the engine then
    #: falls back to recompute-on-join for them.
    can_adopt: bool = False

    def adopt(self, reqs: Sequence[Request], now: float) -> float:
        """Seat requests whose KV-cache already arrived via a
        transmission: occupy slots WITHOUT recomputing the context (the
        transfer already paid for it in virtual time).  Returns step
        cost in seconds (0 for model backends — no prefill runs)."""
        raise NotImplementedError(
            f"{type(self).__name__} cannot adopt migrated KV "
            f"(can_adopt={self.can_adopt})")

    def recompute_cost(self, req: Request) -> Optional[float]:
        """Modeled seconds to rebuild ``req``'s context from scratch on
        THIS backend — the recompute side of the migrate-vs-recompute
        decision.  ``None`` means unknown (the engine then never
        migrates away from this backend)."""
        return None

    @property
    def position(self) -> int:
        return 0


class SimBackend(Backend):
    """Virtual-time cost model (no jax): decode-step latency grows with
    batch size, prefill latency with recomputed tokens.  Tokens are
    synthesized deterministically so conservation checks can count them."""

    def __init__(self, t_decode_base: float = 5e-3,
                 t_decode_per_seq: float = 1e-3,
                 t_prefill_per_token: float = 2e-4):
        self.t_decode_base = float(t_decode_base)
        self.t_decode_per_seq = float(t_decode_per_seq)
        self.t_prefill_per_token = float(t_prefill_per_token)

    @staticmethod
    def _synth_token(r: Request) -> int:
        return (r.rid * 7919 + r.tokens_decoded) % 50000

    def join(self, reqs: Sequence[Request], now: float) -> float:
        # cost covers the recomputed context; THEN the prefill emits one
        # generated token (its last-position logits), like the jax path
        cost = self.t_prefill_per_token * sum(r.prefill_len for r in reqs)
        for r in reqs:
            if not r.done:
                r.tokens.append(self._synth_token(r))
        return cost

    def decode(self, running: Sequence[Request]) -> float:
        for r in running:
            if not r.done:  # wave mode: finished requests idle in slots
                r.tokens.append(self._synth_token(r))
        return self.step_cost(len(running))

    def step_cost(self, batch: int) -> float:
        """Cost of one decode step at occupancy ``batch`` (also used by
        wave mode, where finished requests idle in their slots)."""
        return self.t_decode_base + self.t_decode_per_seq * max(batch, 1)

    # --- KV migration -----------------------------------------------------
    # stateless cost model: adopting transferred KV is free (the
    # Transmission already charged the virtual wire time); no token is
    # emitted because no prefill runs — the next decode produces one
    can_adopt = True

    def adopt(self, reqs: Sequence[Request], now: float) -> float:
        return 0.0

    def recompute_cost(self, req: Request) -> float:
        return self.t_prefill_per_token * req.prefill_len


def _bucket(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def _shrink_bucket(cap: int, n: int, streak: int,
                   patience: int) -> tuple:
    """Bucket shrink hysteresis: a membership drop only re-buckets the
    batch axis down after ``patience`` consecutive shrink-eligible
    removals, so a join/finish cycle sitting on a power-of-two edge
    stops recompiling every step.  Returns ``(new_cap, new_streak)``."""
    target = _bucket(max(n, 1))
    if target >= cap:
        return cap, 0
    streak += 1
    if streak >= patience:
        return target, 0
    return cap, streak


class JaxBackend(Backend):
    """Real prefill/decode over a slot-compacted, bucket-padded cache.

    Slot layout: ``self._slots[i]`` is the request in cache row ``i``;
    rows ``len(_slots)..cap`` are padding (decoded but discarded).  All
    rows share the cache position ``self._pos``; joins left-pad to it.
    """

    def __init__(self, cfg, params=None, max_len: int = 256,
                 sync: int = 16, seed: int = 0,
                 step_time: Optional[SimBackend] = None,
                 shrink_patience: int = 4):
        import jax
        from repro.models import model as model_lib
        from repro.train.step import build_decode_step, build_prefill_step
        self._jax = jax
        self.cfg = cfg
        self.max_len = int(max_len)
        self.join_stride = max(int(sync), 1)
        self.params = params if params is not None \
            else model_lib.init(cfg, jax.random.key(seed))
        # ONE jitted callable each: jax.jit re-specializes per input
        # shape, and bucketing bounds the distinct shapes it ever sees
        self._prefill = jax.jit(build_prefill_step(cfg, self.max_len))
        self._decode = jax.jit(build_decode_step(cfg),
                               donate_argnums=(1,))
        self._rng = np.random.default_rng(seed)
        self._slots: List[Request] = []
        self._cache = None
        self._last = None          # [cap, 1] int32 last tokens
        self._pos = 0
        self.shrink_patience = max(int(shrink_patience), 1)
        self._shrink_streak = 0
        # virtual time for deterministic schedules; wall time is
        # reported separately by the engine's metrics
        self._timer = step_time or SimBackend()

    # --- joinability ------------------------------------------------------
    @property
    def position(self) -> int:
        return self._pos

    @property
    def empty(self) -> bool:
        return not self._slots

    def joinable(self, req: Request) -> bool:
        if not self._slots:
            return True  # empty batch restarts at the joiner's length
        return (req.prefill_len <= self._pos
                and self._pos + req.remaining_new <= self.max_len)

    # --- slot ops ---------------------------------------------------------
    def _req_tokens(self, req: Request, length: int) -> np.ndarray:
        """Prompt + generated-so-far, left-padded to ``length``."""
        if req.prompt is None:
            req.prompt = list(self._rng.integers(
                PAD_ID, self.cfg.vocab_size, req.prompt_len))
        toks = list(req.prompt) + list(req.tokens)
        assert len(toks) <= length, (req.rid, len(toks), length)
        return np.asarray([PAD_ID] * (length - len(toks)) + toks,
                          np.int32)

    def _prefill_batch(self, reqs: Sequence[Request], length: int):
        import jax.numpy as jnp
        bcap = _bucket(len(reqs))
        toks = np.full((bcap, length), PAD_ID, np.int32)
        for i, r in enumerate(reqs):
            toks[i] = self._req_tokens(r, length)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["enc_embeds"] = jnp.asarray(self._rng.normal(
                0, 0.02, (bcap, 8, self.cfg.d_model)), jnp.float32)
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(self._rng.normal(
                0, 0.02, (bcap, 4, self.cfg.d_model)), jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        last = jnp.argmax(logits, -1).astype(jnp.int32)  # [bcap, 1]
        return cache, last

    @staticmethod
    def _cache_rows(cache, idx: np.ndarray):
        """Gather cache rows along the batch axis (axis 1 for stacked
        [L, B, ...] arrays; the scalar position counter passes through)."""
        import jax.numpy as jnp
        i = jnp.asarray(idx)
        return {k: (v if np.ndim(v) == 0 else jnp.take(v, i, axis=1))
                for k, v in cache.items()}

    @staticmethod
    def _emit_prefill_tokens(reqs: Sequence[Request], last) -> None:
        """A prefill's last-position logits ARE one generated token (the
        first for a fresh join, the next one for a recompute rejoin) —
        emit it, as the pre-engine wave driver did."""
        toks = np.asarray(last[:, 0])
        for i, r in enumerate(reqs):
            if not r.done:
                r.tokens.append(int(toks[i]))

    def join(self, reqs: Sequence[Request], now: float) -> float:
        import jax.numpy as jnp
        reqs = list(reqs)
        if not reqs:
            return 0.0
        if not self._slots:
            # (re)start: position = longest prefill, rounded up to the
            # sync quantum so restart shapes stay bucketed too — but
            # never so far up that the slowest joiner's remaining decode
            # would run past max_len (cache writes must stay in bounds)
            need = max(r.prefill_len for r in reqs)
            maxr = max(r.remaining_new for r in reqs)
            pos = -(-need // self.join_stride) * self.join_stride
            self._pos = max(min(pos, self.max_len - maxr), need)
            # the batch prefills EVERY row to the padded position, not
            # to its raw prefill length — charge what actually runs
            cost = self._timer.t_prefill_per_token * self._pos * len(reqs)
            self._cache, self._last = self._prefill_batch(reqs, self._pos)
            self._slots = reqs
            self._shrink_streak = 0
            self._emit_prefill_tokens(reqs, self._last)
            return cost
        assert all(self.joinable(r) for r in reqs)
        cost = self._timer.t_prefill_per_token * self._pos * len(reqs)
        new_cache, new_last = self._prefill_batch(reqs, self._pos)
        n_old, n_new = len(self._slots), len(reqs)
        cap = _bucket(n_old + n_new)
        old_cap = self._last.shape[0]
        if cap > old_cap:  # grow the bucket: zero-pad the batch axis
            pad = cap - old_cap
            self._cache = {
                k: (v if np.ndim(v) == 0
                    else jnp.pad(v, [(0, 0), (0, pad)]
                                 + [(0, 0)] * (v.ndim - 2)))
                for k, v in self._cache.items()}
            self._last = jnp.pad(self._last, [(0, pad), (0, 0)])
        # scatter the joiners' rows into slots [n_old, n_old + n_new)
        rows = self._cache_rows(new_cache, np.arange(n_new))
        self._cache = {
            k: (v if np.ndim(v) == 0 else
                jnp.concatenate([v[:, :n_old], rows[k],
                                 v[:, n_old + n_new:]], axis=1))
            for k, v in self._cache.items()}
        self._last = jnp.concatenate(
            [self._last[:n_old], new_last[:n_new],
             self._last[n_old + n_new:]], axis=0)
        self._slots = self._slots + reqs
        self._shrink_streak = 0
        self._emit_prefill_tokens(reqs, new_last)
        return cost

    def decode(self, running: Sequence[Request]) -> float:
        import jax.numpy as jnp
        assert set(id(r) for r in running) == \
            set(id(r) for r in self._slots), "engine/backend slot drift"
        assert self._pos < self.max_len, \
            "decode would write past max_len — join gating broke"
        logits, self._cache = self._decode(self.params, self._cache,
                                           self._last)
        self._last = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = np.asarray(self._last[:, 0])
        for i, r in enumerate(self._slots):
            if not r.done:  # wave mode: finished requests idle in slots
                r.tokens.append(int(toks[i]))
        self._pos += 1
        return self._timer.step_cost(len(self._slots))

    def remove(self, reqs: Sequence[Request]) -> None:
        drop = {id(r) for r in reqs}
        keep = [i for i, r in enumerate(self._slots)
                if id(r) not in drop]
        self._slots = [self._slots[i] for i in keep]
        if not self._slots:
            self._cache, self._last, self._pos = None, None, 0
            self._shrink_streak = 0
            return
        cap, self._shrink_streak = _shrink_bucket(
            self._last.shape[0], len(self._slots),
            self._shrink_streak, self.shrink_patience)
        idx = np.asarray(keep + [keep[0]] * (cap - len(keep)))
        self._cache = self._cache_rows(self._cache, idx)
        import jax.numpy as jnp
        self._last = jnp.take(self._last, jnp.asarray(idx), axis=0)
