"""Serving work unit: one generation request and its lifecycle.

A :class:`Request` is the serving analogue of the simulator's job: it
arrives at a point in time, carries a prompt, wants ``max_new_tokens``
decoded, and occupies a growing slice of device memory (its KV cache)
while running.  The lifecycle is::

    QUEUED --admit--> RUNNING --last token--> FINISHED
       ^                 |
       +----preempt------+   (evict-and-requeue with recompute)

Preemption keeps the tokens decoded so far — on re-admission the engine
recomputes their KV by prefilling ``prompt + generated`` (the vLLM-style
recompute policy), so no emitted token is ever lost, only the time spent
building its cache.

Requests are duck-typed for the :mod:`repro.sched.placement` registry
(``arrival`` / ``c_iso`` / ``items`` / ``unassigned``), so the same
fcfs/sjf/best-fit/arrival-aware policies that order simulator jobs order
the serving queue — and pick preemption victims (lowest-priority =
last in placement order).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival: float = 0.0
    prompt: Optional[List[int]] = None      # token ids (jax backend)
    # --- SLO deadlines (None = unconstrained) ----------------------------
    #: max seconds from arrival to the first token (queueing + prefill)
    ttft_deadline: Optional[float] = None
    #: max mean seconds per output token after the first (decode cadence)
    tpot_deadline: Optional[float] = None
    #: owning tenant for fairness accounting (None = untenanted; all
    #: such requests share one default bucket — see sched.tenancy)
    tenant: Optional[str] = None

    # --- lifecycle (owned by the engine) ---------------------------------
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = field(default_factory=list)  # generated so far
    admissions: int = 0        # times admitted (first + re-admissions)
    preemptions: int = 0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    def __post_init__(self):
        if self.prompt_len <= 0:
            raise ValueError(f"request {self.rid}: prompt_len must be > 0")
        if self.max_new_tokens <= 0:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be > 0")

    # --- derived sizes ----------------------------------------------------
    @property
    def tokens_decoded(self) -> int:
        return len(self.tokens)

    @property
    def context_len(self) -> int:
        """Tokens currently holding KV slots: prompt + decoded."""
        return self.prompt_len + self.tokens_decoded

    @property
    def prefill_len(self) -> int:
        """Tokens to (re)compute on admission.  After a preemption this
        includes the already-generated tokens (recompute policy)."""
        return self.context_len

    @property
    def remaining_new(self) -> int:
        return max(self.max_new_tokens - self.tokens_decoded, 0)

    @property
    def done(self) -> bool:
        return self.tokens_decoded >= self.max_new_tokens

    # --- SLO attainment ---------------------------------------------------
    def meets_slo(self) -> bool:
        """True when every declared deadline held for this (finished)
        request: TTFT within ``ttft_deadline``, mean decode cadence
        within ``tpot_deadline`` (vacuous with a single token).  A
        request with no deadlines always meets its (empty) SLO."""
        if self.ttft_deadline is not None:
            if self.first_token_t is None or \
                    self.first_token_t - self.arrival > self.ttft_deadline:
                return False
        if self.tpot_deadline is not None and self.tokens_decoded > 1:
            if self.finish_t is None or self.first_token_t is None:
                return False
            tpot = (self.finish_t - self.first_token_t) \
                / (self.tokens_decoded - 1)
            if tpot > self.tpot_deadline:
                return False
        return True

    # --- placement-registry duck typing ----------------------------------
    @property
    def c_iso(self) -> float:
        """Isolated 'service time' proxy: total tokens to process."""
        return float(self.prompt_len + self.max_new_tokens)

    @property
    def items(self) -> float:
        return float(self.prompt_len + self.max_new_tokens)

    @property
    def unassigned(self) -> float:
        """Remaining work, so SJF ranks by what is left, not what was."""
        return float(self.prompt_len + self.remaining_new)

    def __repr__(self) -> str:
        return (f"Request(rid={self.rid}, prompt={self.prompt_len}, "
                f"new={self.tokens_decoded}/{self.max_new_tokens}, "
                f"state={self.state.value})")
