"""Model assembly for all assigned architecture families.

Families: dense / moe (decoder-only LMs), encdec (whisper backbone),
vlm (pixtral backbone; vision frontend stubbed), ssm (mamba2),
hybrid (zamba2: mamba2 blocks + a shared attention block every N).

Design rules:
  * Layers run under ``jax.lax.scan`` over stacked params — HLO size and
    compile time are O(1) in depth (critical for 61-layer 1T-param dry-runs).
  * Same spec tree drives abstract (ShapeDtypeStruct) and concrete init.
  * All entry points are pure functions: (params, cfg, batch[, cache]) -> out.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention, decode_attention,
                                    paged_decode_attention)
from repro.models.layers import mlp, rms_norm, softcap
from repro.models.moe import moe_ffn
from repro.models.params import P, abstract_params, init_params

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _stack(specs: Dict[str, P], n: int) -> Dict[str, P]:
    return {k: P((n,) + v.shape, v.init, v.axis, v.scale, v.dtype)
            for k, v in specs.items()}


def _attn_specs(cfg: ModelConfig) -> Dict[str, P]:
    d, hd = cfg.d_model, cfg.head_dim
    s: Dict[str, P] = {
        "ln_w": P((d,), "ones"),
        "wq": P((d, cfg.num_heads * hd)),
        "wk": P((d, cfg.num_kv_heads * hd)),
        "wv": P((d, cfg.num_kv_heads * hd)),
        "wo": P((cfg.num_heads * hd, d)),
    }
    if cfg.use_qk_norm:
        s["q_norm"] = P((hd,), "ones")
        s["k_norm"] = P((hd,), "ones")
    if cfg.use_post_norm:
        s["post_ln_w"] = P((d,), "ones")
    return s


def _mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, P]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "ln_w": P((d,), "ones"),
        "wi_gate": P((d, f)),
        "wi_up": P((d, f)),
        "wo": P((f, d)),
    }
    if cfg.use_post_norm:
        s["post_ln_w"] = P((d,), "ones")
    return s


def _moe_specs(cfg: ModelConfig) -> Dict[str, P]:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    s = {
        "ln_w": P((d,), "ones"),
        "w_router": P((d, E), "small", scale=0.02, dtype="float32"),
        "w_gate": P((E, d, f)),
        "w_up": P((E, d, f)),
        "w_down": P((E, f, d), axis=-2),
    }
    return s


def _mamba_specs(cfg: ModelConfig) -> Dict[str, P]:
    dm = ssm_mod.mamba2_dims(cfg)
    d = cfg.d_model
    return {
        "ln_w": P((d,), "ones"),
        "in_proj": P((d, dm["in_dim"])),
        "conv_w": P((cfg.conv_width, dm["conv_ch"]), "small", scale=0.1),
        "conv_b": P((dm["conv_ch"],), "zeros"),
        "dt_bias": P((dm["H"],), "zeros", dtype="float32"),
        "A_log": P((dm["H"],), "ones", dtype="float32"),
        "D": P((dm["H"],), "ones", dtype="float32"),
        "norm_w": P((dm["di"],), "ones"),
        "out_proj": P((dm["di"], d)),
    }


def param_specs(cfg: ModelConfig) -> Params:
    d, V = cfg.d_model, cfg.vocab_size
    specs: Params = {
        "embed": P((V, d), "embed", scale=0.02),
        "final_ln_w": P((d,), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((d, V), "small", scale=0.02)

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global:  # gemma2: scan over (local, global) pairs
            npairs = cfg.num_layers // 2
            specs["local"] = {"attn": _stack(_attn_specs(cfg), npairs),
                              "mlp": _stack(_mlp_specs(cfg), npairs)}
            specs["global"] = {"attn": _stack(_attn_specs(cfg), npairs),
                               "mlp": _stack(_mlp_specs(cfg), npairs)}
        else:
            L = cfg.num_layers
            specs["blocks"] = {"attn": _stack(_attn_specs(cfg), L),
                               "mlp": _stack(_mlp_specs(cfg), L)}
    elif cfg.family == "moe":
        L = cfg.num_layers
        specs["blocks"] = {"attn": _stack(_attn_specs(cfg), L),
                           "moe": _stack(_moe_specs(cfg), L)}
        if cfg.d_ff > 0:  # shared dense expert (kimi-k2)
            specs["blocks"]["shared_mlp"] = _stack(
                _mlp_specs(cfg, cfg.d_ff), L)
    elif cfg.family == "encdec":
        L = cfg.num_layers
        specs["enc_blocks"] = {"attn": _stack(_attn_specs(cfg), L),
                               "mlp": _stack(_mlp_specs(cfg), L)}
        specs["dec_blocks"] = {"self_attn": _stack(_attn_specs(cfg), L),
                               "cross_attn": _stack(_attn_specs(cfg), L),
                               "mlp": _stack(_mlp_specs(cfg), L)}
        specs["enc_final_ln_w"] = P((d,), "ones")
    elif cfg.family == "ssm":
        specs["blocks"] = {"mamba": _stack(_mamba_specs(cfg),
                                           cfg.num_layers)}
    elif cfg.family == "hybrid":
        assert cfg.num_layers % cfg.attn_every == 0
        specs["blocks"] = {"mamba": _stack(_mamba_specs(cfg),
                                           cfg.num_layers)}
        specs["shared"] = {"attn": _attn_specs(cfg),
                           "mlp": _mlp_specs(cfg)}
    else:
        raise ValueError(cfg.family)
    return specs


def abstract(cfg: ModelConfig) -> Params:
    return abstract_params(param_specs(cfg), cfg.param_dtype)


def init(cfg: ModelConfig, rng) -> Params:
    return init_params(param_specs(cfg), rng, cfg.param_dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _qk_normed(p, cfg, q, k):
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def _attn_scale(cfg) -> float:
    dim = getattr(cfg, "attn_scale_dim", 0) or cfg.head_dim
    return float(dim) ** -0.5


def attn_block(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
               mode: str,                    # train | prefill | decode
               causal: bool = True,
               window: int = 0,
               layer_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               pos: Optional[jnp.ndarray] = None,
               cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               rope: bool = True):
    """Pre-norm attention with residual. Returns (x_out, new_kv | None).

    * train:   full self-attention, new_kv=None
    * prefill: full self-attention, returns (k, v) [B,S,Hkv,hd]
    * decode:  layer_kv is the full cache slice; the new token's k/v is
               written at index ``pos``; returns updated cache slice.
    * cross_kv set -> cross-attention (no rope, non-causal, ignores cache).
    """
    if layer_kv is not None and layer_kv[0].size == 0:
        layer_kv = None  # scan placeholder for cache-less modes
    B, S, _ = x.shape
    hd = cfg.head_dim
    h = rms_norm(x, p["ln_w"], cfg.norm_eps, use_pallas=False)
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"]).reshape(
        B, S, cfg.num_heads, hd)

    new_kv = None
    if cross_kv is not None:
        k, v = cross_kv
        q, k = _qk_normed(p, cfg, q, k)
        out = attention(q, k, v, causal=False, scale=_attn_scale(cfg),
                        attn_softcap=cfg.attn_softcap,
                        use_pallas=cfg.use_pallas,
                        f32_logits=cfg.attn_f32_logits)
    else:
        k = jnp.einsum("bsd,dk->bsk", h, p["wk"]).reshape(
            B, S, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,dk->bsk", h, p["wv"]).reshape(
            B, S, cfg.num_kv_heads, hd)
        q, k = _qk_normed(p, cfg, q, k)
        if mode == "decode":
            assert layer_kv is not None and pos is not None and S == 1
            if rope:
                from repro.models.layers import apply_rope
                posv = jnp.asarray(pos, jnp.int32).reshape(1)
                q = apply_rope(q, posv, cfg.rope_theta)
                k = apply_rope(k, posv, cfg.rope_theta)
            ck, cv = layer_kv
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), pos, axis=1)
            out = decode_attention(
                q, ck, cv, pos, window=window,
                attn_softcap=cfg.attn_softcap, scale=_attn_scale(cfg),
                use_pallas=cfg.use_pallas,
                f32_logits=cfg.attn_f32_logits)
            new_kv = (ck, cv)
        else:
            if rope:
                from repro.models.layers import apply_rope
                posv = jnp.arange(S)
                q = apply_rope(q, posv, cfg.rope_theta)
                k = apply_rope(k, posv, cfg.rope_theta)
            out = attention(q, k, v, causal=causal, window=window,
                            attn_softcap=cfg.attn_softcap,
                            scale=_attn_scale(cfg),
                            use_pallas=cfg.use_pallas,
                            f32_logits=cfg.attn_f32_logits)
            if mode == "prefill":
                new_kv = (k, v)

    out = jnp.einsum("bsk,kd->bsd",
                     out.reshape(B, S, cfg.num_heads * hd), p["wo"])
    if cfg.use_post_norm:
        out = rms_norm(out, p["post_ln_w"], cfg.norm_eps)
    return x + out, new_kv


def mlp_block(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(x, p["ln_w"], cfg.norm_eps)
    out = mlp(h, p["wi_gate"], p["wi_up"], p["wo"], cfg.act)
    if cfg.use_post_norm:
        out = rms_norm(out, p["post_ln_w"], cfg.norm_eps)
    return x + out


def moe_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              shared_mlp: Optional[Params] = None):
    B, S, d = x.shape
    h = rms_norm(x, p["ln_w"], cfg.norm_eps)
    from repro.models.moe_ep import current_ep_mesh, moe_ffn_ep
    impl = moe_ffn_ep if current_ep_mesh() is not None else moe_ffn
    out = impl(h.reshape(B * S, d), p["w_router"], p["w_gate"],
               p["w_up"], p["w_down"], k=cfg.experts_per_token,
               capacity_factor=cfg.capacity_factor, act=cfg.act)
    y = out.y.reshape(B, S, d)
    if shared_mlp is not None:
        hs = rms_norm(x, shared_mlp["ln_w"], cfg.norm_eps)
        y = y + mlp(hs, shared_mlp["wi_gate"], shared_mlp["wi_up"],
                    shared_mlp["wo"], cfg.act)
    return x + y, out.aux_loss


def mamba_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                state: Optional[ssm_mod.SSMState] = None, *,
                decode: bool = False):
    h = rms_norm(x, p["ln_w"], cfg.norm_eps)
    y, new_state = ssm_mod.mamba2_block(p, cfg, h, state, decode=decode)
    return x + y, new_state


# ---------------------------------------------------------------------------
# Scan-over-layers drivers
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg, mode):
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _scan(body, carry, xs, cfg, mode):
    return jax.lax.scan(_maybe_remat(body, cfg, mode), carry, xs)


# ---------------------------------------------------------------------------
# Forward passes per family
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens):
    x = params["embed"][tokens]  # gather [B,S,d]
    if getattr(cfg, "embed_scale", False) or cfg.local_global:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _unembed(params, cfg, h):
    """Final norm + LM head (+ gemma2 final softcap). h: [..., d]."""
    h = rms_norm(h, params["final_ln_w"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("...d,dv->...v", h, w,
                        preferred_element_type=jnp.float32)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def _dense_stack(params, cfg, x, mode, cache=None):
    """Dense / vlm decoder stack. Returns (h, new_cache_kv, aux)."""
    aux = jnp.zeros((), jnp.float32)
    pos = None if cache is None else cache["len"]

    if cfg.local_global:
        def body(h, xs):
            (pl, pg, kvl, kvg) = xs
            h, nkvl = attn_block(pl["attn"], cfg, h, mode=mode,
                                 window=cfg.sliding_window, layer_kv=kvl,
                                 pos=pos)
            h = mlp_block(pl["mlp"], cfg, h)
            h, nkvg = attn_block(pg["attn"], cfg, h, mode=mode,
                                 layer_kv=kvg, pos=pos)
            h = mlp_block(pg["mlp"], cfg, h)
            return h, (nkvl, nkvg)

        kvl = (cache["local_k"], cache["local_v"]) if cache else None
        kvg = (cache["global_k"], cache["global_v"]) if cache else None
        npairs = cfg.num_layers // 2
        xs = (params["local"], params["global"],
              _split_kv(kvl, npairs), _split_kv(kvg, npairs))
        x, (nkvl, nkvg) = _scan(body, x, xs, cfg, mode)
        new_kv = _merge_local_global(nkvl, nkvg, mode)
        return x, new_kv, aux

    def body(h, xs):
        (pb, kv) = xs
        h, nkv = attn_block(pb["attn"], cfg, h, mode=mode, layer_kv=kv,
                            pos=pos)
        if "moe" in pb:
            h, a = moe_block(pb["moe"], cfg, h, pb.get("shared_mlp"))
        else:
            h = mlp_block(pb["mlp"], cfg, h)
            a = jnp.zeros((), jnp.float32)
        return h, (nkv, a)

    kv = (cache["k"], cache["v"]) if cache else None
    xs = (params["blocks"], _split_kv(kv, cfg.num_layers))
    x, (nkv, auxs) = _scan(body, x, xs, cfg, mode)
    new_cache = None if mode == "train" else {"k": nkv[0], "v": nkv[1]}
    return x, new_cache, jnp.sum(auxs)


def _split_kv(kv, n):
    """Cache arrays already have leading L dim -> scan consumes them as xs.
    When no cache, feed size-0 placeholders (scan needs a pytree with
    leading dim n); attn_block treats size-0 kv as None."""
    if kv is None:
        return (jnp.zeros((n, 0)), jnp.zeros((n, 0)))
    return kv


def _merge_local_global(nkvl, nkvg, mode):
    if mode == "train":
        return None
    return {"local_k": nkvl[0], "local_v": nkvl[1],
            "global_k": nkvg[0], "global_v": nkvg[1]}


def _ssm_stack(params, cfg, x, mode, cache=None):
    """Pure-mamba stack. cache: {"ssm": [L,B,H,P,N], "conv": [L,B,W-1,ch]}."""
    decode = mode == "decode"

    def body(h, xs):
        pb, st = xs
        state = (ssm_mod.SSMState(ssm=st[0], conv=st[1])
                 if st is not None and st[0].ndim > 2 else None)
        h, ns = mamba_block(pb["mamba"], cfg, h, state, decode=decode)
        out = ((ns.ssm, ns.conv) if ns is not None
               else (jnp.zeros((0,)), jnp.zeros((0,))))
        return h, out

    st = ((cache["ssm"], cache["conv"]) if cache is not None
          else (jnp.zeros((cfg.num_layers, 0, 0)),
                jnp.zeros((cfg.num_layers, 0, 0))))
    x, (nssm, nconv) = _scan(body, x, (params["blocks"], st), cfg, mode)
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": nssm, "conv": nconv}
    return x, new_cache, jnp.zeros((), jnp.float32)


def _hybrid_stack(params, cfg, x, mode, cache=None):
    """Zamba2: groups of ``attn_every`` mamba blocks, a single *shared*
    attention+MLP block applied before each group (per-application KV)."""
    n_apps = cfg.num_layers // cfg.attn_every
    per = cfg.attn_every
    decode = mode == "decode"
    pos = None if cache is None else cache["len"]
    shared = params["shared"]

    def group_body(h, xs):
        mamba_group, st_group, kv = xs
        h, nkv = attn_block(shared["attn"], cfg, h, mode=mode, layer_kv=kv,
                            pos=pos)
        h = mlp_block(shared["mlp"], cfg, h)

        def inner(hh, inner_xs):
            pb, st = inner_xs
            state = (ssm_mod.SSMState(ssm=st[0], conv=st[1])
                     if st is not None and st[0].ndim > 2 else None)
            hh, ns = mamba_block(pb, cfg, hh, state, decode=decode)
            out = ((ns.ssm, ns.conv) if ns is not None
                   else (jnp.zeros((0,)), jnp.zeros((0,))))
            return hh, out

        h, nst = jax.lax.scan(_maybe_remat(inner, cfg, mode), h,
                              (mamba_group, st_group))
        nkv_out = nkv if nkv is not None else (jnp.zeros((0,)),) * 2
        return h, (nst, nkv_out)

    mb = params["blocks"]["mamba"]
    mamba_grouped = jax.tree.map(
        lambda a: a.reshape((n_apps, per) + a.shape[1:]), mb)
    if cache is not None:
        st = (cache["ssm"].reshape((n_apps, per) + cache["ssm"].shape[1:]),
              cache["conv"].reshape((n_apps, per) + cache["conv"].shape[1:]))
        kv = (cache["k"], cache["v"])  # [n_apps, B, S, Hkv, hd]
    else:
        st = (jnp.zeros((n_apps, per, 0)), jnp.zeros((n_apps, per, 0)))
        kv = (jnp.zeros((n_apps, 0)), jnp.zeros((n_apps, 0)))

    x, (nst, nkv) = _scan(group_body, x, (mamba_grouped, st, kv), cfg, mode)
    new_cache = None
    if cache is not None:
        L = cfg.num_layers
        new_cache = {
            "ssm": nst[0].reshape((L,) + nst[0].shape[2:]),
            "conv": nst[1].reshape((L,) + nst[1].shape[2:]),
            "k": nkv[0], "v": nkv[1],
        }
    return x, new_cache, jnp.zeros((), jnp.float32)


def _encdec_stacks(params, cfg, enc_x, dec_x, mode, cache=None):
    """Whisper backbone. enc_x: [B,S_enc,d] embeddings (frontend stub);
    dec_x: [B,S_dec,d] decoder token embeddings."""
    pos = None if cache is None else cache["len"]

    if enc_x is not None:
        def enc_body(h, pb):
            h, _ = attn_block(pb["attn"], cfg, h, mode="train", causal=False,
                              rope=False)
            h = mlp_block(pb["mlp"], cfg, h)
            return h, None
        enc_h, _ = _scan(enc_body, enc_x, params["enc_blocks"], cfg, mode)
        enc_h = rms_norm(enc_h, params["enc_final_ln_w"], cfg.norm_eps)

        def cross_kv_body(_, pb):
            k = jnp.einsum("bsd,dk->bsk", enc_h, pb["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dk->bsk", enc_h, pb["cross_attn"]["wv"])
            B, S, _ = enc_h.shape
            k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
            v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
            return None, (k, v)
        _, cross = jax.lax.scan(cross_kv_body, None, params["dec_blocks"])
    else:
        cross = (cache["cross_k"], cache["cross_v"])

    def dec_body(h, xs):
        pb, kv, ckv = xs
        h, nkv = attn_block(pb["self_attn"], cfg, h, mode=mode, layer_kv=kv,
                            pos=pos)
        h, _ = attn_block(pb["cross_attn"], cfg, h, mode="train",
                          cross_kv=ckv, rope=False)
        h = mlp_block(pb["mlp"], cfg, h)
        return h, nkv if nkv is not None else (jnp.zeros((0,)),) * 2

    kv = (cache["k"], cache["v"]) if cache else None
    xs = (params["dec_blocks"], _split_kv(kv, cfg.num_layers), cross)
    dec_h, nkv = _scan(dec_body, dec_x, xs, cfg, mode)
    new_cache = None
    if mode != "train":
        new_cache = {"k": nkv[0], "v": nkv[1],
                     "cross_k": cross[0], "cross_v": cross[1]}
    return dec_h, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_train(params: Params, cfg: ModelConfig, batch: Dict[str, Any]):
    """Returns (hidden [B,S,d], aux_loss scalar). Loss lives in train/loss.py."""
    if cfg.family == "encdec":
        enc_x = batch["enc_embeds"].astype(jnp.dtype(cfg.compute_dtype))
        dec_x = _embed(params, cfg, batch["tokens"])
        h, _, aux = _encdec_stacks(params, cfg, enc_x, dec_x, "train")
        return h, aux
    x = _embed(params, cfg, batch["tokens"])
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    stack = {"dense": _dense_stack, "moe": _dense_stack, "vlm": _dense_stack,
             "ssm": _ssm_stack, "hybrid": _hybrid_stack}[cfg.family]
    h, _, aux = stack(params, cfg, x, "train")
    return h, aux


def lm_logits(params: Params, cfg: ModelConfig, hidden: jnp.ndarray):
    return _unembed(params, cfg, hidden)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract_only: bool = False,
               cross_len: int = 1500):
    """KV/SSM cache pytree (concrete zeros or ShapeDtypeStructs)."""
    dt = jnp.dtype(cfg.compute_dtype)
    mk = (jax.ShapeDtypeStruct if abstract_only
          else lambda s, d: jnp.zeros(s, d))
    hd, Hkv = cfg.head_dim, cfg.num_kv_heads
    cache: Dict[str, Any] = {"len": mk((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.local_global:
            npairs = cfg.num_layers // 2
            for pre in ("local", "global"):
                cache[f"{pre}_k"] = mk((npairs, batch, max_len, Hkv, hd), dt)
                cache[f"{pre}_v"] = mk((npairs, batch, max_len, Hkv, hd), dt)
        else:
            L = cfg.num_layers
            cache["k"] = mk((L, batch, max_len, Hkv, hd), dt)
            cache["v"] = mk((L, batch, max_len, Hkv, hd), dt)
    elif cfg.family == "encdec":
        L = cfg.num_layers
        cache["k"] = mk((L, batch, max_len, Hkv, hd), dt)
        cache["v"] = mk((L, batch, max_len, Hkv, hd), dt)
        cache["cross_k"] = mk((L, batch, cross_len, Hkv, hd), dt)
        cache["cross_v"] = mk((L, batch, cross_len, Hkv, hd), dt)
    elif cfg.family == "ssm":
        dm = ssm_mod.mamba2_dims(cfg)
        L = cfg.num_layers
        cache["ssm"] = mk((L, batch, dm["H"], dm["P"], dm["N"]), jnp.float32)
        cache["conv"] = mk((L, batch, cfg.conv_width - 1, dm["conv_ch"]), dt)
    elif cfg.family == "hybrid":
        dm = ssm_mod.mamba2_dims(cfg)
        L, n_apps = cfg.num_layers, cfg.num_layers // cfg.attn_every
        cache["ssm"] = mk((L, batch, dm["H"], dm["P"], dm["N"]), jnp.float32)
        cache["conv"] = mk((L, batch, cfg.conv_width - 1, dm["conv_ch"]), dt)
        cache["k"] = mk((n_apps, batch, max_len, Hkv, hd), dt)
        cache["v"] = mk((n_apps, batch, max_len, Hkv, hd), dt)
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, abstract_only: bool = False):
    """Page-pool KV cache: a shared pool of fixed-size token pages plus a
    per-request page table and length.  Page 0 is the scratch page —
    unused table slots (and padding rows) point at it, so every gather
    hits a valid page and garbage writes land harmlessly.

    Layout: {"lens": [B], "table": [B, maxp], "k"/"v": [L, P, page, Hkv,
    hd]} where maxp = num_pages - 1 upper-bounds any one request.
    """
    if cfg.family not in ("dense", "moe", "vlm") or cfg.local_global:
        raise NotImplementedError(
            f"paged KV cache supports dense-stack families, got "
            f"{cfg.family} (local_global={cfg.local_global})")
    dt = jnp.dtype(cfg.compute_dtype)
    mk = (jax.ShapeDtypeStruct if abstract_only
          else lambda s, d: jnp.zeros(s, d))
    L, hd, Hkv = cfg.num_layers, cfg.head_dim, cfg.num_kv_heads
    maxp = max(num_pages - 1, 1)
    return {
        "lens": mk((batch,), jnp.int32),
        "table": mk((batch, maxp), jnp.int32),
        "k": mk((L, num_pages, page_size, Hkv, hd), dt),
        "v": mk((L, num_pages, page_size, Hkv, hd), dt),
    }


def _paged_kv_write(pool, new, table, positions, page_size):
    """Scatter per-token k/v into the page pool.

    pool: [P, page, Hkv, hd]; new: [B, S, Hkv, hd]; positions: [B, S]
    absolute token positions; table: [B, maxp].  Rows whose position
    maps to the scratch page (id 0) overwrite garbage only.
    """
    pids = jnp.take_along_axis(table, positions // page_size, axis=1)
    offs = positions % page_size
    return pool.at[pids, offs].set(new.astype(pool.dtype))


def _paged_attn_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                      pools, table, write_table, positions, kv_lens, *,
                      chunk_attend: bool):
    """Pre-norm attention with residual over the page pool.

    x: [B, S, d]; positions: [B, S] absolute positions of these tokens;
    kv_lens: [B] total valid tokens after this write.  KV writes route
    through ``write_table`` (inactive rows' tables are zeroed there, so
    their writes land on the scratch page); gathers use the real
    ``table``.  With ``chunk_attend`` the S chunk tokens attend causally
    through the gathered pages (prefill chunks); otherwise S == 1 decode.
    """
    from repro.kernels.paged_attention.ref import gather_pages
    from repro.models.layers import apply_rope
    B, S, _ = x.shape
    hd = cfg.head_dim
    h = rms_norm(x, p["ln_w"], cfg.norm_eps, use_pallas=False)
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"]).reshape(
        B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dk->bsk", h, p["wk"]).reshape(
        B, S, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dk->bsk", h, p["wv"]).reshape(
        B, S, cfg.num_kv_heads, hd)
    q, k = _qk_normed(p, cfg, q, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    page = pools[0].shape[1]
    kp = _paged_kv_write(pools[0], k, write_table, positions, page)
    vp = _paged_kv_write(pools[1], v, write_table, positions, page)
    if chunk_attend:
        kd = gather_pages(kp, table)           # [B, maxp*page, Hkv, hd]
        vd = gather_pages(vp, table)
        out = attention(
            q, kd, vd, causal=True, q_positions=positions,
            k_positions=jnp.arange(kd.shape[1]), kv_len=kv_lens,
            attn_softcap=cfg.attn_softcap, scale=_attn_scale(cfg),
            use_pallas=False, f32_logits=cfg.attn_f32_logits)
    else:
        out = paged_decode_attention(
            q, kp, vp, table, kv_lens,
            attn_softcap=cfg.attn_softcap, scale=_attn_scale(cfg),
            use_pallas=cfg.use_pallas, f32_logits=cfg.attn_f32_logits)
    out = jnp.einsum("bsk,kd->bsd",
                     out.reshape(B, S, cfg.num_heads * hd), p["wo"])
    if cfg.use_post_norm:
        out = rms_norm(out, p["post_ln_w"], cfg.norm_eps)
    return x + out, (kp, vp)


def _paged_stack(params, cfg, x, cache, positions, kv_lens, active, *,
                 chunk_attend: bool):
    """Dense/moe/vlm stack over the page pool; pools ride scan xs just
    like the dense cache's [L, B, ...] arrays ride theirs."""
    table = cache["table"]
    if active is None:
        write_table = table
    else:
        write_table = jnp.where(jnp.asarray(active, bool)[:, None],
                                table, 0)

    def body(h, xs):
        pb, pools = xs
        h, npools = _paged_attn_block(
            pb["attn"], cfg, h, pools, table, write_table, positions,
            kv_lens, chunk_attend=chunk_attend)
        if "moe" in pb:
            h, _ = moe_block(pb["moe"], cfg, h, pb.get("shared_mlp"))
        else:
            h = mlp_block(pb["mlp"], cfg, h)
        return h, npools

    xs = (params["blocks"], (cache["k"], cache["v"]))
    x, (nk, nv) = _scan(body, x, xs, cfg, "decode")
    return x, {"k": nk, "v": nv, "table": table}


def decode_step_paged(params: Params, cfg: ModelConfig, cache,
                      token: jnp.ndarray, active=None):
    """One-token decode over the paged cache; every row is at its own
    position ``lens[b]``.  token: [B, 1] int32; active: optional [B]
    bool — inactive rows (mid-prefill / padding) write to the scratch
    page, keep their length, and produce garbage logits callers must
    not read.  Returns (logits [B, 1, V], updated cache)."""
    x = _embed(params, cfg, token)
    positions = cache["lens"][:, None]          # [B, 1]
    h, nc = _paged_stack(params, cfg, x, cache, positions,
                         cache["lens"] + 1, active, chunk_attend=False)
    nl = cache["lens"] + 1
    if active is not None:
        nl = jnp.where(jnp.asarray(active, bool), nl, cache["lens"])
    nc["lens"] = nl
    return _unembed(params, cfg, h), nc


def prefill_chunk(params: Params, cfg: ModelConfig, cache,
                  tokens: jnp.ndarray, start: jnp.ndarray,
                  chunk_lens: jnp.ndarray, active=None):
    """Process one prompt chunk per row, writing KV into the rows' pages.

    tokens: [B, C] int32 (PAD-filled past each row's chunk); start: [B]
    int32 absolute position of each row's first chunk token;
    chunk_lens: [B] int32 valid tokens this chunk (<= C; short final
    chunks PAD-fill the tail — those writes land beyond the row's
    length inside its own pages, masked now and overwritten by the next
    chunk or decode); active: optional [B] bool — inactive rows
    (decoding / idle) write to the scratch page and keep their length.
    Returns (logits at each row's last valid chunk token [B, 1, V],
    cache with lens = start + chunk_lens for active rows).
    """
    x = _embed(params, cfg, tokens)
    C = tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    chunk_lens = jnp.asarray(chunk_lens, jnp.int32)
    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    h, nc = _paged_stack(params, cfg, x, cache, positions,
                         start + chunk_lens, active, chunk_attend=True)
    nl = start + chunk_lens
    if active is not None:
        nl = jnp.where(jnp.asarray(active, bool), nl, cache["lens"])
    nc["lens"] = nl
    last = jnp.take_along_axis(
        h, jnp.maximum(chunk_lens - 1, 0)[:, None, None], axis=1)
    return _unembed(params, cfg, last), nc


def decode_step(params: Params, cfg: ModelConfig, cache, token: jnp.ndarray):
    """One-token decode. token: [B, 1] int32. Returns (logits [B,1,V], cache)."""
    x = _embed(params, cfg, token)
    stack = {"dense": _dense_stack, "moe": _dense_stack, "vlm": _dense_stack,
             "ssm": _ssm_stack, "hybrid": _hybrid_stack}.get(cfg.family)
    if cfg.family == "encdec":
        h, nc, _ = _encdec_stacks(params, cfg, None, x, "decode", cache)
    else:
        h, nc, _ = stack(params, cfg, x, "decode", cache)
    nc["len"] = cache["len"] + 1
    # carry across non-updated fields (e.g. hybrids update everything already)
    for key in cache:
        if key not in nc:
            nc[key] = cache[key]
    return _unembed(params, cfg, h), nc


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            max_len: int):
    """Process a prompt, build the cache. Returns (last_logits [B,1,V], cache)."""
    if cfg.family == "encdec":
        enc_x = batch["enc_embeds"].astype(jnp.dtype(cfg.compute_dtype))
        dec_x = _embed(params, cfg, batch["tokens"])
        B, S = batch["tokens"].shape[:2]
        h, nc, _ = _encdec_stacks(params, cfg, enc_x, dec_x, "prefill", None)
        nc = _pad_kv_cache(nc, max_len, S)
        nc["len"] = jnp.asarray(S, jnp.int32)
        return _unembed(params, cfg, h[:, -1:]), nc

    x = _embed(params, cfg, batch["tokens"])
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    S = x.shape[1]
    if cfg.family in ("ssm", "hybrid"):
        # SSM prefill needs real state carry: run with a concrete zero cache
        cache = init_cache(cfg, x.shape[0], max_len)
        stack = _ssm_stack if cfg.family == "ssm" else _hybrid_stack
        h, nc, _ = stack(params, cfg, x, "prefill", cache)
        nc = _pad_kv_cache(nc, max_len, S)
        nc["len"] = jnp.asarray(S, jnp.int32)
        return _unembed(params, cfg, h[:, -1:]), nc

    stack = _dense_stack
    h, nc, _ = stack(params, cfg, x, "prefill", None)
    nc = _pad_kv_cache(nc, max_len, S)
    nc["len"] = jnp.asarray(S, jnp.int32)
    return _unembed(params, cfg, h[:, -1:]), nc


def _pad_kv_cache(nc, max_len: int, cur_len: int):
    """Pad prefill-produced [.., S, Hkv, hd] KV arrays out to max_len slots."""
    def pad(x):
        if x.ndim >= 4 and x.shape[-3] == cur_len and max_len > cur_len:
            pad_width = [(0, 0)] * x.ndim
            pad_width[-3] = (0, max_len - cur_len)
            return jnp.pad(x, pad_width)
        return x
    return {k: (pad(v) if k.endswith(("k", "v")) and "cross" not in k
                and not k.startswith(("ssm", "conv")) else v)
            for k, v in nc.items()}
