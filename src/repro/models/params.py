"""Parameter specification system.

Models declare their parameters as a pytree of ``P`` specs (shape + init
rule). The same spec tree produces either

* ``jax.ShapeDtypeStruct`` stand-ins (dry-run: no allocation), or
* initialized ``jnp`` arrays (smoke tests / real training),

so the abstract and concrete paths can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | small
    axis: int = -2        # fan-in axis for fan_in init
    scale: Optional[float] = None
    dtype: Optional[str] = None


def _init_leaf(spec: P, key, dtype) -> jnp.ndarray:
    dt = jnp.dtype(spec.dtype or dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)
    if spec.init == "small":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)
    # fan_in (default): std = scale / sqrt(fan_in)
    fan_axis = spec.axis if spec.axis >= 0 else len(shape) + spec.axis
    fan_in = shape[fan_axis] if shape else 1
    std = (spec.scale if spec.scale is not None else 1.0) / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)


def abstract_params(spec_tree, dtype: str):
    """Spec tree -> ShapeDtypeStruct tree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or dtype)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def init_params(spec_tree, rng, dtype: str):
    """Spec tree -> initialized array tree."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    keys = jax.random.split(rng, max(len(leaves), 1))
    out = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
