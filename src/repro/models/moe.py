"""Top-k Mixture-of-Experts FFN (the *layer* kind, not the paper's predictor).

Capacity-based grouped-GEMM formulation: tokens are scattered into a
[E, C, d] buffer (static shapes, GSPMD-shardable: E over the 'model' axis =
expert parallelism), each expert runs a dense SwiGLU, results are combined
back with the router weights. Overflowing tokens beyond capacity C are
dropped (standard capacity-factor semantics).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation


class MoEOutput(NamedTuple):
    y: jnp.ndarray          # [N, d]
    aux_loss: jnp.ndarray   # scalar load-balancing loss
    fraction_dropped: jnp.ndarray  # scalar, monitoring


def router_topk(logits: jnp.ndarray, k: int):
    """logits [N, E] -> (weights [N,k] fp32 normalized, idx [N,k] int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray, num_experts: int):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    N = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(idx.size, 1)          # fraction routed per expert
    p = jnp.mean(probs, axis=0)                    # mean router prob per expert
    return num_experts * jnp.sum(f * p)


def moe_ffn(
    x: jnp.ndarray,          # [N, d] flattened tokens
    w_router: jnp.ndarray,   # [d, E]
    w_gate: jnp.ndarray,     # [E, d, f]
    w_up: jnp.ndarray,       # [E, d, f]
    w_down: jnp.ndarray,     # [E, f, d]
    *,
    k: int,
    capacity_factor: float,
    act: str = "silu",
) -> MoEOutput:
    N, d = x.shape
    E = w_router.shape[1]
    C = max(int(N * k * capacity_factor / E), 1)
    # round capacity up to a multiple of 8 for layout friendliness
    C = -(-C // 8) * 8

    logits = jnp.einsum("nd,de->ne", x, w_router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = router_topk(logits, k)          # [N,k]
    aux = load_balance_loss(probs, idx, E)

    # ---- slot assignment: position of each (token, expert) pair within its
    # expert's capacity buffer, computed via a stable sort over expert ids.
    flat_e = idx.reshape(-1)                       # [N*k]
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)  # token per slot
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)       # group by expert
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts           # [E]
    pos_in_e = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    tok = flat_t[order]
    wgt = jnp.where(keep, flat_w[order], 0.0)
    slot = jnp.where(keep, pos_in_e, C - 1)        # clipped; weight zeroed

    # ---- dispatch: buf[e, c, :] = x[token assigned to (e, c)]
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype), mode="drop")

    # ---- expert computation (grouped GEMM on the MXU)
    g = activation(jnp.einsum("ecd,edf->ecf", buf, w_gate), act)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y_buf = jnp.einsum("ecf,efd->ecd", (g * u).astype(x.dtype), w_down)

    # ---- combine back
    y_slots = y_buf[sorted_e, slot]                # [N*k, d]
    y = jnp.zeros((N, d), jnp.float32).at[tok].add(
        y_slots.astype(jnp.float32) * wgt[:, None], mode="drop")
    return MoEOutput(y.astype(x.dtype), aux, dropped)
