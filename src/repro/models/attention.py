"""Grouped-query attention with the full option set used by the assigned archs.

Pure-XLA path (default; what the multi-pod dry-run lowers) plus a Pallas
flash-attention path (TPU target; interpret=True validated on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, *, causal: bool,
          window: int, kv_len: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Boolean [.., Q, K] mask of *allowed* positions.

    q_pos: [Q] or [B, Q]; k_pos: [K] or [B, K].
    """
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[..., None, :].astype(jnp.int32)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    if kv_len is not None:
        kv = jnp.asarray(kv_len, jnp.int32)
        kv = kv.reshape(kv.shape + (1, 1)) if kv.ndim else kv
        ok &= kp < kv
    return ok


def attention(
    q: jnp.ndarray,            # [B, Q, Hq, D]
    k: jnp.ndarray,            # [B, K, Hkv, D]
    v: jnp.ndarray,            # [B, K, Hkv, D]
    *,
    causal: bool = True,
    q_positions: Optional[jnp.ndarray] = None,  # [Q] or [B,Q]
    k_positions: Optional[jnp.ndarray] = None,  # [K] or [B,K]
    kv_len: Optional[jnp.ndarray] = None,       # scalar or [B]: valid cache len
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    use_pallas: bool = False,
    f32_logits: bool = True,
) -> jnp.ndarray:
    """Returns [B, Q, Hq, D]. Softmax in fp32 (or bf16 with explicit
    max-subtraction when ``f32_logits=False`` — the §Perf lever that
    halves S^2 softmax HBM traffic)."""
    B, Q, Hq, D = q.shape
    _, K, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale

    if use_pallas and Q > 1 and causal and kv_len is None and Q == K:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=True, window=window,
            attn_softcap=attn_softcap, scale=scale)

    if q_positions is None:
        q_positions = jnp.arange(Q)
    if k_positions is None:
        k_positions = jnp.arange(K)

    ldt = jnp.float32 if f32_logits else q.dtype
    qg = q.reshape(B, Q, Hkv, G, D)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=ldt
    ) * jnp.asarray(scale, ldt)
    if attn_softcap > 0.0:
        logits = softcap(logits, attn_softcap).astype(ldt)
    mask = _mask(q_positions, k_positions, causal=causal, window=window,
                 kv_len=kv_len)
    # mask broadcast: [.., Q, K] -> [B?, 1, 1, Q, K]
    while mask.ndim < logits.ndim:
        mask = mask[..., None, :, :] if mask.ndim >= 3 else mask[None]
    neg = jnp.asarray(-3e4 if ldt == jnp.bfloat16 else NEG_INF, ldt)
    logits = jnp.where(mask, logits, neg)
    if f32_logits:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    else:
        m = jnp.max(logits, axis=-1, keepdims=True)
        e = jnp.exp((logits - m).astype(jnp.float32)).astype(ldt)
        probs = e / jnp.maximum(jnp.sum(e.astype(jnp.float32), -1,
                                        keepdims=True), 1e-9).astype(ldt)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32 if f32_logits else v.dtype,
    )
    return out.reshape(B, Q, Hq, D).astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,            # [B, 1, Hq, D]
    k_pool: jnp.ndarray,       # [P, page, Hkv, D] shared page pool
    v_pool: jnp.ndarray,
    page_table: jnp.ndarray,   # [B, maxp] int32 (unused slots -> page 0)
    lens: jnp.ndarray,         # [B] int32: valid tokens incl. current
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    use_pallas: bool = False,
    f32_logits: bool = True,
) -> jnp.ndarray:
    """One-token attention against a page-table KV pool; each row has its
    own length (no shared position counter)."""
    if use_pallas:
        from repro.kernels.paged_attention import ops as pa_ops
        return pa_ops.paged_attention(
            q, k_pool, v_pool, page_table, lens,
            window=window, attn_softcap=attn_softcap, scale=scale)
    from repro.kernels.paged_attention.ref import gather_pages
    k = gather_pages(k_pool, page_table)       # [B, maxp*page, Hkv, D]
    v = gather_pages(v_pool, page_table)
    lens = jnp.asarray(lens, jnp.int32)
    return attention(
        q, k, v, causal=True,
        q_positions=(lens - 1)[:, None], k_positions=jnp.arange(k.shape[1]),
        kv_len=lens, window=window, attn_softcap=attn_softcap,
        scale=scale, use_pallas=False, f32_logits=f32_logits)


def decode_attention(
    q: jnp.ndarray,            # [B, 1, Hq, D]
    k_cache: jnp.ndarray,      # [B, S, Hkv, D]
    v_cache: jnp.ndarray,      # [B, S, Hkv, D]
    cache_len: jnp.ndarray,    # scalar int32: number of valid entries
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    use_pallas: bool = False,
    f32_logits: bool = True,
) -> jnp.ndarray:
    """One-token attention against a (possibly partially filled) KV cache."""
    if use_pallas:
        from repro.kernels.decode_attention import ops as da_ops
        return da_ops.decode_attention(
            q, k_cache, v_cache, cache_len,
            window=window, attn_softcap=attn_softcap, scale=scale)
    q_pos = jnp.asarray(cache_len, jnp.int32).reshape(1)  # query at index len
    return attention(
        q, k_cache, v_cache, causal=True,
        q_positions=q_pos, k_positions=jnp.arange(k_cache.shape[1]),
        kv_len=cache_len + 1, window=window,
        attn_softcap=attn_softcap, scale=scale, use_pallas=False,
        f32_logits=f32_logits)
