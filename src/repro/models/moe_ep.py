"""shard_map expert-parallel MoE (the optimized path).

The GSPMD lowering of the capacity-buffer MoE scatters data-sharded tokens
into an expert-sharded [E, C, d] buffer — XLA's fallback materializes the
FULL buffer per shard and all-reduces it (measured: 24.3 TB of all-reduce
per device per step on qwen3-moe train_4k). This module replaces the
dispatch with the canonical EP pattern:

  local top-k routing -> local capacity buffer [E, C_src, d]
  all_to_all over the EP ('data') axis  (the irreducible token exchange)
  local expert GEMMs with the LOCAL expert shard (TP over 'model' inside)
  reverse all_to_all -> local combine

Capacity semantics change slightly (per-source-shard capacity instead of
global), which is standard for EP implementations.

The mesh is provided via ``ep_mesh_context`` (the launcher/dry-run sets
it); without a context the dense-GSPMD path in ``repro.models.moe`` runs.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import activation
from repro.models.moe import MoEOutput, load_balance_loss, router_topk
from repro.utils.compat import shard_map

_ctx = threading.local()


@contextmanager
def ep_mesh_context(mesh, data_axis: str = "data",
                    model_axis: str = "model",
                    extra_batch_axes: Tuple[str, ...] = (),
                    tp_dispatch: bool = False):
    """Declare the mesh for shard_map MoE. ``extra_batch_axes`` are axes
    tokens are also sharded over but experts are replicated over ('pod').

    ``tp_dispatch``: also shard the routing/dispatch phase over the model
    axis (otherwise every TP rank repeats it on the full local token set —
    measured 9.4 GB/layer of capacity buffer on kimi-k2). Costs one
    all-gather of the received expert inputs before the GEMMs."""
    prev = getattr(_ctx, "info", None)
    _ctx.info = (mesh, data_axis, model_axis, tuple(extra_batch_axes),
                 tp_dispatch)
    try:
        yield
    finally:
        _ctx.info = prev


def current_ep_mesh():
    return getattr(_ctx, "info", None)


def _local_dispatch(x, weights, idx, E: int, C: int):
    """Group local tokens by expert into [E, C, d] (all local ops).

    Returns (buf, tok, slot, sorted_e, wgt, keep)."""
    N, d = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    tok = flat_t[order]
    wgt = jnp.where(keep, flat_w[order], 0.0)
    slot = jnp.where(keep, pos_in_e, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype), mode="drop")
    return buf, tok, slot, sorted_e, wgt, keep


def moe_ffn_ep(
    x: jnp.ndarray,          # [N, d] GLOBAL flattened tokens
    w_router: jnp.ndarray,   # [d, E] replicated
    w_gate: jnp.ndarray,     # [E, d, f] sharded P(data, None, model)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,     # [E, f, d] sharded P(data, model, None)
    *,
    k: int,
    capacity_factor: float,
    act: str = "silu",
) -> MoEOutput:
    info = current_ep_mesh()
    assert info is not None, "moe_ffn_ep requires ep_mesh_context"
    mesh, daxis, maxis, extra, tp_dispatch = info
    D = mesh.shape[daxis]
    E = w_router.shape[1]
    assert E % D == 0, (E, D)

    token_axes = (extra + (daxis,)) if extra else (daxis,)
    if tp_dispatch:
        token_axes = token_axes + (maxis,)

    def body(xl, wr, wg, wu, wd):
        # xl: [N_local, d]; wg: [E/D, d, f/M]; wd: [E/D, f/M, d]
        Nl, d = xl.shape
        C = max(int(Nl * k * capacity_factor / E), 1)
        C = -(-C // 8) * 8
        logits = jnp.einsum("nd,de->ne", xl, wr,
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = router_topk(logits, k)
        aux = load_balance_loss(probs, idx, E)
        aux = jax.lax.pmean(aux, token_axes)
        dropped = jnp.zeros((), jnp.float32)

        buf, tok, slot, sorted_e, wgt, keep = _local_dispatch(
            xl, weights, idx, E, C)
        # exchange: [E, C, d] -> [E/D, D*C, d] (expert-major blocks land
        # on their owning shard)
        recv = jax.lax.all_to_all(buf, daxis, split_axis=0, concat_axis=1,
                                  tiled=True)
        if tp_dispatch:
            # dispatch ran on model-sharded tokens; the expert GEMMs (TP
            # over f) need every token of their experts: gather over TP
            recv = jax.lax.all_gather(recv, maxis, axis=1, tiled=True)
        # local expert GEMMs (TP over 'model' on f)
        g = activation(jnp.einsum("ecd,edf->ecf", recv, wg), act)
        u = jnp.einsum("ecd,edf->ecf", recv, wu)
        y_part = jnp.einsum("ecf,efd->ecd", (g * u).astype(recv.dtype), wd)
        if tp_dispatch:
            # return each TP rank its own token block, summing partials:
            # reduce-scatter == psum + slice at a quarter of the bytes
            y_recv = jax.lax.psum_scatter(y_part, maxis, scatter_dimension=1,
                                          tiled=True)
        else:
            y_recv = jax.lax.psum(y_part, maxis)  # TP partial-sum over f
        # reverse exchange: [E/D, D*C, d] -> [E, C, d]
        y_buf = jax.lax.all_to_all(y_recv.astype(xl.dtype), daxis,
                                   split_axis=1, concat_axis=0, tiled=True)
        y_slots = y_buf[sorted_e, slot]
        y = jnp.zeros((Nl, d), jnp.float32).at[tok].add(
            y_slots.astype(jnp.float32) * wgt[:, None], mode="drop")
        return y.astype(xl.dtype), aux, dropped

    n_spec = P(token_axes if len(token_axes) > 1 else token_axes[0], None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(n_spec, P(None, None), P(daxis, None, maxis),
                  P(daxis, None, maxis), P(daxis, maxis, None)),
        out_specs=(n_spec, P(), P()),
        check_vma=False,
    )(x, w_router, w_gate, w_up, w_down)
    return MoEOutput(*out)
