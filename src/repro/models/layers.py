"""Core layers: norms, rotary embeddings, activations, MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             use_pallas: bool = False) -> jnp.ndarray:
    """RMSNorm in fp32, cast back to x.dtype. (1+w) convention NOT used."""
    if use_pallas:
        from repro.kernels.rmsnorm import ops as rms_ops
        return rms_ops.rmsnorm(x, weight, eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, fp32, shape [head_dim // 2]."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]).

    x: [B, S, H, D]; positions: [B, S] (or [S]) int32.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [d/2]
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * inv  # [B, S, d/2]
    cos = jnp.cos(angles)[..., None, :]  # [B, S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> np.ndarray:
    """Whisper-encoder style sinusoidal positional embedding [S, D] (fp32)."""
    half = d_model // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    pos = np.arange(seq_len)[:, None] * freqs[None, :]
    return np.concatenate([np.sin(pos), np.cos(pos)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp(x: jnp.ndarray, wi_gate: jnp.ndarray, wi_up: jnp.ndarray,
        wo: jnp.ndarray, act: str) -> jnp.ndarray:
    """x: [..., d]; wi_*: [d, f]; wo: [f, d]."""
    g = activation(jnp.einsum("...d,df->...f", x, wi_gate), act)
    u = jnp.einsum("...d,df->...f", x, wi_up)
    return jnp.einsum("...f,fd->...d", g * u, wo)
