"""Mamba2 (state-space duality) blocks: chunked train/prefill + O(1) decode.

Reference chunked SSD in pure jnp (this is what the dry-run lowers); the
Pallas kernel in ``repro.kernels.ssd_scan`` implements the intra-chunk part
for TPU and is validated against this code.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


class SSMState(NamedTuple):
    ssm: jnp.ndarray    # [B, H, P, N]
    conv: jnp.ndarray   # [B, W-1, conv_channels]


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: [B,S,ch], w: [W,ch], b: [ch]."""
    W = w.shape[0]
    out = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(cache: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step of the causal conv. cache: [B, W-1, ch], x_t: [B, ch]."""
    window = jnp.concatenate([cache, x_t[:, None]], axis=1)  # [B, W, ch]
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    new_cache = window[:, 1:]
    return new_cache, y.astype(x_t.dtype)


def ssd_chunked(
    xb: jnp.ndarray,      # [B, S, H, P] dt-weighted inputs (x * dt)
    a: jnp.ndarray,       # [B, S, H] log-decay per step (dt * A, A < 0)
    B_mat: jnp.ndarray,   # [B, S, G, N]
    C_mat: jnp.ndarray,   # [B, S, G, N]
    *,
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    if use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        return ssd_ops.ssd_scan(xb, a, B_mat, C_mat, chunk=chunk,
                                initial_state=initial_state)
    B, S, H, P = xb.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    assert H % G == 0
    pad = (-S) % chunk
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc, Q = Sp // chunk, chunk
    xb_c = xb.reshape(B, nc, Q, H, P)
    a_c = a.reshape(B, nc, Q, H).astype(jnp.float32)
    B_c = B_mat.reshape(B, nc, Q, G, N)
    C_c = C_mat.reshape(B, nc, Q, G, N)

    cum = jnp.cumsum(a_c, axis=2)                       # [B,nc,Q,H]
    # broadcast groups to heads for the CB inner products
    rep = H // G
    Bh = jnp.repeat(B_c, rep, axis=3)                   # [B,nc,Q,H,N]
    Ch = jnp.repeat(C_c, rep, axis=3)

    # ---- intra-chunk (the "attention-like" quadratic-in-Q term)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,i,j,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch.astype(jnp.float32),
                    Bh.astype(jnp.float32))
    M = cb * L                                          # [B,nc,i,j,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xb_c.astype(jnp.float32))

    # ---- per-chunk terminal states
    a_last = cum[:, :, -1, :]                           # [B,nc,H]
    decay_out = jnp.exp(a_last[:, :, None, :] - cum)    # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                        decay_out, Bh.astype(jnp.float32),
                        xb_c.astype(jnp.float32))       # [B,nc,H,P,N]

    # ---- inter-chunk recurrence
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s_prev, inp):
        st, al = inp                                    # [B,H,P,N], [B,H]
        s_next = s_prev * jnp.exp(al)[:, :, None, None] + st
        return s_next, s_prev

    states_t = jnp.moveaxis(states, 1, 0)               # [nc,B,H,P,N]
    a_last_t = jnp.moveaxis(a_last, 1, 0)                # [nc,B,H]
    final, prev_states = jax.lax.scan(step, s0, (states_t, a_last_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         Ch.astype(jnp.float32) * jnp.exp(cum)[..., None],
                         prev_states)
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y.astype(xb.dtype), final


def ssd_decode_step(
    state: jnp.ndarray,   # [B, H, P, N] fp32
    x: jnp.ndarray,       # [B, H, P]
    dt: jnp.ndarray,      # [B, H] (post-softplus)
    A: jnp.ndarray,       # [H] (negative)
    B_vec: jnp.ndarray,   # [B, G, N]
    C_vec: jnp.ndarray,   # [B, G, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent update. Returns (new_state, y [B,H,P])."""
    B, H, P, N = state.shape
    G = B_vec.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_vec, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    Ch = jnp.repeat(C_vec, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))              # [B,H]
    xdt = x.astype(jnp.float32) * dtf[..., None]              # [B,H,P]
    new_state = (state * decay[:, :, None, None]
                 + xdt[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return new_state, y


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg) -> dict:
    di = cfg.d_inner
    H = cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * G * N
    return dict(di=di, H=H, G=G, N=N, P=cfg.ssm_head_dim, conv_ch=conv_ch,
                in_dim=2 * di + 2 * G * N + H)


def mamba2_block(p: dict, cfg, x: jnp.ndarray,
                 state: Optional[SSMState] = None,
                 *, decode: bool = False):
    """Mamba2 block. x: [B,S,d] (S=1 when decode=True).

    Returns (y [B,S,d], new_state | None).
    """
    d = mamba2_dims(cfg)
    di, H, G, N, P = d["di"], d["H"], d["G"], d["N"], d["P"]
    Bsz, S, _ = x.shape

    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xBC_raw, dt_raw = jnp.split(proj, [di, 2 * di + 2 * G * N], axis=-1)

    if decode:
        assert state is not None and S == 1
        new_conv, xBC_t = conv_step(state.conv, xBC_raw[:, 0], p["conv_w"],
                                    p["conv_b"])
        xBC = jax.nn.silu(xBC_t)[:, None]            # [B,1,conv_ch]
    else:
        xBC = jax.nn.silu(causal_conv1d(xBC_raw, p["conv_w"], p["conv_b"]))

    x_ssm, B_mat, C_mat = jnp.split(xBC, [di, di + G * N], axis=-1)
    x_ssm = x_ssm.reshape(Bsz, S, H, P)
    B_mat = B_mat.reshape(Bsz, S, G, N)
    C_mat = C_mat.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [H]

    if decode:
        new_ssm, y = ssd_decode_step(
            state.ssm, x_ssm[:, 0], dt[:, 0], A, B_mat[:, 0], C_mat[:, 0])
        y = y[:, None]                                         # [B,1,H,P]
        new_state = SSMState(ssm=new_ssm, conv=new_conv)
    else:
        xb = x_ssm * dt[..., None].astype(x_ssm.dtype)
        a = dt * A                                             # [B,S,H]
        init = state.ssm if state is not None else None
        y, final = ssd_chunked(xb, a, B_mat, C_mat, chunk=cfg.ssm_chunk,
                               initial_state=init,
                               use_pallas=cfg.use_pallas)
        if state is not None:
            new_state = SSMState(ssm=final,
                                 conv=_conv_tail(xBC_raw, cfg.conv_width))
        else:
            new_state = None

    y = y + x_ssm.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"]), new_state


def _conv_tail(xBC_raw, width: int) -> jnp.ndarray:
    """Last (width-1) *raw* (pre-conv, pre-silu) inputs — exactly what
    ``conv_step`` expects as its rolling cache during decode."""
    return xBC_raw[:, -(width - 1):]


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    d = mamba2_dims(cfg)
    return SSMState(
        ssm=jnp.zeros((batch, d["H"], cfg.ssm_head_dim, d["N"]), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, d["conv_ch"]), dtype),
    )
