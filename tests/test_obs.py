"""Observability subsystem (repro/obs/): tracing, telemetry, reports.

* ``Tracer`` — trace_event JSON schema validity, lazy track metadata,
  B/E nesting enforcement, seeded byte-determinism;
* ``validate_chrome_trace`` — rejects every malformed-shape class the
  benchmarks' schema gate guards against;
* zero-cost default — a traced engine / simulator run produces the
  SAME summary dict as the untraced run, bit for bit (the acceptance
  bar that lets tracing ride every run without a goldens fork);
* ``Telemetry`` on the runtime — per-kind event counters, stale drops,
  node-utilization timelines;
* structured admission rejects + decision provenance;
* per-link utilization ledgers (``Topology.link_stats``) and the
  rejected-join axis counters in ``ServingMetrics``;
* ``repro.obs.report.summarize`` reproducing a traced run's goodput
  and migration count from the trace alone.
"""
import json

import numpy as np
import pytest

from repro.core import (MoEPredictor, SimConfig, Simulator,
                        spark_sim_suite, training_apps)
from repro.core.simulator import OursPolicy
from repro.obs import NullTracer, Telemetry, Tracer, validate_chrome_trace
from repro.obs.report import summarize
from repro.sched import ClusterRuntime, ClusterState
from repro.sched.admission import AdmissionController
from repro.sched.resources import DemandModel, ResourceVector
from repro.sched.topology import Topology, get_topology
from repro.serve import Engine, Request, ServingDemand, SimBackend
from repro.core.experts import MemoryFunction


def make_requests(n, seed=0, rate=20.0, prompt=(8, 32), new=(8, 40)):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i,
                    prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i]))
            for i in range(n)]


def _reference_engine(mode="continuous", tracer=None, **kw):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           host_ram_per_req_gb=0.01)
    full = 32 + 40
    budget = ResourceVector(hbm=0.5 + 2e-4 * full * 3.0,
                            host_ram=0.01 * 6.0)
    if kw.get("replicas", 1) == 1:
        kw.setdefault("backend", SimBackend())
    return Engine(make_requests(24, seed=0), demand, budget,
                  mode=mode, placement="fcfs", max_batch=16,
                  tracer=tracer, **kw)


def _topo_engine(migrate=True, tracer=None):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 56 * 2.5, net=1.0)
    topo = get_topology("two-rack", nodes=4, gbps=10.0,
                        uplink_gbps=(0.2, 4.0))
    reqs = [Request(rid=r.rid, prompt_len=r.prompt_len,
                    max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                    ttft_deadline=0.5, tpot_deadline=0.05)
            for r in make_requests(24, seed=9, rate=120.0,
                                   prompt=(12, 25), new=(8, 33))]
    return Engine(reqs, demand, budget, mode="continuous",
                  placement="fcfs", max_batch=32, replicas=4,
                  router="topo-aware",
                  backends=[SimBackend(t_prefill_per_token=2e-3)
                            for _ in range(4)],
                  topology=topo, migrate=migrate,
                  ingress_gb_per_token=2e-3, tracer=tracer)


# --- Tracer -----------------------------------------------------------------

def test_tracer_emits_schema_valid_trace_with_track_metadata():
    tr = Tracer()
    tr.complete("step", 0.0, 0.5, process="replica0", thread="steps",
                cat="serving", args={"batch": 3})
    tr.instant("join", 0.1, process="replica0", thread="events")
    tr.counter("node0:util", 0.5, {"hbm": 0.7, "host_ram": 0.2},
               process="replica0")
    tr.async_begin("req", 0.0, 7, cat="request", process="requests",
                   thread="lifecycle")
    tr.async_end("req", 0.9, 7, cat="request", process="requests",
                 thread="lifecycle", args={"tokens": 12})
    tr.begin("outer", 1.0)
    tr.begin("inner", 1.1)
    tr.end(1.2, name="inner")
    tr.end(1.3)
    payload = tr.chrome()
    validate_chrome_trace(payload)          # does not raise
    # lazy track registry: one process_name M event per process, one
    # thread_name per (process, thread), stable first-use pids
    meta = [e for e in payload["traceEvents"] if e["ph"] == "M"]
    procs = {e["args"]["name"]: e["pid"] for e in meta
             if e["name"] == "process_name"}
    assert set(procs) == {"replica0", "requests", "runtime"}
    assert procs["replica0"] == 1           # first-use order
    # virtual seconds became microseconds
    step = next(e for e in payload["traceEvents"] if e["name"] == "step")
    assert step["ts"] == 0.0 and step["dur"] == pytest.approx(5e5)
    assert len(tr) == len(payload["traceEvents"])


def test_tracer_end_enforces_nesting():
    tr = Tracer()
    with pytest.raises(ValueError, match="no open span"):
        tr.end(1.0)
    tr.begin("a", 0.0)
    with pytest.raises(ValueError, match="does not match"):
        tr.end(0.5, name="b")
    tr.end(0.6, name="a")                   # the mismatch didn't pop
    validate_chrome_trace(tr.chrome())


@pytest.mark.parametrize("bad", [
    "not a dict",
    {"no": "traceEvents"},
    {"traceEvents": "not a list"},
    {"traceEvents": [{"ph": "Z", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0}]},
    {"traceEvents": [{"ph": "i", "name": "", "pid": 1, "tid": 1,
                      "ts": 0}]},
    {"traceEvents": [{"ph": "i", "name": "x", "pid": "1", "tid": 1,
                      "ts": 0}]},
    {"traceEvents": [{"ph": "i", "name": "x", "pid": 1, "tid": 1,
                      "ts": -1.0}]},
    {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0}]},                      # missing dur
    {"traceEvents": [{"ph": "b", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0}]},                      # async sans id/cat
    {"traceEvents": [{"ph": "C", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0, "args": {"v": "high"}}]},
    {"traceEvents": [{"ph": "E", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0}]},                      # E with no B
    {"traceEvents": [{"ph": "B", "name": "x", "pid": 1, "tid": 1,
                      "ts": 0}]},                      # unclosed B
])
def test_validator_rejects_malformed_traces(bad):
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert not nt.enabled
    nt.complete("x", 0, 1)
    nt.begin("x", 0)
    nt.end(1)
    nt.instant("x", 0)
    nt.counter("x", 0, {"v": 1})
    nt.async_begin("x", 0, 1, cat="c")
    nt.async_end("x", 1, 1, cat="c")
    assert len(nt) == 0 and nt.chrome()["traceEvents"] == []


# --- zero-cost default: traced == untraced, bit for bit ---------------------

def test_traced_engine_summary_bit_identical_to_untraced():
    untraced = _reference_engine().run()
    tracer = Tracer()
    traced = _reference_engine(tracer=tracer).run()
    assert traced == untraced               # dict ==, every key exact
    assert len(tracer) > 0
    validate_chrome_trace(tracer.chrome())


def test_traced_trace_is_seed_deterministic():
    """Two identical seeded runs emit byte-identical traces — no
    wall-clock value ever enters a trace."""
    blobs = []
    for _ in range(2):
        tr = Tracer()
        _reference_engine(tracer=tr).run()
        blobs.append(json.dumps(tr.chrome(), sort_keys=True))
    assert blobs[0] == blobs[1]


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def test_traced_simulator_bit_identical_and_spans_balanced(suite):
    apps, moe = suite
    jobs = [(apps[i], 30.0) for i in (0, 5, 11, 17)]
    untraced = Simulator(jobs, OursPolicy(moe), SimConfig(n_hosts=6),
                         seed=3).run()
    tracer = Tracer()
    traced = Simulator(jobs, OursPolicy(moe), SimConfig(n_hosts=6),
                       seed=3, tracer=tracer).run()
    assert traced == untraced
    validate_chrome_trace(tracer.chrome())
    evs = tracer.events
    # every job/exec async span that opened also closed
    for cat in ("job", "exec"):
        opened = {e["id"] for e in evs
                  if e["ph"] == "b" and e.get("cat") == cat}
        closed = {e["id"] for e in evs
                  if e["ph"] == "e" and e.get("cat") == cat}
        assert opened and opened == closed


# --- Telemetry on the runtime -----------------------------------------------

def test_runtime_counts_events_and_stale_drops():
    rt = ClusterRuntime(ClusterState.homogeneous(
        1, ResourceVector(hbm=1.0)))
    rt.on("ev", lambda t, p: None)
    rt.on("stale", lambda t, p: False)
    for t in (1.0, 2.0, 3.0):
        rt.push(t, "ev", None)
    rt.push(2.5, "stale", None)
    rt.run()
    tm = rt.telemetry
    assert tm.counter("events.ev") == 3
    assert tm.counter("events.stale.stale") == 1
    assert tm.counter("events.dispatched") == 4
    assert tm.gauges["wall_s"] >= 0.0       # wall gauges exist but are
    #   never copied into summaries (the bit-identical check above
    #   would break on machine speed if they were)
    s = tm.summary()
    assert s["counters"]["events.ev"] == 3


def test_engine_samples_node_utilization_timelines():
    tracer = Tracer()
    eng = _reference_engine(tracer=tracer)
    eng.run()
    lines = eng.telemetry.timelines
    assert any(k.startswith("node0.util.") for k in lines)
    for pts in lines.values():
        ts = [t for t, _ in pts]
        assert ts == sorted(ts)             # virtual-time ordered
        # forced over-budget progress can push booked/capacity past 1
        assert all(v >= 0.0 and np.isfinite(v) for _, v in pts)


def test_telemetry_summary_reduces_timelines():
    tm = Telemetry()
    tm.sample("x", 0.0, 1.0)
    tm.sample("x", 1.0, 3.0)
    s = tm.summary()["timelines"]["x"]
    assert s == {"n": 2, "mean": 2.0, "max": 3.0, "last": 3.0}


# --- structured admission rejects + provenance ------------------------------

def test_admit_reject_reason_names_axis_and_deficit():
    ctrl = AdmissionController()
    dm = DemandModel({"hbm": MemoryFunction("affine", 0.0, 5.0)})
    dec = ctrl.admit(dm, ResourceVector(hbm=2.0), floor=1.0)
    assert dec.units == 0.0
    rej = dec.info["reject"]
    assert rej["axis"] == "hbm"
    assert rej["floor"] == 1.0
    # the smallest useful grant (1 unit = 5 GB) overshoots by 3 GB
    assert rej["deficit"]["hbm"] == pytest.approx(3.0)


def test_admit_target_records_provenance(suite):
    apps, moe = suite
    from repro.sched.estimator import JobTarget, get_estimator
    ctrl = AdmissionController(
        estimator=get_estimator("moe", predictor=moe))
    free = ResourceVector(host_ram=40.0)
    dec = ctrl.admit_target(JobTarget(apps[0], 100.0), free, cap=64.0,
                            rng=np.random.default_rng(0))
    prov = dec.info["provenance"]
    assert prov["free"] == dict(free.items())
    assert prov["binding_axis"] == dec.binding_axis
    assert set(prov["confidence"]) >= {"host_ram"}
    assert isinstance(prov["conservative"], bool)
    # the shaded budget the inverse actually saw, not the raw free
    assert prov["budget"]["host_ram"] <= prov["free"]["host_ram"]


def test_serving_metrics_count_rejects_by_axis():
    out = _reference_engine().run()
    assert out["rejected_joins"] == sum(out["rejects_by_axis"].values())
    if out["rejected_joins"]:
        assert all(isinstance(k, str) and v > 0
                   for k, v in out["rejects_by_axis"].items())


# --- per-link utilization ledgers -------------------------------------------

def test_link_stats_conserve_bytes_and_busy_time():
    topo = Topology("pair")
    topo.add_node("a")
    topo.add_node("b")
    topo.add_link("a", "b", 1.0)
    rt = ClusterRuntime(ClusterState.homogeneous(
        1, ResourceVector(hbm=1.0)))
    topo.attach(rt)
    topo.transmit("a", "b", 1.0, now=0.0)
    topo.transmit("a", "b", 1.0, now=0.5)   # overlaps: peak 2 flows
    rt.run()
    stats = topo.link_stats(elapsed=2.0)
    (st,) = stats.values()
    assert st["bytes_gb"] == pytest.approx(2.0)
    assert st["busy_s"] == pytest.approx(2.0)   # busy 0.0 -> 2.0
    assert st["busy_frac"] == pytest.approx(1.0)
    assert st["peak_flows"] == 2


def test_topology_engine_reports_link_stats():
    out = _topo_engine(migrate=True).run()
    assert out["migrations"] > 0
    links = out["links"]
    assert links and all(
        set(st) >= {"busy_s", "busy_frac", "bytes_gb", "peak_flows"}
        for st in links.values())
    # KV actually moved over at least one link
    assert sum(st["bytes_gb"] for st in links.values()) > 0.0


# --- trace -> report round trip ---------------------------------------------

def test_report_reproduces_goodput_and_migrations_from_trace():
    untraced = _topo_engine(migrate=True).run()
    tracer = Tracer()
    traced = _topo_engine(migrate=True, tracer=tracer).run()
    assert traced == untraced               # tracing changed nothing
    payload = tracer.chrome()
    validate_chrome_trace(payload)
    rep = summarize(payload)
    # the acceptance bar: the trace alone reproduces the run's metrics
    assert rep["goodput_tok_s"] == untraced["goodput_tok_s"]
    assert rep["migrations"] == untraced["migrations"]
    assert rep["completed"] == untraced["completed"]
    assert rep["elapsed_s"] == untraced["elapsed_s"]
    # breakdown + occupancy are populated and sane
    assert rep["breakdown"]["decode_s"] > 0.0
    assert rep["per_node"] and all(
        0.0 <= st["occupancy"] <= 1.0 for st in rep["per_node"].values())
    assert rep["events_by_kind"].get("step", 0) > 0


def test_report_format_is_printable():
    tracer = Tracer()
    out = _reference_engine(tracer=tracer).run()
    from repro.obs.report import format_report
    rep = summarize(tracer.chrome())
    txt = format_report(rep, title="ref")
    assert "goodput" in txt and "breakdown" in txt
    assert rep["goodput_tok_s"] == out["goodput_tok_s"]
