"""The multi-tenant fairness subsystem (repro/sched/tenancy.py):

* Tenant / TenantRegistry — validation, round-trip serialization,
  credit scoring from live SLO / latency / reject signals;
* credit monotonicity — a tenant's weighted dominant share never
  DECREASES as its credit degrades (worse behavior can only push it
  later in the admission order);
* pack_step — the per-node knapsack never exceeds headroom on any
  axis, never admits less than the FIFO prefix would have, splits a
  saturated node by weight (sharing incentive), and is deterministic;
* WeightedDRFRouter — with no registry bound it degrades exactly to
  least-loaded; with one bound it spreads a tenant across replicas;
* the engine seam — ``tenants=None`` leaves the schedule bit-identical
  (tenant labels on requests are inert without a registry), tenanted
  runs are seeded-deterministic, and per-step reject origins reconcile
  with the summary's ``rejects_by_origin``.
"""
import numpy as np
import pytest

from repro.sched import (Tenant, TenantRegistry, get_router,
                         pack_step, request_origin)
from repro.sched.resources import ResourceVector
from repro.sched.tenancy import Skip  # noqa: F401  (structured reason)
from repro.serve import Engine, Request, ServingDemand


def make_requests(n, seed=0, rate=20.0, tenant=None, ttft=0.25):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i, prompt_len=int(rng.integers(8, 24)),
                    max_new_tokens=int(rng.integers(8, 32)),
                    arrival=float(t[i]), ttft_deadline=ttft,
                    tpot_deadline=0.05, tenant=tenant)
            for i in range(n)]


def tagged(rids, tenant):
    """Minimal join candidates for pack_step: fresh Requests carrying
    a tenant, rid order == queue order."""
    return [Request(rid=r, prompt_len=8, max_new_tokens=8,
                    arrival=0.0, tenant=tenant) for r in rids]


# --- Tenant / TenantRegistry -------------------------------------------------

def test_tenant_validation():
    with pytest.raises(ValueError):
        Tenant("a", weight=0.0)
    with pytest.raises(ValueError):
        Tenant("a", weight=-1.0)
    with pytest.raises(ValueError):
        Tenant("a", error_budget=1.5)
    with pytest.raises(ValueError):
        TenantRegistry(window=0)
    with pytest.raises(ValueError):
        TenantRegistry(min_credit=0.0)
    reg = TenantRegistry([Tenant("a")])
    with pytest.raises(ValueError):
        reg.add(Tenant("a"))        # duplicate


def test_registry_round_trip():
    reg = TenantRegistry(
        [Tenant("gold", weight=2.0, error_budget=0.05),
         Tenant("bronze", weight=0.5)], window=32, min_credit=0.1)
    back = TenantRegistry.from_dict(reg.to_dict())
    assert back.window == 32 and back.min_credit == 0.1
    assert back.names() == ("gold", "bronze")
    for name in reg.names():
        assert back.get(name) == reg.get(name)
    # live state does not persist: fresh registry has full credit
    reg.observe_slo("gold", False)
    back2 = TenantRegistry.from_dict(reg.to_dict())
    assert back2.credit("gold") == 1.0


def test_credit_signals_and_floor():
    reg = TenantRegistry([Tenant("a", error_budget=0.1)], window=10)
    assert reg.credit("a") == 1.0          # no history = full credit
    for _ in range(10):
        reg.observe_slo("a", True)
    assert reg.credit("a") == 1.0          # perfect attainment
    for _ in range(10):
        reg.observe_slo("a", False)        # window now all misses
    assert reg.credit("a") == reg.min_credit
    # latency: sustained p99 at 2x target halves the latency score
    reg2 = TenantRegistry([Tenant("b")], window=10)
    for _ in range(10):
        reg2.observe_latency_ratio("b", 2.0)
    assert reg2.credit("b") == pytest.approx(0.5)
    # prediction: only origin == "new" rejects degrade credit
    reg3 = TenantRegistry([Tenant("c")], window=4)
    for _ in range(8):
        reg3.observe_reject("c", origin="requeue")
    assert reg3.credit("c") == 1.0
    reg3.observe_reject("c", origin="new")
    assert reg3.credit("c") < 1.0
    assert reg3.rejects["c"] == {"requeue": 8, "new": 1}


def test_credit_monotonicity_in_weighted_share():
    """The pinned invariant: as a tenant's credit degrades, its
    weighted dominant share (for the SAME usage) never decreases —
    lower credit can only push it later in the admission order."""
    reg = TenantRegistry([Tenant("a")], window=16)
    cap = ResourceVector(hbm=10.0, host_ram=4.0)
    vec = ResourceVector(hbm=2.0, host_ram=1.0)
    shares = [reg.weighted_share_of("a", vec, cap)]
    for _ in range(16):
        reg.observe_slo("a", False)
        shares.append(reg.weighted_share_of("a", vec, cap))
    assert all(b >= a - 1e-12 for a, b in zip(shares, shares[1:]))
    assert shares[-1] > shares[0]


def test_dominant_share_ignores_uncapacitated_axes():
    cap = ResourceVector(hbm=10.0)
    vec = ResourceVector(hbm=1.0, net=99.0)   # net has no capacity
    assert TenantRegistry.dominant_share(vec, cap) == pytest.approx(0.1)


def test_usage_ledger_reconcile():
    reg = TenantRegistry([Tenant("a"), Tenant("b")])
    reg.add_usage("a", 0, ResourceVector(hbm=1.0))
    reg.add_usage("a", 1, ResourceVector(hbm=2.0))
    reg.add_usage("b", 0, ResourceVector(hbm=4.0))
    assert reg.usage("a").get("hbm") == pytest.approx(3.0)
    reg.set_node_usage(0, {"b": ResourceVector(hbm=0.5)})
    assert reg.usage("a").get("hbm") == pytest.approx(2.0)  # node 0 gone
    assert reg.usage("b", 0).get("hbm") == pytest.approx(0.5)


def test_request_origin():
    r = Request(rid=0, prompt_len=4, max_new_tokens=4, arrival=0.0)
    assert request_origin(r) == "new"
    r.admissions = 1
    assert request_origin(r) == "requeue"
    r2 = Request(rid=1, prompt_len=4, max_new_tokens=4, arrival=0.0)
    r2.preemptions = 2
    assert request_origin(r2) == "requeue"


# --- pack_step ---------------------------------------------------------------

def test_pack_never_over_budget_and_beats_fifo_prefix():
    reg = TenantRegistry([Tenant("a"), Tenant("b")])
    rng = np.random.default_rng(3)
    cands = []
    sizes = {}
    for i in range(16):
        r = tagged([i], "a" if i % 2 else "b")[0]
        cands.append(r)
        sizes[i] = float(rng.uniform(0.5, 3.0))
    headroom = ResourceVector(hbm=6.0)
    cap = ResourceVector(hbm=6.0)
    vec_of = lambda r: ResourceVector(hbm=sizes[r.rid])  # noqa: E731
    admitted, skips = pack_step(reg, cands, headroom, cap, {},
                                vec_of, slots=len(cands))
    used = ResourceVector()
    for r in admitted:
        used = used + vec_of(r)
    assert used.fits(headroom)
    # the FIFO prefix: admit in order until the first misfit
    fifo, acc = 0, 0.0
    for r in cands:
        if acc + sizes[r.rid] > 6.0:
            break
        acc += sizes[r.rid]
        fifo += 1
    assert len(admitted) >= fifo
    # every skip names the binding axis and a positive deficit
    for s in skips:
        assert s.axis == "hbm" and s.deficit > 0.0
        assert s.origin == "new"
    # determinism: identical call, identical plan
    admitted2, skips2 = pack_step(reg, cands, headroom, cap, {},
                                  vec_of, slots=len(cands))
    assert [r.rid for r in admitted2] == [r.rid for r in admitted]
    assert skips2 == skips


def test_pack_sharing_incentive_and_weights():
    """Saturated node, equal weights: the split is even (each tenant is
    no worse off than under a static 1/n partition).  Doubling one
    tenant's weight doubles its slice."""
    vec_of = lambda r: ResourceVector(hbm=1.0)  # noqa: E731
    headroom = ResourceVector(hbm=8.0)
    cap = ResourceVector(hbm=8.0)
    cands = tagged(range(0, 8), "a") + tagged(range(8, 16), "b")
    reg = TenantRegistry([Tenant("a"), Tenant("b")])
    admitted, _ = pack_step(reg, cands, headroom, cap, {}, vec_of,
                            slots=16)
    by = {"a": 0, "b": 0}
    for r in admitted:
        by[r.tenant] += 1
    assert by == {"a": 4, "b": 4}
    reg2 = TenantRegistry([Tenant("a", weight=2.0), Tenant("b")])
    admitted2, _ = pack_step(reg2, cands, headroom, cap, {}, vec_of,
                             slots=16)
    by2 = {"a": 0, "b": 0}
    for r in admitted2:
        by2[r.tenant] += 1
    assert by2["a"] > by2["b"]
    assert by2["a"] + by2["b"] == 8


def test_pack_skip_does_not_block_smaller_later():
    """A tenant's oversized head-of-line request is skipped, not a
    roadblock: later smaller candidates (any tenant) still land."""
    reg = TenantRegistry([Tenant("a"), Tenant("b")])
    big, small_a, small_b = tagged([0], "a") + tagged([1], "a") \
        + tagged([2], "b")
    sizes = {0: 10.0, 1: 1.0, 2: 1.0}
    vec_of = lambda r: ResourceVector(hbm=sizes[r.rid])  # noqa: E731
    admitted, skips = pack_step(
        reg, [big, small_a, small_b], ResourceVector(hbm=2.0),
        ResourceVector(hbm=2.0), {}, vec_of, slots=3)
    assert sorted(r.rid for r in admitted) == [1, 2]
    assert [s.rid for s in skips] == [0]


def test_pack_slot_cap_produces_no_skips():
    """Candidates beyond the batch-slot cap were not reached, not
    rejected — they must not inflate per-tenant reject counters."""
    reg = TenantRegistry([Tenant("a")])
    vec_of = lambda r: ResourceVector(hbm=1.0)  # noqa: E731
    admitted, skips = pack_step(
        reg, tagged(range(6), "a"), ResourceVector(hbm=100.0),
        ResourceVector(hbm=100.0), {}, vec_of, slots=2)
    assert len(admitted) == 2 and skips == []


# --- WeightedDRFRouter -------------------------------------------------------

def _nodes(n, seed):
    from repro.sched import Node
    rng = np.random.default_rng(seed)
    nodes = []
    for i in range(n):
        node = Node(nid=i, capacity=ResourceVector(hbm=8.0, net=1.0))
        node.book(f"bg{i}", ResourceVector(
            hbm=float(rng.uniform(0.0, 6.0)),
            net=float(rng.uniform(0.0, 0.8))))
        nodes.append(node)
    return nodes


def test_drf_router_without_registry_is_least_loaded():
    drf, ll = get_router("drf"), get_router("least-loaded")
    demand = ResourceVector(hbm=1.0, net=0.1)
    for seed in range(8):
        nodes = _nodes(4, seed)
        assert drf.route(demand, nodes).nid == ll.route(demand, nodes).nid


def test_drf_router_spreads_a_tenant():
    """With a registry bound, the router sends a tenant's next request
    to the node where that tenant's post-placement share is lowest —
    its existing concentration, not the global load, decides."""
    from repro.sched import Node
    reg = TenantRegistry([Tenant("a")])
    reg.add_usage("a", 0, ResourceVector(hbm=4.0))
    nodes = [Node(nid=i, capacity=ResourceVector(hbm=8.0))
             for i in range(2)]
    # node 0 is globally EMPTIER, but tenant a already sits there
    nodes[1].book("bg", ResourceVector(hbm=2.0))
    router = get_router("drf")
    router.tenancy, router.tenant = reg, "a"
    try:
        assert router.route(ResourceVector(hbm=1.0), nodes).nid == 1
    finally:
        router.tenancy = router.tenant = None


# --- the engine seam ---------------------------------------------------------

def _engine(requests, tenants=None, router="least-loaded", replicas=2):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           host_ram_per_req_gb=0.01)
    budget = ResourceVector(hbm=0.5 + 2e-4 * 56 * 4.0, host_ram=0.08)
    return Engine(requests, demand, budget, mode="continuous",
                  placement="fcfs", max_batch=8, replicas=replicas,
                  router=router, tenants=tenants)


def _mixed(seed=0):
    reqs = make_requests(24, seed=seed, rate=40.0)
    for i, r in enumerate(reqs):
        r.tenant = ("a", "b", "c")[i % 3]
    return reqs


def test_untenanted_labels_are_inert():
    """tenants=None: tenant labels on requests must not change the
    schedule — same summary as the unlabeled run, apart from the
    (purely observational) per-tenant breakdown."""
    plain = _engine(make_requests(24, rate=40.0)).run()
    labeled = _engine(_mixed()).run()
    assert labeled["tenants"] != {}      # observed
    for k, v in plain.items():
        if k != "tenants":
            assert labeled[k] == v, k
    assert plain["rejects_by_origin"] == labeled["rejects_by_origin"]


def test_tenanted_run_deterministic_and_reconciled():
    def run():
        reg = TenantRegistry([Tenant("a", weight=2.0), Tenant("b"),
                              Tenant("c")])
        eng = _engine(_mixed(), tenants=reg, router="drf")
        return eng.run(), reg, eng
    s1, reg1, eng1 = run()
    s2, reg2, _ = run()
    assert s1 == s2                      # seeded determinism
    assert set(s1["tenants"]) == {"a", "b", "c"}
    assert s1["completed"] == 24
    # per-origin reject totals reconcile with the step records
    by_origin = {"new": 0, "requeue": 0}
    for dec in eng1.metrics.steps:
        by_origin["new"] += dec.rejected_new
        by_origin["requeue"] += dec.rejected_requeue
        assert len(dec.rejected_rids) == \
            dec.rejected_new + dec.rejected_requeue
    assert {k: v for k, v in by_origin.items() if v} \
        == s1["rejects_by_origin"]
    # registry credit is live and bounded
    for name in ("a", "b", "c"):
        assert reg1.min_credit <= reg1.credit(name) <= 1.0
    # summary() surfaces the same tenants with their reject counters
    table = reg1.summary()
    assert set(table) == {"a", "b", "c"}


def test_registry_list_seam_and_auto_register():
    """Engine(tenants=[Tenant(...)]) wraps a registry; unknown tenant
    names arriving on requests register themselves at weight 1.0."""
    eng = _engine(_mixed(), tenants=[Tenant("a", weight=2.0)],
                  router="drf")
    assert "a" in eng.tenancy and "b" in eng.tenancy
    assert eng.tenancy.get("b").weight == 1.0
    summary = eng.run()
    assert summary["completed"] == 24
