"""Invariants of the online scheduling subsystem (repro/sched/):
admission never over-books the budget, arrival streams conserve work
across OOM kills and requeues, and open-arrival runs are deterministic.
Mirrors tests/test_system.py style (module-scope fitted suite)."""
import numpy as np
import pytest

from repro.core import (MoEPredictor, SimConfig, Simulator,
                        spark_sim_suite, training_apps)
from repro.core.experts import MemoryFunction, calibrate_two_point
from repro.core.metrics import (run_open_scenario, run_scenario,
                                windowed_metrics)
from repro.core.simulator import OursPolicy, PairwisePolicy, Policy
from repro.core.workloads import (FEATURE_NAMES, INPUT_SIZES_M_ITEMS,
                                  AppProfile, size_class_of)
from repro.sched import (AdmissionController, Arrival, ArrivalConfig,
                         OnlineRefresher, poisson_arrivals,
                         trace_arrivals)
from repro.sched.arrivals import sample_input_size


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def _novel_app(seed=0, shift=2.0, cluster_seed=42):
    """An app from a feature cluster the predictor never saw, with an
    affine (weight-dominated) memory curve. Apps created with the same
    ``cluster_seed`` share a tight cluster (like a workload class)."""
    center = np.random.default_rng(cluster_seed).uniform(
        0.15, 0.85, len(FEATURE_NAMES)) + shift
    rng = np.random.default_rng(seed)
    feat = center + rng.normal(0, 0.015, len(FEATURE_NAMES))
    return AppProfile(name=f"NV.app{seed}", suite="NV", family="affine",
                      true_fn=MemoryFunction("affine", 6.0, 0.03),
                      cpu_load=0.3, rate=0.05, features=feat)


# --- AdmissionController ---------------------------------------------------

def test_admission_never_exceeds_budget():
    """Core invariant: booked memory <= budget for every family over a
    seeded sweep of curves and budgets."""
    ctrl = AdmissionController()
    rng = np.random.default_rng(0)
    for _ in range(200):
        fam = ["power", "exp_saturation", "log", "affine"][
            rng.integers(4)]
        fn = MemoryFunction(fam, float(rng.uniform(2.0, 60.0)),
                            float(rng.uniform(0.02, 0.8)))
        budget = float(rng.uniform(1.0, 64.0))
        dec = ctrl.admit(fn, budget, cap=float(rng.uniform(1.0, 50.0)))
        assert dec.mem_gb <= budget + 1e-9
        if dec and np.isfinite(dec.units):
            # admitted units actually fit under the budget
            assert float(fn(dec.units)) <= budget * 1.02 + 1e-6


def test_admission_calibrate_matches_two_point():
    ctrl = AdmissionController()
    fn = ctrl.calibrate("affine", [(2.0, 5.0), (4.0, 9.0)])
    ref = calibrate_two_point("affine", 2.0, 5.0, 4.0, 9.0)
    assert fn.family == "affine"
    assert np.isclose(fn.m, ref.m) and np.isclose(fn.b, ref.b)
    # >2 probes falls back to least squares on the same family
    fn3 = ctrl.calibrate("affine", [(1.0, 3.0), (2.0, 5.0), (4.0, 9.0)])
    assert abs(float(fn3(8.0)) - 17.0) < 0.5


def test_admission_calibrate_rejects_single_probe():
    with pytest.raises(ValueError):
        AdmissionController().calibrate("affine", [(2.0, 5.0)])


def test_admission_effective_budget_shading():
    ctrl = AdmissionController()
    assert ctrl.effective_budget(64.0) == 64.0
    assert ctrl.effective_budget(64.0, safety_margin=0.25) == 48.0
    assert ctrl.effective_budget(64.0, conservative=True) == 32.0
    assert ctrl.effective_budget(64.0, oom_count=2) == 16.0
    # backoff saturates at max_oom_shifts
    assert ctrl.effective_budget(64.0, oom_count=9) == \
        ctrl.effective_budget(64.0, oom_count=3)


def test_admission_floor_and_cap():
    ctrl = AdmissionController()
    fn = MemoryFunction("affine", 0.0, 1.0)   # y == x
    assert ctrl.admit(fn, 10.0).units == pytest.approx(10.0)
    assert ctrl.admit(fn, 10.0, cap=4.0).units == pytest.approx(4.0)
    assert not ctrl.admit(fn, 10.0, floor=20.0)


def test_admit_batch_serving_semantics():
    ctrl = AdmissionController()
    fn = MemoryFunction("affine", 1.0, 0.5)   # weights + per-request GB
    assert ctrl.admit_batch(fn, 5.0).units == 8
    assert ctrl.admit_batch(fn, 5.0, max_batch=3).units == 3
    # a model that barely fits still serves one request at a time —
    # and the within-budget case is NOT flagged forced
    assert not ctrl.admit_batch(fn, 5.0).info["forced"]
    dec = ctrl.admit_batch(fn, 0.1)
    assert dec.units == 1
    # fn(1) = 1.5 GB > 0.1 GB budget: forced progress is observable,
    # not silent (the serving driver logs it)
    assert dec.info["forced"]
    assert dec.mem_gb <= 0.1 + 1e-9   # booking still clamps to budget
    # saturating curve under a generous budget -> bounded by max_batch
    sat = MemoryFunction("exp_saturation", 2.0, 1.0)
    assert ctrl.admit_batch(sat, 10.0, max_batch=64).units == 64
    # ...and REQUIRES a bound: unbounded admission must not silently
    # return a huge batch
    with pytest.raises(ValueError):
        ctrl.admit_batch(sat, 10.0)


# --- arrival streams -------------------------------------------------------

def test_poisson_arrivals_shape_and_determinism(suite):
    apps, _ = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=40)
    a1 = poisson_arrivals(apps, acfg, seed=9)
    a2 = poisson_arrivals(apps, acfg, seed=9)
    assert len(a1) == 40
    assert [x.t for x in a1] == [x.t for x in a2]
    assert all(x1.app.name == x2.app.name for x1, x2 in zip(a1, a2))
    ts = [x.t for x in a1]
    assert ts == sorted(ts) and ts[0] > 0
    assert poisson_arrivals(apps, acfg, seed=10)[0].t != ts[0]


def test_poisson_arrivals_horizon_and_weights(suite):
    apps, _ = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=200, horizon_s=400.0)
    arr = poisson_arrivals(apps, acfg, seed=0)
    assert 0 < len(arr) < 200
    assert all(a.t <= 400.0 for a in arr)
    # degenerate weights pin the stream to one app
    w = np.zeros(len(apps))
    w[3] = 1.0
    arr = poisson_arrivals(apps, ArrivalConfig(n_jobs=10, app_weights=w),
                           seed=0)
    assert all(a.app is apps[3] for a in arr)
    with pytest.raises(ValueError):
        poisson_arrivals(apps, ArrivalConfig(app_weights=[1.0]), seed=0)


def test_trace_arrivals_replay(suite):
    apps, _ = suite
    trace = [(50.0, apps[1].name, "large"), (10.0, apps[0].name, 3.5)]
    arr = trace_arrivals(trace, apps)
    assert [a.t for a in arr] == [10.0, 50.0]
    assert arr[0].items == 3.5 and arr[1].items == 1000.0
    with pytest.raises(KeyError):
        trace_arrivals([(0.0, "no.such.app", 1.0)], apps)


def test_sample_input_size_respects_class_mix():
    rng = np.random.default_rng(0)
    xs = {sample_input_size(rng, {"small": 1.0}) for _ in range(20)}
    assert xs == {0.3}


def test_size_class_of_round_trips_table4():
    for cls, items in INPUT_SIZES_M_ITEMS.items():
        assert size_class_of(items) == cls
    assert size_class_of(2.0) == "small"     # log-nearest, not linear
    assert size_class_of(200.0) == "large"


# --- open-arrival simulator invariants -------------------------------------

class UnderPredictPolicy(Policy):
    """Deliberately under-predicts memory 5x -> executors overflow their
    hosts -> OOM kills and requeues (the conservation stressor)."""
    uses_profiling = True

    def __init__(self):
        super().__init__(None)

    def predict(self, job, rng):
        t = job.app.true_fn
        return MemoryFunction(t.family, t.m * 0.2, t.b), {}


def _items_in_flight(sim, job):
    return sum(e.items_left for h in sim.hosts for e in h.execs
               if e.job is job)


def test_arrival_stream_conserves_items_under_oom(suite):
    """done + unassigned + in-flight == items for every job at every
    scheduling step, even while OOM kills requeue work."""
    apps, _ = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=15)
    arrivals = poisson_arrivals(apps, acfg, seed=4)
    cfg = SimConfig(n_hosts=8)
    sim = Simulator(None, UnderPredictPolicy(), cfg, seed=4,
                    arrivals=arrivals)
    orig_spawn, orig_remove = sim._spawn, sim._remove_exec

    def check(job):
        total = job.done + job.unassigned + _items_in_flight(sim, job)
        assert total == pytest.approx(job.items, rel=1e-6), job.jid

    def spawn_spy(job, host, items, mt, mc, delay=0.0):
        e = orig_spawn(job, host, items, mt, mc, delay)
        check(job)
        return e

    def remove_spy(e, requeue):
        orig_remove(e, requeue)
        check(e.job)

    sim._spawn, sim._remove_exec = spawn_spy, remove_spy
    out = sim.run()
    assert out["oom_count"] > 0        # the stressor actually fired
    for job in sim.jobs:               # everything still completed
        assert job.finish is not None
        assert job.done == pytest.approx(job.items, rel=1e-6)


def test_open_arrival_memory_never_overclaimed(suite):
    """Scheduler invariant survives the open-arrival path: booked memory
    never exceeds host capacity at spawn time."""
    apps, moe = suite
    acfg = ArrivalConfig(rate_per_s=0.1, n_jobs=20)
    arrivals = poisson_arrivals(apps, acfg, seed=2)
    cfg = SimConfig(n_hosts=10)
    sim = Simulator(None, OursPolicy(moe), cfg, seed=2, arrivals=arrivals)
    orig = sim._spawn

    def spy(job, host, items, mt, mc, delay=0.0):
        e = orig(job, host, items, mt, mc, delay)
        assert host.mem_claimed <= cfg.host_mem_gb + 1e-6
        return e

    sim._spawn = spy
    out = sim.run()
    assert all(j.finish is not None for j in sim.jobs)


def test_open_scenario_skips_empty_streams(suite):
    """A horizon-truncated empty stream must not fold stp=0 into the
    gmean (which would collapse the aggregate for every policy); a run
    where EVERY stream is empty is an error, not a number."""
    apps, moe = suite
    tight = ArrivalConfig(rate_per_s=0.0005, n_jobs=5, horizon_s=20.0)
    with pytest.raises(ValueError):
        run_open_scenario(apps, lambda s: OursPolicy(moe), tight,
                          n_streams=2, seed=5)


def test_open_arrival_determinism(suite):
    apps, moe = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=12)
    r1 = run_open_scenario(apps, lambda s: OursPolicy(moe), acfg,
                           n_streams=2, seed=5, window_s=1000.0)
    r2 = run_open_scenario(apps, lambda s: OursPolicy(moe), acfg,
                           n_streams=2, seed=5, window_s=1000.0)
    assert r1["stp_gmean"] == r2["stp_gmean"]
    assert r1["antt_gmean"] == r2["antt_gmean"]
    assert r1["windows"] == r2["windows"]
    r3 = run_open_scenario(apps, lambda s: OursPolicy(moe), acfg,
                           n_streams=2, seed=6)
    assert r3["stp_gmean"] != r1["stp_gmean"]


def test_batch_path_unchanged_by_arrival_refactor(suite):
    """jobs_spec batch mode == an arrival stream with every t=0 (the
    closed-batch special case of the open system)."""
    apps, moe = suite
    jobs = [(apps[i], 30.0) for i in (0, 5, 11, 17)]
    cfg = SimConfig(n_hosts=6)
    out_batch = Simulator(jobs, OursPolicy(moe), cfg, seed=3).run()
    arrivals = [Arrival(0.0, app, items) for app, items in jobs]
    out_open = Simulator(None, OursPolicy(moe), cfg, seed=3,
                         arrivals=arrivals).run()
    assert out_batch["stp"] == out_open["stp"]
    assert out_batch["antt"] == out_open["antt"]


def test_windowed_metrics_account_for_every_finish(suite):
    apps, moe = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=15)
    arrivals = poisson_arrivals(apps, acfg, seed=8)
    sim = Simulator(None, OursPolicy(moe), SimConfig(n_hosts=8), seed=8,
                    arrivals=arrivals)
    out = sim.run()
    wins = windowed_metrics(out, 1500.0)
    finished = sum(1 for f in out["finish_times"] if f is not None)
    assert sum(w["completed"] for w in wins) == finished
    assert wins[-1]["unfinished"] == len(arrivals) - finished
    assert sum(w["arrived"] for w in wins) <= len(arrivals)
    assert all(w["stp"] >= 0.0 for w in wins)
    with pytest.raises(ValueError):
        windowed_metrics(out, 0.0)


# --- online predictor refresh ----------------------------------------------

def test_online_refresher_folds_in_novel_class(suite):
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    novel = _novel_app(seed=1)
    fam0, _, conf0 = moe.select_family(novel.features)
    assert not conf0                   # unseen cluster -> unconfident
    ref = OnlineRefresher(moe)
    xs = np.asarray([1.0, 50.0, 100.0])
    ys = np.asarray(novel.true_fn(xs))
    assert ref.observe(novel.features, xs, ys) == "affine"
    fam1, _, conf1 = moe.select_family(novel.features)
    assert conf1 and fam1 == "affine"
    # a twin arrival is now confident -> rejected (no table bloat)
    twin = _novel_app(seed=1)
    assert ref.observe(twin.features, xs, ys) is None
    assert ref.stats() == {"accepted": 1, "rejected": 1, "table_full": 0}
    # a full table drops offers and says so
    ref.max_updates = 1
    third = _novel_app(seed=9, shift=5.0, cluster_seed=77)
    assert ref.observe(third.features, xs, ys) is None
    assert ref.stats()["table_full"] == 1


def test_online_refresher_rejects_noisy_fits(suite):
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    ref = OnlineRefresher(moe)
    novel = _novel_app(seed=2)
    xs = np.asarray([1.0, 50.0, 100.0])
    ys = np.asarray([5.0, 80.0, 20.0])   # not any family's curve
    assert ref.observe(novel.features, xs, ys) is None
    assert ref.rejected == 1
    # too few probes is also a rejection
    assert ref.observe(novel.features, xs[:2], ys[:2]) is None


def test_online_refresher_rejects_ambiguous_flat_curve(suite):
    """A noisy flat probe curve fits EVERY family about equally well —
    the argmin is measurement noise, and folding it in would label the
    cluster with an arbitrary family. (Noiseless curves are fine: there
    the generating family is distinguishably best even when flat.)"""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    ref = OnlineRefresher(moe)
    novel = _novel_app(seed=4)
    xs = np.asarray([0.1, 1.5, 3.0])
    ys = np.asarray([6.05, 6.00, 6.14])  # ~flat + 2% measurement noise
    assert ref.observe(novel.features, xs, ys) is None
    assert ref.rejected == 1


def test_partial_update_requires_fit():
    with pytest.raises(RuntimeError):
        MoEPredictor().partial_update(np.zeros(len(FEATURE_NAMES)),
                                      "affine")


def test_partial_update_dedupes_near_twin_rows(suite):
    """A row within dedupe_tol of an existing SAME-family row adds no
    information: it must be dropped (returns False) instead of growing
    the KNN table without bound."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    n0 = len(moe._X_raw)
    feat = _novel_app(seed=1).features
    assert moe.partial_update(feat, "affine") is True
    # the EXACT same features again -> duplicate, table unchanged
    assert moe.partial_update(feat, "affine") is False
    # a near-twin (same tight cluster) -> still a duplicate
    twin = _novel_app(seed=2).features
    assert moe.partial_update(twin, "affine") is False
    assert len(moe._X_raw) == n0 + 1
    assert moe.n_online_rows == 1
    # same features but a DIFFERENT family is new information, kept
    assert moe.partial_update(twin, "log") is True
    assert len(moe._X_raw) == n0 + 2


def test_partial_update_evicts_oldest_online_row(suite):
    """Beyond max_online_rows the oldest ONLINE row is evicted; offline
    training rows are never touched."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    moe.max_online_rows = 2
    n_fit = moe._n_fit
    f1 = _novel_app(seed=1, shift=2.0, cluster_seed=42).features
    f2 = _novel_app(seed=1, shift=4.0, cluster_seed=43).features
    f3 = _novel_app(seed=1, shift=6.0, cluster_seed=44).features
    assert moe.partial_update(f1, "affine")
    assert moe.partial_update(f2, "affine")
    assert moe.partial_update(f3, "affine")        # evicts f1
    assert moe.n_online_rows == 2
    # max_online_rows=0 disables online rows (reject, don't evict)
    frozen = MoEPredictor(max_online_rows=0).fit(training_apps(apps))
    assert frozen.partial_update(f1, "affine") is False
    assert frozen.n_online_rows == 0
    assert len(moe._X_raw) == n_fit + 2 == len(moe.knn.X)
    # f1's row is gone, f2/f3 remain
    assert not any(np.allclose(row, f1) for row in moe._X_raw)
    assert any(np.allclose(row, f2) for row in moe._X_raw)
    assert any(np.allclose(row, f3) for row in moe._X_raw)
    # training rows intact
    assert moe._n_fit == n_fit
    for a in training_apps(apps):
        assert any(np.allclose(row, a.features) for row in moe._X_raw)


def test_refresher_counts_dedupe_as_rejection(suite):
    """OnlineRefresher with confidence gating off: the predictor-level
    dedupe is the second line of defense against table bloat."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    ref = OnlineRefresher(moe, only_unconfident=False)
    novel = _novel_app(seed=1)
    xs = np.asarray([1.0, 50.0, 100.0])
    ys = np.asarray(novel.true_fn(xs))
    assert ref.observe(novel.features, xs, ys) == "affine"
    assert ref.observe(novel.features, xs, ys) is None
    assert ref.stats() == {"accepted": 1, "rejected": 1, "table_full": 0}
    assert moe.n_online_rows == 1


def test_partial_update_keeps_second_novel_cluster_unconfident(suite):
    """Widening the scaler envelope contracts KNN distances; the
    confidence threshold must contract with them, or a SECOND unseen
    cluster would suddenly look 'near' and lose the paper's
    distance-based soundness fallback."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    other = _novel_app(seed=7, shift=3.5, cluster_seed=99)
    assert not moe.select_family(other.features)[2]
    moe.partial_update(_novel_app(seed=1).features, "affine")
    # cluster A is now in the table; unrelated cluster B must still
    # trigger the conservative fallback
    assert not moe.select_family(other.features)[2]


def test_partial_update_preserves_existing_accuracy(suite):
    """Widening the scaler envelope for an out-of-range arrival must not
    break selection on the original training clusters."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    before = sum(moe.select_family(a.features)[0] == a.family
                 for a in apps)
    moe.partial_update(_novel_app(seed=3).features, "affine")
    after = sum(moe.select_family(a.features)[0] == a.family
                for a in apps)
    assert after >= before - 1         # at most negligible drift


def test_ours_policy_refreshes_during_open_stream(suite):
    """End-to-end: a stream containing a novel class teaches the
    predictor while serving (the demo's assertion, minified)."""
    apps, _ = suite
    moe = MoEPredictor().fit(training_apps(apps))
    novel = [_novel_app(seed=s) for s in range(3)]
    universe = list(apps) + novel
    w = np.asarray([0.2] * len(apps) + [3.0] * len(novel))
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=10, app_weights=w)
    arrivals = poisson_arrivals(universe, acfg, seed=11)
    assert any(a.app.suite == "NV" for a in arrivals)
    ref = OnlineRefresher(moe)
    sim = Simulator(None, OursPolicy(moe, refresher=ref),
                    SimConfig(n_hosts=8), seed=11, arrivals=arrivals)
    sim.run()
    assert ref.accepted >= 1
    # the novel CLUSTER is now confidently selectable, labeled with
    # whatever family the in-stream probes supported (a flat curve is
    # legitimately ambiguous between families — all fit within 5%)
    fam, _, conf = moe.select_family(novel[0].features)
    assert conf and fam == ref.history[0]
