"""The elastic runtime (repro/sched/elastic.py) and its consumer seams:

* SlowdownCurve / fit_slowdown_curve — validation, interpolation,
  the spill-model derivation (slowdown bounded by the disk re-read
  factor, monotone in the granted fraction), flat-curve fallbacks;
* ElasticController — the shrink-vs-wait-vs-reject matrix, including
  the conservative flat curve never volunteering for a cut;
* AdmissionController.shrink_target — the shrunken booking never
  exceeds the budget on any axis, ``info["shrink"]`` carries the
  priced verdict, average-rate axes never shrink;
* FailureSchedule — seeded determinism, own-RNG isolation, the
  efail/erepair event ride on a ClusterRuntime with the repair pushed
  by the fail handler;
* Autoscaler — sustained-trend scale decisions (one bursty sample
  never flaps the fleet), streak resets after each action;
* the simulator seam — an EMPTY failure plan leaves a seeded run
  bit-identical (attach perturbs no RNG stream), a deterministic plan
  releases stale claims on fail and re-admits on repair, the legacy
  Poisson fail/repair channel conserves work, elastic shrink spawns
  fire and charge their slowdown, tenant-DRF interleaves the scan;
* the engine seam — flags-off summaries carry no ``elastic`` section,
  replica fail/drain/repair completes every request, the autoscaler
  scales up under a burst, shrunken joins book within budget;
* tenancy half-life — the default window path is bit-identical, decay
  forgives an old bad burst faster than the hard window.
"""
import numpy as np
import pytest

from repro.core import MoEPredictor, SimConfig, Simulator, \
    spark_sim_suite, training_apps
from repro.core.experts import MemoryFunction
from repro.core.simulator import OursPolicy
from repro.sched import (AdmissionController, Arrival, Autoscaler,
                         ElasticController, FailureSchedule,
                         SlowdownCurve, Tenant, TenantRegistry,
                         fit_slowdown_curve, get_estimator,
                         pick_spawn_node, shrink_vector)
from repro.sched.cluster import ClusterRuntime, ClusterState
from repro.sched.resources import MEMORY_AXES, ResourceVector
from repro.serve import Engine, Request, ServingDemand
from repro.serve.batcher import ContinuousBatcher


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def spilly(apps):
    """Slope-dominated apps (sub-GB quarter-chunk floor): the mix
    where a shrunken memory grant genuinely spills items."""
    return [a for a in apps if a.measure(0.0625) < 1.0]


def make_requests(n, seed=0, rate=20.0, prompt=(8, 24), new=(8, 32),
                  ttft=0.25, tpot=0.05):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i, prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i]), ttft_deadline=ttft,
                    tpot_deadline=tpot)
            for i in range(n)]


# --- SlowdownCurve ---------------------------------------------------------

def test_slowdown_curve_validation():
    with pytest.raises(ValueError):
        SlowdownCurve(((0.0, 2.0),))          # fraction out of (0, 1]
    with pytest.raises(ValueError):
        SlowdownCurve(((1.5, 1.0),))
    with pytest.raises(ValueError):
        SlowdownCurve(((0.5, 0.9),))          # slowdown < 1
    with pytest.raises(ValueError):
        SlowdownCurve.linear(2.0, min_fraction=1.0)
    flat = SlowdownCurve.flat()
    assert not flat.shrinkable
    assert flat.slowdown_at(1.0) == 1.0
    assert flat.slowdown_at(0.5) == float("inf")
    assert SlowdownCurve(()).points == ((1.0, 1.0),)   # empty -> flat


def test_slowdown_curve_interpolation():
    c = SlowdownCurve.linear(3.0, min_fraction=0.5)
    assert c.shrinkable and c.min_fraction == pytest.approx(0.5)
    assert c.slowdown_at(1.0) == 1.0
    assert c.slowdown_at(0.5) == pytest.approx(3.0)
    assert c.slowdown_at(0.75) == pytest.approx(2.0)   # linear midpoint
    assert c.slowdown_at(0.49) == float("inf")         # below support
    assert c.slowdown_at(1.2) == 1.0                   # above full grant
    # monotone: deeper cut never cheaper
    fs = np.linspace(0.5, 1.0, 21)
    ss = [c.slowdown_at(f) for f in fs]
    assert all(a >= b - 1e-12 for a, b in zip(ss, ss[1:]))


def test_fit_slowdown_curve_spill_model():
    fn = MemoryFunction("affine", 0.5, 0.1)   # 0.5 GB floor + 0.1/item
    c = fit_slowdown_curve(fn, 100.0, spill_cost=3.0)
    assert c.shrinkable
    # the default grid reaches the controller's default min_fraction
    assert c.min_fraction == pytest.approx(0.25)
    assert c.slowdown_at(1.0) == 1.0
    for f in (0.3, 0.5, 0.75, 0.9):
        s = c.slowdown_at(f)
        # priced between free and the pure disk re-read factor
        assert 1.0 <= s <= 3.0 + 1e-9
    # spill model at f=0.5: in_mem = inverse(0.5 * 10.5) = 47.5 items,
    # slowdown = (47.5 + 3 * 52.5) / 100
    assert c.slowdown_at(0.5) == pytest.approx(
        (47.5 + 3.0 * 52.5) / 100.0, rel=1e-6)


def test_fit_slowdown_curve_degenerate_falls_flat():
    assert not fit_slowdown_curve(
        MemoryFunction("affine", 0.5, 0.1), 0.0).shrinkable
    # no inverse on the callable -> not shrinkable
    assert not fit_slowdown_curve(lambda u: 0.1 * u, 10.0).shrinkable


# --- ElasticController -----------------------------------------------------

def test_elastic_controller_validation():
    with pytest.raises(ValueError):
        ElasticController(max_slowdown=0.5)
    with pytest.raises(ValueError):
        ElasticController(min_fraction=0.0)
    with pytest.raises(ValueError):
        ElasticController(min_fraction=1.5)


def test_elastic_controller_decision_matrix():
    ctl = ElasticController(max_slowdown=2.0, min_fraction=0.25)
    curve = SlowdownCurve.linear(3.0, min_fraction=0.25)
    # nothing free at all -> reject
    assert ctl.decide(curve, 0.0).action == "reject"
    # fits outright -> trivial shrink at full grant, free
    d = ctl.decide(None, 1.0)
    assert d.action == "shrink" and d.fraction == 1.0 and d.slowdown == 1.0
    # flat / missing curve -> wait (conservative fallback never shrinks)
    assert ctl.decide(None, 0.8).action == "wait"
    assert ctl.decide(SlowdownCurve.flat(), 0.8).action == "wait"
    # cut deeper than the controller or curve support -> wait
    assert ctl.decide(curve, 0.2).action == "wait"
    # priced over the cap -> wait (linear(3.0): 0.3 costs ~2.87)
    assert ctl.decide(curve, 0.3).action == "wait"
    # priced under the cap -> shrink, carrying the charged slowdown
    d = ctl.decide(curve, 0.8)
    assert bool(d) and d.action == "shrink"
    assert d.fraction == pytest.approx(0.8)
    assert d.slowdown == pytest.approx(curve.slowdown_at(0.8))


def test_shrink_vector_memory_axes_only():
    v = ResourceVector(host_ram=10.0, cpu=0.6, hbm=4.0, net=2.0)
    s = shrink_vector(v, 0.5)
    for a in v:
        if a in MEMORY_AXES:
            assert s[a] == pytest.approx(0.5 * v[a])
        else:
            assert s[a] == pytest.approx(v[a])


# --- AdmissionController.shrink_target -------------------------------------

def test_shrink_target_books_within_budget():
    ctl = AdmissionController(safety_margin=0.0)
    fn = MemoryFunction("affine", 0.5, 0.1)   # demand(100) = 10.5 GB
    curve = fit_slowdown_curve(fn, 100.0)
    elastic = ElasticController(max_slowdown=2.5)
    info = {}
    dec = ctl.shrink_target(fn, 6.0, units=100.0, curve=curve,
                            elastic=elastic, info=info)
    assert dec.units == pytest.approx(100.0)
    assert dec.booked is not None and dec.booked.fits(dec.budget)
    sh = dec.info["shrink"]
    assert sh["fraction"] == pytest.approx(6.0 / 10.5, rel=1e-6)
    assert 1.0 < sh["slowdown"] <= 2.5 + 1e-9
    # book=False plans without reserving
    dry = ctl.shrink_target(fn, 6.0, units=100.0, curve=curve,
                            elastic=elastic, book=False)
    assert dry.booked is None and dry.mem_gb == 0.0
    assert dry.info["shrink"]["fraction"] == pytest.approx(
        sh["fraction"])


def test_shrink_target_wait_and_rate_axes():
    ctl = AdmissionController(safety_margin=0.0)
    fn = MemoryFunction("affine", 0.5, 0.1)
    elastic = ElasticController(max_slowdown=2.5)
    # flat curve -> structured wait, zero units
    dec = ctl.shrink_target(fn, 6.0, units=100.0,
                            curve=SlowdownCurve.flat(), elastic=elastic)
    assert dec.units == 0.0 and dec.info["elastic"]["action"] == "wait"
    assert "reject" in dec.info
    # an over-budget average-rate axis (cpu) cannot be shrunk away
    from repro.sched.resources import DemandModel
    dm = DemandModel(curves={"host_ram": fn}, fixed={"cpu": 2.0})
    bv = ResourceVector(host_ram=6.0, cpu=1.0)
    dec = ctl.shrink_target(dm, bv, units=100.0,
                            curve=fit_slowdown_curve(fn, 100.0),
                            elastic=elastic)
    assert dec.units == 0.0
    assert dec.info["elastic"]["action"] == "wait"
    assert dec.info["reject"]["axis"] == "cpu"


# --- FailureSchedule -------------------------------------------------------

def test_failure_schedule_validation_and_determinism():
    with pytest.raises(ValueError):
        FailureSchedule([(1.0, 0)], repair_s=-1.0)
    with pytest.raises(ValueError):
        FailureSchedule([(-1.0, 0)])
    with pytest.raises(ValueError):
        FailureSchedule.poisson(seed=0, mtbf_s=0.0, n_targets=1,
                                horizon_s=1.0)
    a = FailureSchedule.poisson(seed=7, mtbf_s=3.0, n_targets=4,
                                horizon_s=50.0, repair_s=1.0)
    b = FailureSchedule.poisson(seed=7, mtbf_s=3.0, n_targets=4,
                                horizon_s=50.0, repair_s=1.0)
    c = FailureSchedule.poisson(seed=8, mtbf_s=3.0, n_targets=4,
                                horizon_s=50.0, repair_s=1.0)
    assert a.failures == b.failures and a.failures != c.failures
    assert all(0.0 <= t < 50.0 for t, _ in a.failures)
    capped = FailureSchedule.poisson(seed=7, mtbf_s=3.0, n_targets=4,
                                     horizon_s=50.0, repair_s=1.0,
                                     max_failures=3)
    assert capped.failures == a.failures[:3]


def test_failure_schedule_rides_the_runtime():
    runtime = ClusterRuntime(ClusterState.homogeneous(
        1, ResourceVector(hbm=1.0)))
    plan = FailureSchedule([(1.0, 0), (4.0, 1), (2.0, 7)], repair_s=0.5)
    events = []
    plan.attach(runtime,
                on_fail=lambda t, i: events.append(("fail", t, i)),
                on_repair=lambda t, i: events.append(("repair", t, i)),
                n_targets=2)      # target 7 is out of range: dropped
    runtime.run()
    assert events == [("fail", 1.0, 0), ("repair", 1.5, 0),
                      ("fail", 4.0, 1), ("repair", 4.5, 1)]
    assert plan.n_failed == 2 and plan.n_repaired == 2


# --- Autoscaler ------------------------------------------------------------

def test_autoscaler_validation():
    with pytest.raises(ValueError):
        Autoscaler(max_replicas=2, min_replicas=3)
    with pytest.raises(ValueError):
        Autoscaler(max_replicas=0)
    with pytest.raises(ValueError):
        Autoscaler(max_replicas=2, interval_s=0.0)
    with pytest.raises(ValueError):
        Autoscaler(max_replicas=2, sustain=0)


def test_autoscaler_sustained_trends():
    a = Autoscaler(max_replicas=4, min_replicas=1, sustain=3,
                   scale_up_queue=4.0, scale_down_queue=0.5)
    # one bursty sample never flaps the fleet
    assert a.observe(0.0, queue_depth=100.0, active=1) == "hold"
    assert a.observe(1.0, queue_depth=0.0, active=1) == "hold"
    # three SUSTAINED hot samples -> up, and the streak resets
    for i in range(2):
        assert a.observe(2.0 + i, queue_depth=20.0, active=1) == "hold"
    assert a.observe(4.0, queue_depth=20.0, active=1) == "up"
    assert a.observe(5.0, queue_depth=20.0, active=2) == "hold"
    # at the ceiling, pressure cannot scale further
    for i in range(6):
        assert a.observe(6.0 + i, queue_depth=99.0, active=4) == "hold"
    # calm samples above the floor -> down after sustain
    assert a.observe(20.0, queue_depth=0.0, active=2) == "hold"
    assert a.observe(21.0, queue_depth=0.0, active=2) == "hold"
    assert a.observe(22.0, queue_depth=0.0, active=2) == "down"
    # at the floor, calm holds
    assert all(a.observe(30.0 + i, queue_depth=0.0, active=1) == "hold"
               for i in range(6))


def test_autoscaler_slo_floor_triggers_up():
    a = Autoscaler(max_replicas=2, sustain=2, slo_floor=0.9)
    for _ in range(8):
        a.observe_finished(False)
    assert a.attainment() < 0.9
    assert a.observe(0.0, queue_depth=0.0, active=1) == "hold"
    assert a.observe(1.0, queue_depth=0.0, active=1) == "up"


def test_pick_spawn_node():
    assert pick_spawn_node([]) is None
    assert pick_spawn_node([3, 1, 2]) == 1      # no topology: lowest id
    from repro.sched import get_topology
    topo = get_topology("two-rack", nodes=4)
    picked = pick_spawn_node([1, 3], topo)
    assert picked in (1, 3)
    # deterministic across calls
    assert pick_spawn_node([1, 3], topo) == picked


# --- the simulator seam ----------------------------------------------------

def _sim_arrivals(apps, n=16, rate=0.05, seed=5, tenant_of=None):
    from repro.sched.arrivals import sample_input_size
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    sizes = {"small": 0.5, "medium": 0.5, "large": 0.0}
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        app = apps[int(rng.choice(len(apps)))]
        out.append(Arrival(t, app, sample_input_size(rng, sizes),
                           tenant=tenant_of(i) if tenant_of else None))
    return out


def _run_sim(apps, moe, *, elastic=None, failure_plan=None, seed=3,
             n=12, hosts=4, mem=10.0, arrivals=None, spawn_spy=None):
    cfg = SimConfig(n_hosts=hosts, host_mem_gb=mem, tasks_per_slot=2,
                    elastic=elastic, failure_plan=failure_plan)
    pol = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    sim = Simulator(None, pol, cfg, seed=seed,
                    arrivals=arrivals if arrivals is not None
                    else _sim_arrivals(spilly(apps), n=n))
    if spawn_spy is not None:
        orig = sim._spawn
        def wrapped(job, host, *a, **kw):
            spawn_spy(sim, job, host)
            return orig(job, host, *a, **kw)
        sim._spawn = wrapped
    out = sim.run()
    out["_sim"] = sim
    return out


def _strip(out):
    return {k: v for k, v in out.items()
            if k in ("stp", "antt", "oom_count", "finish_times",
                     "unfinished")}


def test_sim_empty_failure_plan_is_bit_identical(suite):
    """Attaching the machinery with NOTHING planned must not perturb
    the schedule: the plan draws from its own RNG at construction and
    injects zero events."""
    apps, moe = suite
    base = _run_sim(apps, moe)
    wired = _run_sim(apps, moe,
                     failure_plan=FailureSchedule([], repair_s=1.0))
    assert _strip(base) == _strip(wired)


def test_sim_failure_plan_releases_claims_and_repairs(suite):
    """Deterministic fail: every executor claim on the downed host is
    released (stale-claim release), the job's non-checkpointed work
    requeues, and the repair re-admits the host into the scan."""
    apps, moe = suite
    plan = FailureSchedule.poisson(seed=9, mtbf_s=800.0, n_targets=4,
                                   horizon_s=4000.0, repair_s=150.0)
    assert plan.failures       # the seed actually draws events
    out = _run_sim(apps, moe, failure_plan=plan, seed=6)
    sim = out["_sim"]
    assert plan.n_failed >= 1 and plan.n_repaired == plan.n_failed
    assert out["unfinished"] == 0        # repair re-admitted the work
    for h in sim.hosts:                  # no stale claims at drain
        assert not h.execs
        assert h.up
    # identical plan + seed -> identical run
    plan2 = FailureSchedule.poisson(seed=9, mtbf_s=800.0, n_targets=4,
                                    horizon_s=4000.0, repair_s=150.0)
    out2 = _run_sim(apps, moe, failure_plan=plan2, seed=6)
    assert _strip(out) == _strip(out2)


def test_sim_fail_handler_drops_claims_immediately(suite):
    """Right after the efail handler runs, the downed host holds no
    executors and no booked capacity — the invariant the dispatcher
    relies on to skip it."""
    apps, moe = suite
    plan = FailureSchedule.poisson(seed=9, mtbf_s=800.0, n_targets=4,
                                   horizon_s=4000.0, repair_s=150.0)
    cfg = SimConfig(n_hosts=4, host_mem_gb=10.0, tasks_per_slot=2,
                    failure_plan=plan)
    pol = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    sim = Simulator(None, pol, cfg, seed=6,
                    arrivals=_sim_arrivals(spilly(apps), n=12))
    seen = []
    orig = sim._fail_host
    def spy(t, idx):
        orig(t, idx)
        host = sim.hosts[idx]
        assert not host.up and not host.node.up
        assert not host.execs
        seen.append(idx)
    sim._fail_host = spy
    sim.run()
    assert seen                          # the spy actually fired


def test_sim_legacy_poisson_failures_conserve_work(suite):
    """Satellite: the LEGACY fail/repair channel (Poisson re-arm from
    the simulator RNG) still drains every job, releases claims, and
    stays seeded-deterministic."""
    apps, moe = suite
    cfg = SimConfig(n_hosts=4, host_mem_gb=10.0, tasks_per_slot=2,
                    failures=True, host_mtbf_s=900.0,
                    repair_time_s=100.0)
    pol = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    arrivals = _sim_arrivals(spilly(apps), n=10)
    out = Simulator(None, pol, cfg, seed=2, arrivals=arrivals).run()
    assert out["unfinished"] == 0
    pol2 = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    out2 = Simulator(None, pol2, cfg, seed=2, arrivals=arrivals).run()
    assert _strip(out) == _strip(out2)


def test_sim_elastic_shrink_fires_and_completes(suite):
    """With the controller bound and memory scarce, at least one
    executor spawns on a shrunken grant (telemetry counter) and the
    stream still drains — the slowdown is charged, not dropped."""
    apps, moe = suite
    arrivals = _sim_arrivals(spilly(apps), n=20, rate=0.06, seed=5)
    rigid = _run_sim(apps, moe, arrivals=arrivals, mem=10.0)
    el = _run_sim(apps, moe, arrivals=arrivals, mem=10.0,
                  elastic=ElasticController(max_slowdown=2.9))
    shrunk = int(el["_sim"].telemetry.counters.get("elastic.shrink", 0))
    assert shrunk >= 1
    assert int(rigid["_sim"].telemetry.counters.get(
        "elastic.shrink", 0)) == 0
    assert el["unfinished"] == 0


def test_sim_tenant_drf_interleaves_scan(suite):
    """Satellite: the host-scan DRF interleave — with tenant "a"
    flooding the queue ahead of tenant "b", ``_tenant_order`` hands
    "b" the second scan slot (progressive filling charges "a" for its
    first grant) instead of draining "a" FIFO-style."""
    from types import SimpleNamespace
    apps, moe = suite
    pol = OursPolicy(estimator=get_estimator("moe", predictor=moe))
    fn = MemoryFunction("affine", 0.5, 0.1)
    def job(tenant):
        return SimpleNamespace(tenant=tenant, unassigned=40.0,
                               items=40.0, fn_hat=fn)
    jobs = [job("a") for _ in range(5)] + [job("b"), job("b")]
    sim = SimpleNamespace(
        cfg=SimConfig(n_hosts=2, host_mem_gb=10.0),
        hosts=[SimpleNamespace(execs=[]) for _ in range(2)])
    order = [j.tenant for j in pol._tenant_order(sim, jobs)]
    assert len(order) == len(jobs)
    assert sorted(order) == sorted(j.tenant for j in jobs)
    assert order[0] == "a" and "b" in order[:2], order
    # untenanted jobs form their own pseudo-tenant and interleave too
    mixed = [job("a"), job("a"), job(None)]
    order2 = [j.tenant for j in pol._tenant_order(sim, mixed)]
    assert None in order2[:2], order2


def test_sim_tenant_arrivals_thread_to_jobs(suite):
    """Tenants declared on Arrivals land on the spawned jobs' claims
    (the accounting the interleave charges against) and the stream
    still drains."""
    apps, moe = suite
    pool = spilly(apps)
    from repro.sched.arrivals import sample_input_size
    rng = np.random.default_rng(0)
    sizes = {"small": 1.0}
    arrivals = [Arrival(0.1 * i, pool[i % len(pool)],
                        sample_input_size(rng, sizes),
                        tenant=("a" if i % 2 == 0 else "b"))
                for i in range(6)]
    seen = set()
    def spy(sim, job, host):
        seen.add(job.tenant)
    out = _run_sim(apps, moe, arrivals=arrivals, spawn_spy=spy)
    assert out["unfinished"] == 0
    assert seen == {"a", "b"}


# --- the engine seam -------------------------------------------------------

def _srv_demand(shrink=None):
    return ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                         shrink=shrink)


def test_engine_flags_off_no_elastic_section():
    reqs = make_requests(8, seed=1)
    s = Engine(reqs, _srv_demand(), 1.0, mode="continuous",
               max_batch=8).run()
    assert "elastic" not in s


def test_engine_empty_failure_plan_identical():
    reqs = make_requests(8, seed=1)
    base = Engine(reqs, _srv_demand(), 1.0, mode="continuous",
                  max_batch=8).run()
    wired = Engine(make_requests(8, seed=1), _srv_demand(), 1.0,
                   mode="continuous", max_batch=8,
                   failures=FailureSchedule([], repair_s=0.1)).run()
    for k in ("goodput_tok_s", "slo_goodput_tok_s", "completed",
              "preemptions", "node_steps"):
        assert base[k] == wired[k], k


def test_engine_rejects_elastic_on_wave():
    for kw in ({"elastic": ElasticController()},
               {"failures": FailureSchedule([])},
               {"autoscaler": Autoscaler(max_replicas=2)}):
        with pytest.raises(ValueError, match="continuous"):
            Engine(make_requests(4), _srv_demand(), 1.0, mode="wave",
                   **kw)


def test_engine_replica_failure_drains_and_repairs():
    reqs = make_requests(12, seed=3, rate=40.0)
    plan = FailureSchedule([(0.05, 0)], repair_s=0.2)
    eng = Engine(reqs, _srv_demand(), 1.0, mode="continuous",
                 max_batch=8, replicas=2, router="least-loaded",
                 failures=plan)
    s = eng.run()
    assert s["completed"] == len(reqs)   # drained work finishes
    ev = s["elastic"]["replica_events"]
    assert ev["fail"] == 1 and ev["repair"] == 1
    # deterministic replay
    s2 = Engine(make_requests(12, seed=3, rate=40.0), _srv_demand(),
                1.0, mode="continuous", max_batch=8, replicas=2,
                router="least-loaded",
                failures=FailureSchedule([(0.05, 0)],
                                         repair_s=0.2)).run()
    assert s["goodput_tok_s"] == s2["goodput_tok_s"]
    assert s["node_steps"] == s2["node_steps"]


def test_engine_autoscaler_scales_up_under_burst():
    rng = np.random.default_rng(4)
    t, reqs = 0.0, []
    for i in range(24):
        t += float(rng.exponential(1.0 / (60.0 if i >= 4 else 8.0)))
        reqs.append(Request(rid=i, prompt_len=16, max_new_tokens=16,
                            arrival=t, ttft_deadline=0.2,
                            tpot_deadline=0.05))
    auto = Autoscaler(max_replicas=3, min_replicas=1, interval_s=0.05,
                      sustain=2)
    eng = Engine(reqs, _srv_demand(), 0.6, mode="continuous",
                 max_batch=4, replicas=1, router="least-loaded",
                 autoscaler=auto)
    s = eng.run()
    assert s["completed"] == len(reqs)
    assert s["elastic"]["replica_events"].get("scale_up", 0) >= 1
    # spares ran real steps once flipped live
    assert len([n for n, c in s["node_steps"].items() if c > 0]) >= 2


def test_engine_shrunken_joins_book_within_budget():
    reqs = make_requests(10, seed=6, rate=50.0, prompt=(24, 40),
                         new=(24, 40))
    demand = _srv_demand(
        shrink=SlowdownCurve.linear(1.6, min_fraction=0.5))
    full_ctx = 40 + 40
    budget = 0.5 + 2e-4 * full_ctx * 2.0     # ~2 full joins of KV
    eng = Engine(reqs, demand, budget, mode="continuous", max_batch=8,
                 elastic=ElasticController(max_slowdown=2.0))
    s = eng.run()
    assert s["completed"] == len(reqs)
    assert s["elastic"]["shrunk_joins"] >= 1
    for dec in eng.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced


def test_batcher_shrink_plan_direct():
    """The batcher-level contract: a join that does not fit at full KV
    is admitted at a priced fraction, the grant is frozen, and the
    booked footprint stays within budget."""
    demand = ServingDemand(
        weights_gb=0.0, kv_gb_per_token=0.01,
        shrink=SlowdownCurve.linear(2.0, min_fraction=0.5))
    budget = ResourceVector(hbm=1.5)     # one full 1.0 GB join + half
    b = ContinuousBatcher(demand, budget, max_batch=4,
                          elastic=ElasticController(max_slowdown=2.5))
    pending = [Request(rid=i, prompt_len=50, max_new_tokens=50,
                       arrival=0.0) for i in range(3)]
    dec = b.plan_step([], pending, now=0.0, step=0)
    assert dec.shrunk, dec
    rid, frac, slow = dec.shrunk[0]
    assert 0.5 <= frac < 1.0 and 1.0 < slow <= 2.5
    assert dec.booked.fits(dec.budget)
    # applying the grant freezes it
    b.register_shrunk(pending[0], frac, slow)
    assert pending[0].rid in b.shrunk


# --- tenancy half-life -----------------------------------------------------

def test_tenant_halflife_validation_and_default_identity():
    with pytest.raises(ValueError):
        Tenant("a", credit_halflife_s=0.0)
    win = TenantRegistry([Tenant("a")], window=16)
    exp = TenantRegistry([Tenant("a", credit_halflife_s=None)],
                         window=16)
    rng = np.random.default_rng(2)
    for i in range(24):
        ok = bool(rng.random() < 0.7)
        ratio = float(rng.uniform(0.2, 1.5))
        for reg in (win, exp):
            reg.observe_slo("a", ok, now=float(i))
            reg.observe_latency_ratio("a", ratio, now=float(i))
    assert win.credit("a") == exp.credit("a")


@pytest.mark.slow
def test_elastic_bench_acceptance_end_to_end():
    """Tier-2: the full acceptance bench (both cells, strict bars) —
    the diurnal+failures simulator cell and the burst+failures serving
    cell both hold their strict wins."""
    from benchmarks import elastic_bench
    payload = elastic_bench.main()     # raises on any failed bar
    assert payload["sim"]["stp_ratio"] > 1.0
    assert payload["serving"]["slo_ratio"] > 1.0


@pytest.mark.slow
def test_engine_failure_churn_long(suite):
    """Tier-2: many fail/repair cycles across a 3-replica fleet under
    a sustained stream — every request still completes and the event
    ledger stays balanced."""
    reqs = make_requests(60, seed=8, rate=30.0)
    plan = FailureSchedule.poisson(seed=13, mtbf_s=0.4, n_targets=3,
                                   horizon_s=3.0, repair_s=0.15)
    s = Engine(reqs, _srv_demand(), 1.0, mode="continuous",
               max_batch=8, replicas=3, router="least-loaded",
               failures=plan).run()
    assert s["completed"] == len(reqs)
    ev = s["elastic"]["replica_events"]
    assert ev.get("fail", 0) >= 2
    assert ev.get("repair", 0) == ev.get("fail", 0)


def test_tenant_halflife_forgives_old_burst():
    """An early bad burst followed by sustained good behaviour: the
    half-life tenant's credit recovers ABOVE the hard-window tenant's
    while the burst is still inside the window."""
    win = TenantRegistry([Tenant("a")], window=64)
    exp = TenantRegistry([Tenant("a", credit_halflife_s=5.0)],
                         window=64)
    for i in range(8):                     # the bad burst at t ~ 0
        win.observe_slo("a", False, now=float(i) * 0.1)
        exp.observe_slo("a", False, now=float(i) * 0.1)
    for i in range(24):                    # sustained good behaviour
        t = 10.0 + float(i)
        win.observe_slo("a", True, now=t)
        exp.observe_slo("a", True, now=t)
    assert exp.credit("a") > win.credit("a")
    # and decay is monotone: more good time -> more credit
    before = exp.credit("a")
    for i in range(8):
        exp.observe_slo("a", True, now=40.0 + float(i))
    assert exp.credit("a") >= before
