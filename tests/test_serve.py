"""Invariants of the continuous-batching serving engine (repro/serve/):

* budget safety  — the booked (modeled) footprint never exceeds the
  budget on any axis at any step unless the decision is ``forced``, and
  forced steps only ever cover the single-request progress floor;
* conservation   — every request is admitted ``preemptions + 1`` times,
  finishes exactly its ``max_new_tokens``, and ends FINISHED;
* determinism    — identical seeds give identical step-by-step
  schedules (admissions, evictions, batch sizes, virtual times);
* termination    — a preemption storm (budget barely above one request)
  drains without tripping the engine's structural step bound.

Fast tier-1 tests run on the virtual-time SimBackend; the real-jax
engine tests are @slow (jit-compile dominated) and run in the full
suite (`-m ""` / CI_FULL=1).
"""
import os

import numpy as np
import pytest

from repro.sched import (AdmissionController, load_trace_jsonl,
                         trace_arrivals)
from repro.sched.resources import DemandModel, ResourceVector
from repro.serve import (ContinuousBatcher, Engine, PrefixCurve, Request,
                         RequestQueue, RequestState, ServingDemand,
                         SimBackend, requests_from_arrivals)

DATA = os.path.join(os.path.dirname(__file__), "data")


def make_requests(n, seed=0, rate=20.0, prompt=(8, 32), new=(8, 40)):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i,
                    prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i]))
            for i in range(n)]


def run_engine(n=24, seed=0, mode="continuous", kv_mult=3.0,
               placement="fcfs", host_ram=True, max_batch=16):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           host_ram_per_req_gb=0.01 if host_ram else 0.0)
    full = 32 + 40  # prompt + new upper bounds
    axes = {"hbm": 0.5 + 2e-4 * full * kv_mult}
    if host_ram:
        axes["host_ram"] = 0.01 * max(2.0 * kv_mult, 2.0)
    eng = Engine(make_requests(n, seed=seed), demand,
                 ResourceVector(**axes), SimBackend(), mode=mode,
                 placement=placement, max_batch=max_batch)
    summary = eng.run()
    return eng, summary


# --- batcher / engine invariants -------------------------------------------

@pytest.mark.parametrize("mode", ["continuous", "wave"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_no_unforced_over_budget_step(mode, seed):
    """Core safety invariant: booked <= budget on every axis at every
    step, except steps explicitly flagged forced."""
    eng, _ = run_engine(seed=seed, mode=mode, kv_mult=2.0)
    assert eng.metrics.steps, "engine recorded no steps"
    for dec in eng.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced, dec


def test_forced_only_covers_single_request_floor():
    """A forced step is the min_batch=1 progress guarantee: it runs
    exactly one request whose footprint alone exceeds the budget."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    # budget below the weights: EVERY step is forced, batch is always 1
    eng = Engine(make_requests(6, seed=3, new=(4, 8)), demand,
                 ResourceVector(hbm=0.4), SimBackend())
    s = eng.run()
    assert s["completed"] == 6
    assert s["forced_steps"] == s["steps"] > 0
    for dec in eng.metrics.steps:
        assert dec.forced and dec.batch == 1 and dec.forced_axes


@pytest.mark.parametrize("mode", ["continuous", "wave"])
def test_request_conservation(mode):
    """Every request ends FINISHED with exactly max_new_tokens decoded,
    admitted once per eviction plus one; step records agree."""
    eng, s = run_engine(n=30, seed=1, mode=mode, kv_mult=1.5)
    assert s["completed"] == 30
    admitted = preempted = 0
    for r in eng.requests:
        assert r.state == RequestState.FINISHED
        assert len(r.tokens) == r.max_new_tokens
        assert r.admissions == r.preemptions + 1, r
        assert r.finish_t is not None and r.first_token_t is not None
        assert r.finish_t >= r.first_token_t >= r.arrival
        admitted += r.admissions
        preempted += r.preemptions
    # the step log tells the same story as the request lifecycles
    assert admitted == sum(len(d.admitted) for d in eng.metrics.steps)
    assert preempted == sum(len(d.preempted) for d in eng.metrics.steps)


def test_identical_seeds_identical_schedules():
    runs = [run_engine(n=20, seed=5, kv_mult=2.0)[0] for _ in range(2)]
    a, b = runs[0].metrics, runs[1].metrics
    assert len(a.steps) == len(b.steps)
    for da, db in zip(a.steps, b.steps):
        assert (da.admitted, da.preempted, da.batch, da.forced,
                da.binding_axis) == \
            (db.admitted, db.preempted, db.batch, db.forced,
             db.binding_axis)
        assert da.t == pytest.approx(db.t)
    assert runs[0].metrics.summary() == runs[1].metrics.summary()


def test_different_seed_changes_schedule():
    a = run_engine(n=20, seed=5, kv_mult=2.0)[0].metrics.steps
    b = run_engine(n=20, seed=6, kv_mult=2.0)[0].metrics.steps
    assert [d.admitted for d in a] != [d.admitted for d in b]


def test_preemption_storm_terminates():
    """Budget barely above a single request's full footprint: constant
    evict/requeue churn must still drain (the structural step bound is
    an assertion, so run() raising would fail this test)."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 1.05)
    eng = Engine(make_requests(20, seed=2, rate=1000.0), demand, budget,
                 SimBackend(), max_batch=16)
    s = eng.run()
    assert s["completed"] == 20
    assert s["preemptions"] > 0      # the storm actually happened
    for dec in eng.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced


def test_continuous_beats_wave_goodput():
    """The acceptance bar: step-level admission >= wave admission at
    equal budget, on a contended scenario."""
    for seed in (0, 1):
        _, cont = run_engine(n=30, seed=seed, mode="continuous")
        _, wave = run_engine(n=30, seed=seed, mode="wave")
        assert cont["goodput_tok_s"] >= wave["goodput_tok_s"] * 0.99
    # under real contention the win is material, not a tie
    assert cont["goodput_tok_s"] > wave["goodput_tok_s"] * 1.1


def test_binding_axis_recorded_per_step():
    """With a tight host_ram side-car budget, some joins must bind on
    host_ram — the per-axis observability the simulator already has."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=1e-5,
                           host_ram_per_req_gb=0.05)
    eng = Engine(make_requests(20, seed=0), demand,
                 ResourceVector(hbm=2.0, host_ram=0.2), SimBackend(),
                 max_batch=16)
    s = eng.run()
    assert s["completed"] == 20
    assert s["binding_axes"].get("host_ram", 0) > 0


def test_engine_rejects_unknown_mode_and_bad_budget():
    demand = ServingDemand(weights_gb=0.1, kv_gb_per_token=1e-4)
    reqs = make_requests(2)
    with pytest.raises(ValueError, match="mode"):
        Engine(reqs, demand, 1.0, SimBackend(), mode="batch")
    with pytest.raises(ValueError, match="hbm"):
        ContinuousBatcher(demand, ResourceVector(host_ram=1.0))


# --- PrefixCurve ------------------------------------------------------------

def test_prefix_curve_monotone_and_inverse():
    costs = [0.5, 0.25, 1.0, 0.25]
    fn = PrefixCurve(costs)
    cum = np.cumsum(costs)
    for k in range(1, 5):
        assert fn(k) == pytest.approx(cum[k - 1])
    assert fn(0) == 0.0
    # inverse: the largest (fractional) u whose prefix fits y; whole
    # requests are what the batcher floors to
    assert int(fn.inverse(0.74)) == 1
    assert int(fn.inverse(0.75)) == 2
    assert int(fn.inverse(10.0)) == 4        # exhausted, not unbounded
    assert fn.inverse(-1.0) == 0.0
    xs = np.linspace(0, 4, 33)
    ys = [fn(x) for x in xs]
    assert all(b >= a - 1e-12 for a, b in zip(ys, ys[1:]))
    # controller integration: prefix curve on hbm + affine on host_ram
    dm = DemandModel({"hbm": fn}, primary_axis="hbm")
    dec = AdmissionController().admit(dm, ResourceVector(hbm=0.8),
                                     cap=4.0, book=False)
    assert int(dec.units) == 2 and dec.binding_axis == "hbm"


def test_serving_demand_requires_affine_fit():
    from repro.core.experts import MemoryFunction
    dm = DemandModel({"hbm": MemoryFunction("log", 1.0, 0.5)},
                     primary_axis="hbm")
    with pytest.raises(ValueError, match="affine"):
        ServingDemand.from_demand_model(dm, 64)


# --- queue / placement ------------------------------------------------------

def test_queue_release_and_placement_order():
    reqs = [Request(rid=0, prompt_len=30, max_new_tokens=30, arrival=0.0),
            Request(rid=1, prompt_len=4, max_new_tokens=4, arrival=0.0),
            Request(rid=2, prompt_len=10, max_new_tokens=10, arrival=5.0)]
    q = RequestQueue(reqs, placement="sjf")
    q.release(0.0)
    assert [r.rid for r in q.pending(0.0)] == [1, 0]   # short first
    assert q.next_arrival() == 5.0
    q.release(5.0)
    assert [r.rid for r in q.pending(5.0)] == [1, 2, 0]
    q.take(q.pending(5.0)[:2])
    assert [r.rid for r in q.pending(5.0)] == [0]
    q.requeue(reqs[1])
    assert len(q) == 2 and not q.drained


def test_requests_from_arrivals_maps_stream():
    from repro.core.workloads import spark_sim_suite
    from repro.sched import ArrivalConfig, poisson_arrivals
    apps = spark_sim_suite()
    arr = poisson_arrivals(apps, ArrivalConfig(rate_per_s=0.5, n_jobs=10),
                           seed=3)
    reqs = requests_from_arrivals(arr, max_new_tokens=16,
                                  prompt_scale=0.5, max_prompt=64,
                                  seed=3)
    assert len(reqs) == len(arr)
    assert all(r.arrival == pytest.approx(a.t)
               for r, a in zip(reqs, sorted(arr, key=lambda x: x.t)))
    assert all(1 <= r.prompt_len <= 64 for r in reqs)
    assert all(8 <= r.max_new_tokens <= 16 for r in reqs)


# --- trace replay (load_trace_jsonl) ---------------------------------------

def test_load_trace_jsonl_fixture():
    from repro.core.workloads import INPUT_SIZES_M_ITEMS, spark_sim_suite
    apps = spark_sim_suite()
    arr = load_trace_jsonl(os.path.join(DATA, "trace_small.jsonl"), apps)
    assert [a.app.name for a in arr] == \
        ["HB.Kmeans", "BDB.Grep", "HB.Sort", "SB.PageRank", "SP.Pca"]
    assert [a.t for a in arr] == sorted(a.t for a in arr)
    assert arr[0].items == INPUT_SIZES_M_ITEMS["small"]
    assert arr[2].items == 4.0
    # byte-equivalent to hand-building the rows via trace_arrivals
    ref = trace_arrivals([(0.0, "HB.Kmeans", "small"),
                          (3.75, "BDB.Grep", 0.75),
                          (12.5, "HB.Sort", 4.0),
                          (21.0, "SB.PageRank", "medium"),
                          (40.25, "SP.Pca", "large")], apps)
    assert arr == ref


def test_load_trace_jsonl_rejects_bad_rows(tmp_path):
    from repro.core.workloads import spark_sim_suite
    apps = spark_sim_suite()
    for bad, msg in [('{"t": 1.0}', "need 't' and 'app'"),
                     ('{"t": 1.0, "app": "HB.Sort"}',
                      "'items' or 'size'"),
                     ('{"t": 1, "app": "HB.Sort", "size": "tiny"}',
                      "size class"),
                     ("not json", "bad JSON")]:
        p = tmp_path / "bad.jsonl"
        p.write_text(bad + "\n")
        with pytest.raises(ValueError, match=msg):
            load_trace_jsonl(str(p), apps)
    p = tmp_path / "unknown_app.jsonl"
    p.write_text('{"t": 1.0, "app": "NOPE", "items": 1.0}\n')
    with pytest.raises(KeyError):
        load_trace_jsonl(str(p), apps)


# --- calibrated footprint helper: the kv-growth estimator owns the
# cache; DemandModel.from_model_config is its deprecated shim ----------------

def test_from_model_config_caches_per_key(capsys):
    from repro.configs import get_config
    from repro.sched.estimator import _FOOTPRINT_CACHE
    cfg = get_config("qwen3-0.6b", smoke=True)
    _FOOTPRINT_CACHE.pop((cfg.name, 40), None)
    dm1 = DemandModel.from_model_config(cfg, 40)
    assert "fit" in capsys.readouterr().out
    dm2 = DemandModel.from_model_config(cfg, 40,
                                        host_ram_per_req_gb=0.01)
    assert "reused" in capsys.readouterr().out
    fn1, fn2 = dm1.primary_fn, dm2.primary_fn
    assert fn1.family == "affine"
    assert (fn1.m, fn1.b) == (fn2.m, fn2.b)      # same cached fit
    assert "host_ram" in dm2.curves and "host_ram" not in dm1.curves
    # a different max_len is a different key -> refit, steeper KV slope
    dm3 = DemandModel.from_model_config(cfg, 80)
    assert "fit" in capsys.readouterr().out
    assert dm3.primary_fn.b > fn1.b
    # refit=True bypasses the cache but reproduces the same pure fit
    dm4 = DemandModel.from_model_config(cfg, 40, refit=True)
    assert (dm4.primary_fn.m, dm4.primary_fn.b) == (fn1.m, fn1.b)
    sd = ServingDemand.from_demand_model(dm2, 40)
    assert sd.weights_gb == pytest.approx(fn1.m)
    assert sd.kv_gb_per_token == pytest.approx(fn1.b / 40)
    assert sd.host_ram_per_req_gb == pytest.approx(0.01)


# --- the real jax path ------------------------------------------------------

def _jax_engine(n_requests, max_len, seed=0, kv_slots=2.5, sync=8,
                new=(4, 10)):
    from repro.configs import get_config
    from repro.serve import JaxBackend
    cfg = get_config("qwen3-0.6b", smoke=True)
    dm = DemandModel.from_model_config(cfg, max_len)
    sd = ServingDemand.from_demand_model(dm, max_len)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt_len=int(rng.integers(4, max_len - new[1] - 1)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(i) * 1e-3)
            for i in range(n_requests)]
    budget = ResourceVector(
        hbm=sd.weights_gb + sd.kv_gb_per_token * max_len * kv_slots)
    eng = Engine(reqs, sd, budget,
                 JaxBackend(cfg, max_len=max_len, sync=sync, seed=seed),
                 mode="continuous", max_batch=8)
    return eng, eng.run()


@pytest.mark.slow
def test_jax_engine_smoke():
    """Real prefill/decode under step-level admission: joins, immediate
    retirement, exact token counts.  (@slow: ~4s of jit compiles — the
    fast tier keeps the batcher invariants on SimBackend; the CLI smoke
    and this test cover the jax path in the full suite.)"""
    eng, s = _jax_engine(4, max_len=32, kv_slots=2.5)
    assert s["completed"] == 4
    for r in eng.requests:
        assert len(r.tokens) == r.max_new_tokens
        assert all(isinstance(t, int) for t in r.tokens)
    for dec in eng.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced


@pytest.mark.slow
def test_jax_engine_restart_rounding_stays_in_bounds():
    """Regression: a restart prefill whose sync-rounded position would
    leave no room for the slowest joiner's remaining decode must clamp
    back (old code wrote KV past max_len via clamped dynamic updates)."""
    from repro.configs import get_config
    from repro.serve import JaxBackend
    cfg = get_config("qwen3-0.6b", smoke=True)
    max_len = 48
    dm = DemandModel.from_model_config(cfg, max_len)
    sd = ServingDemand.from_demand_model(dm, max_len)
    # prefill 30 rounds to 32 with sync=16, but 32 + 18 > 48
    reqs = [Request(rid=0, prompt_len=30, max_new_tokens=18, arrival=0.0),
            Request(rid=1, prompt_len=8, max_new_tokens=10, arrival=0.0)]
    be = JaxBackend(cfg, max_len=max_len, sync=16)
    eng = Engine(reqs, sd, ResourceVector(hbm=1.0), be, max_batch=4)
    s = eng.run()
    assert s["completed"] == 2
    assert all(len(r.tokens) == r.max_new_tokens for r in reqs)


@pytest.mark.slow
def test_jax_engine_preemption_and_recompute():
    """Tight budget on the real backend: eviction, requeue, KV recompute
    on rejoin — generated tokens survive the round trip."""
    eng, s = _jax_engine(8, max_len=48, kv_slots=1.5, new=(8, 16))
    assert s["completed"] == 8
    assert s["preemptions"] > 0
    for r in eng.requests:
        assert len(r.tokens) == r.max_new_tokens
