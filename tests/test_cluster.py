"""The event-driven ClusterRuntime substrate (repro/sched/cluster.py):

* EventLoop ordering/determinism — FIFO within a timestamp, identical
  seeded runs give identical schedules across 1..N replicas;
* Router registry round-trip + routing semantics (single / least-loaded
  / net-aware over per-node headroom);
* Node conservation — the claim ledger's booked vector equals the sum
  of live demands at every event, on both consumers;
* goldens — the legacy ``Simulator.run`` shim and the single-replica
  serving Engine are pinned bit-identical to their pre-runtime outputs
  (values captured from the pre-refactor code on the reference setup);
* multi-replica routing — 2 replicas routed ``net-aware`` beat
  ``single``-node routing under net contention;
* per-axis confidence shading — ``admit_target`` shades each memory
  axis by its own estimate confidence; the scalar conservative path
  survives as a deprecated, golden-pinned shim.
"""
import numpy as np
import pytest

from repro.core import (MoEPredictor, SimConfig, Simulator,
                        spark_sim_suite, training_apps)
from repro.core.simulator import OursPolicy
from repro.sched import (AdmissionController, ArrivalConfig,
                         ClusterRuntime, ClusterState, EventLoop, Node,
                         Router, available_routers, get_router,
                         poisson_arrivals, register_router)
from repro.sched.estimator import JobTarget, get_estimator
from repro.sched.resources import ResourceVector
from repro.serve import Engine, Request, ServingDemand, SimBackend


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def make_requests(n, seed=0, rate=20.0, prompt=(8, 32), new=(8, 40)):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i,
                    prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i]))
            for i in range(n)]


# --- EventLoop ---------------------------------------------------------------

def test_event_loop_time_order_and_fifo_ties():
    loop = EventLoop()
    loop.push(2.0, "b", None)
    loop.push(1.0, "a", None)
    loop.push(1.0, "c", None)      # same t as "a": FIFO, not kind order
    loop.push(0.5, "d", None)
    popped = [(t, kind) for t, _, kind, _ in
              (loop.pop() for _ in range(4))]
    assert popped == [(0.5, "d"), (1.0, "a"), (1.0, "c"), (2.0, "b")]
    assert not loop and len(loop) == 0 and loop.peek_t() is None


def test_runtime_dispatch_stale_and_until():
    rt = ClusterRuntime(ClusterState.homogeneous(1, ResourceVector(hbm=1)))
    seen, ticks = [], []
    rt.on("ev", lambda t, p: seen.append((t, p)))
    rt.on("stale", lambda t, p: False)       # stale: no tick
    rt.push(1.0, "ev", "x")
    rt.push(2.0, "stale", None)
    rt.push(3.0, "ev", "y")
    rt.push(9.0, "ev", "never")              # until() stops before it
    end = rt.run(tick=ticks.append, until=lambda: len(seen) >= 2)
    assert seen == [(1.0, "x"), (3.0, "y")]
    assert ticks == [1.0, 3.0]               # the stale event didn't tick
    assert end == rt.t == 3.0
    with pytest.raises(KeyError, match="no handler"):
        rt.push(0.0, "unknown", None)
        rt.run()


def test_runtime_max_time_does_not_advance_clock():
    rt = ClusterRuntime(ClusterState.homogeneous(1, ResourceVector(hbm=1)))
    rt.on("ev", lambda t, p: None)
    rt.push(1.0, "ev", None)
    rt.push(50.0, "ev", None)
    assert rt.run(max_time=10.0) == 1.0      # the 50.0 event was dropped


# --- Node / ClusterState -----------------------------------------------------

def test_node_ledger_book_rebook_release():
    node = Node(0, ResourceVector(hbm=4.0, net=1.0))
    node.book("a", ResourceVector(hbm=1.0, net=0.25))
    node.book("b", ResourceVector(hbm=0.5))
    with pytest.raises(KeyError, match="already booked"):
        node.book("a", ResourceVector(hbm=1.0))
    assert node.headroom() == ResourceVector(hbm=2.5, net=0.75)
    assert node.utilization("hbm") == pytest.approx(1.5 / 4.0)
    assert node.utilization("host_ram") == 0.0   # uncapacitated axis
    node.rebook("a", ResourceVector(hbm=2.0, net=0.25))
    assert node.headroom()["hbm"] == pytest.approx(1.5)
    with pytest.raises(KeyError, match="not booked"):
        node.rebook("zzz", ResourceVector(hbm=1.0))
    assert node.release("b") == ResourceVector(hbm=0.5)
    assert node.n_claims == 1 and "a" in node and "b" not in node
    node.record_binding("net")
    node.record_binding("net")
    cluster = ClusterState([node, Node(1, ResourceVector(hbm=4.0))])
    cluster[1].record_binding("hbm")
    assert cluster.binding_axes() == {"net": 2, "hbm": 1}
    assert len(cluster.headroom()) == 2


# --- Router registry ---------------------------------------------------------

def test_router_registry_round_trip():
    assert {"single", "least-loaded", "net-aware"} <= \
        set(available_routers())
    for name in available_routers():
        r = get_router(name)
        assert isinstance(r, Router) and r.name == name
    with pytest.raises(KeyError, match="unknown router"):
        get_router("nope")

    @register_router("test-router")
    class TestRouter(Router):
        def route(self, demand, nodes, now=0.0):
            return nodes[-1]
    try:
        assert isinstance(get_router("test-router"), TestRouter)
        assert "test-router" in available_routers()
    finally:
        from repro.sched import cluster as cluster_mod
        del cluster_mod._REGISTRY["test-router"]
    with pytest.raises(TypeError):
        register_router("bad")(object)


def test_router_semantics_over_headroom():
    cap = ResourceVector(hbm=4.0, net=1.0)
    cluster = ClusterState.homogeneous(3, cap)
    cluster[0].book("x", ResourceVector(hbm=3.0, net=0.2))
    cluster[1].book("y", ResourceVector(hbm=1.0, net=0.8))
    # node 2 is empty
    demand = ResourceVector(hbm=0.5, net=0.1)
    assert get_router("single").route(demand, cluster.nodes).nid == 0
    assert get_router("least-loaded").route(demand, cluster.nodes).nid == 2
    assert get_router("net-aware").route(demand, cluster.nodes).nid == 2
    # net-aware keys on the net axis FIRST, least-loaded on the worst
    # axis: node 0 has more net headroom (0.5 vs 0.4) but a worse
    # worst-axis fraction (hbm 0.3 vs 0.4), so the two routers diverge
    pair = ClusterState.homogeneous(2, cap)
    pair[0].book("x", ResourceVector(hbm=2.8, net=0.5))
    pair[1].book("y", ResourceVector(hbm=2.2, net=0.6))
    assert get_router("net-aware").route(demand, pair.nodes).nid == 0
    assert get_router("least-loaded").route(demand, pair.nodes).nid == 1
    # down nodes are skipped (node 2 would otherwise win outright)
    cluster[2].up = False
    assert get_router("least-loaded").route(demand, cluster.nodes).nid == 0
    # ties resolve to the lowest nid (stable/deterministic)
    fresh = ClusterState.homogeneous(3, cap)
    assert get_router("least-loaded").route(demand, fresh.nodes).nid == 0
    assert get_router("net-aware").route(demand, fresh.nodes).nid == 0


# --- goldens: the legacy paths are bit-identical over the runtime -----------
# Values captured from the PRE-ClusterRuntime code (PR 4 tree) on the
# reference scenario; rel=1e-12 keeps the pin at float-print precision
# while tolerating last-bit library drift.

BATCH_GOLDEN = {
    "stp": 3.252231962950136, "antt": 1.2652251063617623,
    "makespan": 149.4293231807283, "oom_count": 0,
    "binding_axes": {"cap": 96},
    "finish_times": [97.03756132236386, 51.49319535335683,
                     149.4293231807283, 139.06547957694463]}

OPEN_GOLDEN = {
    "stp": 6.948619990727461, "antt": 14.319200085684232,
    "makespan": 19085.733991463447, "oom_count": 0,
    "binding_axes": {"cap": 324, "host_ram": 85}}


def _pin(out, golden):
    for k, v in golden.items():
        if isinstance(v, float):
            assert out[k] == pytest.approx(v, rel=1e-12), k
        elif isinstance(v, list):
            assert out[k] == pytest.approx(v, rel=1e-12), k
        else:
            assert out[k] == v, k


def test_simulator_shim_matches_prerefactor_batch_golden(suite):
    apps, moe = suite
    jobs = [(apps[i], 30.0) for i in (0, 5, 11, 17)]
    sim = Simulator(jobs, OursPolicy(moe), SimConfig(n_hosts=6), seed=3)
    out = sim.run()
    _pin(out, BATCH_GOLDEN)
    # the shim really runs on the shared substrate
    assert isinstance(sim.runtime, ClusterRuntime)
    assert sim.binding_axes == sim.cluster.binding_axes()
    # drained run: every executor claim was released back to its node
    assert all(n.n_claims == 0 for n in sim.cluster)


def test_simulator_shim_matches_prerefactor_open_golden(suite):
    apps, moe = suite
    arrivals = poisson_arrivals(
        apps, ArrivalConfig(rate_per_s=0.05, n_jobs=12), seed=5)
    out = Simulator(None, OursPolicy(moe), SimConfig(n_hosts=8), seed=5,
                    arrivals=arrivals).run()
    _pin(out, OPEN_GOLDEN)


def test_simulator_nodes_conserve_booked_claims(suite):
    """booked == sum of live executor claim vectors at every spawn and
    removal — the Node-ledger conservation invariant on the simulator."""
    apps, moe = suite
    arrivals = poisson_arrivals(
        apps, ArrivalConfig(rate_per_s=0.1, n_jobs=10), seed=2)
    sim = Simulator(None, OursPolicy(moe), SimConfig(n_hosts=6), seed=2,
                    arrivals=arrivals)
    orig_spawn, orig_remove = sim._spawn, sim._remove_exec

    def check(host):
        booked = host.node.booked
        live = ResourceVector()
        for e in host.execs:
            live = live + e.claimed_vec
        for a in set(booked.axes) | set(live.axes):
            assert booked.get(a) == pytest.approx(live.get(a)), a
        assert host.node.n_claims == len(host.execs)

    def spawn_spy(job, host, items, mt, mc, delay=0.0):
        e = orig_spawn(job, host, items, mt, mc, delay)
        check(host)
        return e

    def remove_spy(e, requeue):
        host = e.host
        orig_remove(e, requeue)
        check(host)

    sim._spawn, sim._remove_exec = spawn_spy, remove_spy
    out = sim.run()
    assert all(j.finish is not None for j in sim.jobs)
    assert all(n.n_claims == 0 for n in sim.cluster)


SERVE_CONT_GOLDEN = {
    "goodput_tok_s": 355.69049875467294,
    "elapsed_s": 1.7374655836006665, "steps": 182, "completed": 24,
    "preemptions": 6, "forced_steps": 0,
    "ttft_mean_s": 0.03621988061252291,
    "binding_axes": {"hbm": 17, "host_ram": 6}}

SERVE_WAVE_GOLDEN = {
    "goodput_tok_s": 295.6942616173603,
    "elapsed_s": 2.0899965951984405, "steps": 251, "completed": 24,
    "preemptions": 0, "forced_steps": 0,
    "ttft_mean_s": 0.2374850961944661, "binding_axes": {"hbm": 4}}


def _reference_engine(mode, **kw):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           host_ram_per_req_gb=0.01)
    full = 32 + 40
    budget = ResourceVector(hbm=0.5 + 2e-4 * full * 3.0,
                            host_ram=0.01 * 6.0)
    if kw.get("replicas", 1) == 1:
        kw.setdefault("backend", SimBackend())
    return Engine(make_requests(24, seed=0), demand, budget,
                  mode=mode, placement="fcfs", max_batch=16, **kw)


@pytest.mark.parametrize("mode,golden", [
    ("continuous", SERVE_CONT_GOLDEN), ("wave", SERVE_WAVE_GOLDEN)])
def test_single_replica_engine_matches_prerefactor_golden(mode, golden):
    eng = _reference_engine(mode)
    out = eng.run()
    _pin(out, golden)
    assert out["node_steps"] == {0: golden["steps"]}


def test_engine_nodes_conserve_booked_claims():
    """booked == weights + sum of committed request demand vectors
    (running + locally queued) after EVERY step event, across replicas
    (the serving-side Node-ledger conservation invariant)."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 3.0, net=0.3)
    eng = Engine(make_requests(24, seed=1, rate=50.0), demand, budget,
                 replicas=2, router="net-aware", max_batch=16)
    orig = eng._sync_node
    checks = [0]

    def spy(ridx):
        orig(ridx)
        node = eng.runtime.cluster[ridx]
        expect = ResourceVector(hbm=demand.weights_gb)
        for r in eng._running[ridx] + eng._pending[ridx]:
            expect = expect + demand.request_vector(r)
        booked = node.booked
        for a in set(booked.axes) | set(expect.axes):
            assert booked.get(a) == pytest.approx(expect.get(a)), a
        checks[0] += 1

    eng._sync_node = spy
    s = eng.run()
    assert s["completed"] == 24 and checks[0] > 0
    # drained: only the weights claim remains on each node
    assert all(n.n_claims == 1 for n in eng.runtime.cluster)


def test_burst_arrivals_spread_across_replicas():
    """Simultaneous arrivals (rate 0: everything at t=0) must still
    spread: routing books a queued request's demand on its node
    immediately, so the next route() call sees shrunk headroom instead
    of tying every request to node 0."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 8.0, net=0.4)
    reqs = [Request(rid=i, prompt_len=16, max_new_tokens=16,
                    arrival=0.0) for i in range(12)]
    eng = Engine(reqs, demand, budget, replicas=2, router="net-aware",
                 max_batch=8)
    s = eng.run()
    assert s["completed"] == 12
    assert set(s["node_steps"]) == {0, 1}, s["node_steps"]


def test_multi_replica_seeded_determinism():
    runs = []
    for _ in range(2):
        eng = _reference_engine("continuous", replicas=2,
                                router="least-loaded")
        eng.run()
        runs.append([(d.step, d.node, d.admitted, d.preempted, d.batch,
                      d.forced, d.binding_axis, d.t)
                     for d in eng.metrics.steps])
    assert runs[0] == runs[1]
    assert {n for _, n, *_ in runs[0]} == {0, 1}   # both replicas ran


def test_two_replica_net_aware_beats_single_routing():
    """The acceptance bar for routing being real: under net contention,
    net-aware routing over 2 replicas out-serves single-node routing."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 8.0, net=0.25)
    out = {}
    for router in ("net-aware", "single"):
        eng = Engine(make_requests(32, seed=3, rate=50.0), demand,
                     budget, replicas=2, router=router, max_batch=16)
        out[router] = eng.run()
    assert out["net-aware"]["goodput_tok_s"] > \
        out["single"]["goodput_tok_s"] * 1.05
    # the single router really used one node; net-aware used both
    assert set(out["single"]["node_steps"]) == {0}
    assert set(out["net-aware"]["node_steps"]) == {0, 1}


def test_engine_rejects_bad_replica_configs():
    demand = ServingDemand(weights_gb=0.1, kv_gb_per_token=1e-4)
    reqs = make_requests(2)
    with pytest.raises(ValueError, match="replicas must be"):
        Engine(reqs, demand, 1.0, replicas=0)
    with pytest.raises(ValueError, match="wave mode"):
        Engine(reqs, demand, 1.0, mode="wave", replicas=2)
    with pytest.raises(ValueError, match="one per replica"):
        Engine(reqs, demand, 1.0, SimBackend(), replicas=2)
    with pytest.raises(ValueError, match="2 backends"):
        Engine(reqs, demand, 1.0, replicas=3,
               backends=[SimBackend(), SimBackend()])


# --- per-axis confidence shading (satellite) --------------------------------

def test_effective_budget_per_axis_confidence():
    ctrl = AdmissionController()
    free = ResourceVector(host_ram=64.0, hbm=32.0, cpu=1.0, net=10.0)
    shaded = ctrl.effective_budget(
        free, confidence={"host_ram": 1.0, "hbm": 0.0, "net": 0.0})
    assert shaded["host_ram"] == pytest.approx(64.0)   # full confidence
    assert shaded["hbm"] == pytest.approx(16.0)        # zero -> halved
    assert shaded["net"] == pytest.approx(10.0)        # non-memory axis
    assert shaded["cpu"] == pytest.approx(1.0)
    # linear in between, composed with margin/backoff exactly like the
    # scalar rules
    mid = ctrl.effective_budget(free, confidence={"host_ram": 0.5})
    assert mid["host_ram"] == pytest.approx(64.0 * 0.75)
    both = ctrl.effective_budget(free, safety_margin=0.25, oom_count=1,
                                 confidence={"host_ram": 0.5})
    assert both["host_ram"] == pytest.approx(64.0 * 0.75 * 0.75 * 0.5)
    # memory axes NOT in the confidence map keep the scalar flag path
    part = ctrl.effective_budget(free, conservative=True,
                                 confidence={"host_ram": 1.0})
    assert part["host_ram"] == pytest.approx(64.0)
    assert part["hbm"] == pytest.approx(16.0)


def test_admit_target_per_axis_vs_scalar_shading(suite):
    apps, moe = suite
    free = ResourceVector(host_ram=32.0, cpu=1.0)
    target = JobTarget(apps[0], 100.0)
    # the conservative estimator reports zero confidence on every axis,
    # so per-axis shading reproduces the scalar halving bit-for-bit —
    # the golden pinning the deprecated shim
    cons = AdmissionController(estimator="conservative")
    dec_axis = cons.admit_target(target, free,
                                 rng=np.random.default_rng(0))
    with pytest.warns(DeprecationWarning, match="scalar"):
        dec_scalar = cons.admit_target(target, free, shading="scalar",
                                       rng=np.random.default_rng(0))
    assert dec_axis.units == dec_scalar.units
    assert dec_axis.budget_gb == dec_scalar.budget_gb == \
        pytest.approx(16.0)
    # a confident moe estimate keeps (most of) its budget under
    # per-axis shading instead of being halved wholesale
    ctrl = AdmissionController(estimator=get_estimator(
        "moe", predictor=moe))
    dec = ctrl.admit_target(target, free, rng=np.random.default_rng(0))
    est = dec.info["estimate"]
    conf = est.confidence["host_ram"]
    expect = 32.0 * (0.5 + 0.5 * min(max(conf, 0.0), 1.0))
    assert dec.budget_gb == pytest.approx(expect)
    with pytest.raises(ValueError, match="unknown shading"):
        ctrl.admit_target(target, free, shading="nope")


# --- SLO fields + slo_goodput (satellite) -----------------------------------

def test_slo_goodput_counts_only_requests_within_deadlines():
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    reqs = make_requests(16, seed=4, rate=100.0)
    for r in reqs:        # tight TTFT under a contended budget: some miss
        r.ttft_deadline = 0.05
        r.tpot_deadline = 0.05
    eng = Engine(reqs, demand,
                 ResourceVector(hbm=0.5 + 2e-4 * 72 * 2.0),
                 SimBackend(), max_batch=8)
    s = eng.run()
    assert s["completed"] == 16
    met = [r for r in reqs if r.meets_slo()]
    assert 0 < len(met) < 16          # the deadline actually separates
    assert s["slo_good_tokens"] == sum(r.tokens_decoded for r in met)
    assert s["slo_goodput_tok_s"] < s["goodput_tok_s"]
    assert s["slo_attainment"] == pytest.approx(len(met) / 16)
    # no deadlines -> SLO vacuously met, slo goodput == goodput
    eng2 = _reference_engine("continuous")
    s2 = eng2.run()
    assert s2["slo_goodput_tok_s"] == pytest.approx(s2["goodput_tok_s"])
    assert s2["slo_attainment"] == 1.0


# --- unified forced-admission record (satellite) ----------------------------

@pytest.mark.parametrize("mode", ["continuous", "wave"])
def test_forced_record_shape_unified_across_modes(mode):
    """Budget below the weights: every step is forced and every forced
    step names the rids it force-ran — the ONE record shape both the
    batcher floor and the legacy wave path now fill."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    eng = Engine(make_requests(5, seed=3, new=(4, 8)), demand,
                 ResourceVector(hbm=0.4), SimBackend(), mode=mode)
    s = eng.run()
    assert s["completed"] == 5
    assert s["forced_steps"] == s["steps"] > 0
    for dec in eng.metrics.steps:
        assert dec.forced and dec.forced_rids and dec.forced_axes
        assert dec.batch == 1
        assert set(dec.forced_rids) <= {r.rid for r in eng.requests}
    assert s["forced_admissions"] >= s["forced_steps"]
