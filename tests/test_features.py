"""Compiled-artifact feature extraction (the 22 TPU features)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.features import (TPU_FEATURE_NAMES, extract_features,
                                 features_from_record)


def test_feature_vector_shape_and_finiteness():
    cfg = get_config("qwen3-0.6b", smoke=True)
    f = extract_features(cfg, "train", probe_seq=32, probe_batch=2)
    assert f.shape == (22,)
    assert np.all(np.isfinite(f))
    assert len(TPU_FEATURE_NAMES) == 22


@pytest.mark.slow
def test_features_separate_architecture_families():
    """Attention-free vs dense archs produce distinct feature vectors —
    the property the KNN expert selector relies on."""
    dense = extract_features(get_config("qwen3-0.6b", smoke=True),
                             "train", 32, 2)
    ssm = extract_features(get_config("mamba2-780m", smoke=True),
                           "train", 32, 2)
    moe = extract_features(get_config("qwen3-moe-30b-a3b", smoke=True),
                           "train", 32, 2)
    assert np.linalg.norm(dense - ssm) > 1.0
    assert np.linalg.norm(dense - moe) > 1.0


def test_features_from_dryrun_record():
    rec = {
        "roofline": {"compute_s": 1.0, "memory_s": 3.0,
                     "collective_s": 1.0},
        "cost": {"flops_per_device": 1e12, "hbm_bytes_per_device": 1e10},
        "memory": {"argument_bytes": 2 ** 30, "temp_bytes": 2 ** 32,
                   "output_bytes": 2 ** 30},
        "collectives": {"total_bytes": 1e9,
                        "bytes": {"all-reduce": 8e8, "all-gather": 2e8},
                        "counts": {"all-reduce": 10, "all-gather": 4}},
        "hlo_ops": {"dot": 30, "fusion": 100, "while": 2},
        "loops": [{"trip": 24}, {"trip": 24}],
        "params_total": 1e9,
        "tokens": 4096,
    }
    f = features_from_record(rec)
    names = dict(zip(TPU_FEATURE_NAMES, f))
    assert abs(names["log_flops"] - 12.0) < 1e-6
    assert abs(names["coll_allreduce_frac"] - 0.8) < 1e-6
    assert names["loop_trip_mean"] == 24.0
    assert abs(names["memory_term_share"] - 0.6) < 1e-6


@pytest.mark.parametrize("kind", ["train", "decode"])
def test_extract_both_step_kinds(kind):
    cfg = get_config("qwen3-0.6b", smoke=True)
    f = extract_features(cfg, kind, probe_seq=32, probe_batch=2)
    assert np.all(np.isfinite(f))
