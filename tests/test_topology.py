"""The network-topology subsystem (repro/sched/topology.py):

* Link / path mechanics — preset registry round-trip, deterministic
  shortest-hop BFS, latency and bottleneck-bandwidth aggregation;
* Transmission timing — single flow lands at exactly
  ``start + latency + gb / bandwidth``; staggered flows fair-share the
  link (the classic 1-then-2-then-1 flow schedule, hand-computed);
* the satellite property sweep — concurrent transmissions on shared
  links CONSERVE bytes, and no fair-share completion ever beats the
  exclusive-bandwidth lower bound ``start + latency + gb / B``;
* the ``topo-aware`` router — degrades to least-loaded without a bound
  topology, avoids the congested path with one;
* measured net curves — ``ModelTarget.net_probes`` feeds observed
  (bytes, duration) pairs through the two-point family-selection fit
  on BOTH the kv-growth and moe estimators;
* engine integration — KV migration on the two-rack fabric fires and
  conserves tokens; heterogeneous per-replica budgets skew
  least-loaded routing toward the big node;
* goldens — ``topology=None`` (the default) keeps the 2-replica
  net-aware engine BIT-IDENTICAL to the pre-topology capture, and an
  attached-but-inert topology (no ingress payload, no migration)
  changes nothing either; ``net-aware`` stays registered as the
  deprecated per-node-counter shim.
"""
import numpy as np
import pytest

from repro.sched import (ClusterRuntime, ClusterState, Node,
                         ResourceVector, Topology, available_routers,
                         available_topologies, get_router, get_topology)
from repro.sched.estimator import ModelTarget, get_estimator
from repro.serve import Engine, Request, ServingDemand, SimBackend


def make_runtime():
    return ClusterRuntime(
        ClusterState.homogeneous(1, ResourceVector(hbm=1.0)))


def make_requests(n, seed=0, rate=20.0, prompt=(8, 32), new=(8, 40)):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i,
                    prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i]))
            for i in range(n)]


# --- presets + paths ---------------------------------------------------------

def test_preset_registry_round_trip():
    assert set(available_topologies()) >= {"single-switch", "two-rack",
                                           "ring"}
    with pytest.raises(KeyError, match="unknown topology"):
        get_topology("fat-tree")
    for name in available_topologies():
        topo = get_topology(name, nodes=4)
        assert topo.ingress is not None
        for nid in range(4):
            assert topo.has_node(Topology.replica_name(nid))
            topo.path(topo.ingress, Topology.replica_name(nid))


def test_two_rack_splits_halves_and_paths():
    topo = get_topology("two-rack", nodes=4, gbps=10.0,
                        uplink_gbps=(1.0, 4.0))
    # first half on rack0, second on rack1
    assert [l.dst for l in topo.path("ingress", "n1")][1] == "rack0"
    assert [l.dst for l in topo.path("ingress", "n2")][1] == "rack1"
    # bottleneck bandwidth is the rack uplink
    assert topo.exclusive_gbps("ingress", "n0") == 1.0
    assert topo.exclusive_gbps("ingress", "n3") == 4.0
    # intra-rack migration path never crosses an uplink
    assert topo.exclusive_gbps("n2", "n3") == 10.0
    with pytest.raises(ValueError, match=">= 2 nodes"):
        get_topology("two-rack", nodes=1)


def test_path_lookup_determinism_and_errors():
    topo = get_topology("ring", nodes=4)
    assert topo.path("n0", "n0") == ()
    # shortest-hop both ways round the ring, deterministic on re-query
    assert topo.path("n0", "n1") == topo.path("n0", "n1")
    assert len(topo.path("n0", "n2")) == 2
    with pytest.raises(KeyError, match="unknown topology node"):
        topo.path("n0", "n9")
    lonely = Topology("lonely")
    lonely.add_node("a")
    lonely.add_node("b")
    with pytest.raises(KeyError, match="no path"):
        lonely.path("a", "b")
    with pytest.raises(ValueError, match="bandwidth"):
        lonely.add_link("a", "b", 0.0)
    with pytest.raises(KeyError, match="add_node"):
        lonely.add_link("a", "zzz", 1.0)


# --- transmission timing -----------------------------------------------------

def test_single_flow_exact_timing_and_probe():
    topo = get_topology("single-switch", nodes=2, gbps=2.0,
                        latency_s=0.01).attach(make_runtime())
    done = []
    tr = topo.transmit("ingress", "n0", 1.0, now=0.0, tag="t",
                       on_complete=lambda t, x: done.append(t))
    topo._runtime.run()
    # 2 hops x 10ms pipe delay, then 1 GB at the full 2 GB/s
    assert done == [pytest.approx(0.02 + 0.5)]
    assert tr.finish_t == pytest.approx(0.52)
    assert tr.duration_s == pytest.approx(0.52)
    assert topo.net_probes("t") == ((1.0, pytest.approx(0.52)),)
    assert topo.in_flight == 0 and not topo._started()


def test_same_node_and_zero_byte_transfers_complete():
    topo = get_topology("single-switch", nodes=2,
                        latency_s=0.25).attach(make_runtime())
    a = topo.transmit("n0", "n0", 5.0, now=1.0)
    b = topo.transmit("ingress", "n0", 0.0, now=1.0)
    topo._runtime.run()
    assert a.finish_t == pytest.approx(1.0)      # no hops, no latency
    assert b.finish_t == pytest.approx(1.5)      # latency only
    # zero-byte transfers never pollute the measured probes
    assert topo.net_probes() == ((5.0, pytest.approx(0.0, abs=1e-12)),) \
        or all(gb > 0.0 for gb, _ in topo.net_probes())


def test_fair_share_staggered_flows_hand_computed():
    """1 GB at t=0 and 1 GB at t=0.5 over one 1 GB/s link: the first
    flow runs alone (0.5 GB done), both halve to 0.5 GB/s until the
    first finishes at 1.5, the second then finishes alone at 2.0."""
    topo = Topology("pair")
    topo.add_node("a")
    topo.add_node("b")
    topo.add_link("a", "b", 1.0)
    topo.attach(make_runtime())
    t1 = topo.transmit("a", "b", 1.0, now=0.0)
    t2 = topo.transmit("a", "b", 1.0, now=0.5)
    topo._runtime.run()
    assert t1.finish_t == pytest.approx(1.5)
    assert t2.finish_t == pytest.approx(2.0)
    assert t1.done_gb == pytest.approx(1.0)
    assert t2.done_gb == pytest.approx(1.0)


def test_estimate_transfer_accounts_current_contention():
    topo = Topology("pair")
    topo.add_node("a")
    topo.add_node("b")
    link = topo.add_link("a", "b", 2.0, latency_s=0.1)
    topo.attach(make_runtime())
    assert topo.estimate_transfer_s("a", "b", 1.0) \
        == pytest.approx(0.1 + 1.0 / 2.0)
    link.flows[99] = None            # one flow in flight: residual halves
    assert topo.estimate_transfer_s("a", "b", 1.0) \
        == pytest.approx(0.1 + 1.0 / 1.0)
    assert topo.estimate_transfer_s("a", "a", 123.0) == 0.0


# --- the satellite property sweep -------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_property_bytes_conserved_and_exclusive_lower_bound(seed):
    """Random concurrent transmissions over a shared fabric: every
    byte arrives exactly once, and no completion beats the
    exclusive-bandwidth lower bound ``start + latency + gb / B``."""
    rng = np.random.default_rng(seed)
    topo = get_topology("two-rack", nodes=4,
                        gbps=float(rng.uniform(1.0, 10.0)),
                        uplink_gbps=(float(rng.uniform(0.2, 2.0)),
                                     float(rng.uniform(0.2, 2.0))),
                        latency_s=float(rng.uniform(0.0, 0.05)))
    topo.attach(make_runtime())
    names = list(topo.nodes())
    sent = []
    t0 = 0.0
    for _ in range(40):
        t0 += float(rng.exponential(0.05))
        src, dst = rng.choice(names, size=2, replace=False)
        sent.append(topo.transmit(str(src), str(dst),
                                  float(rng.uniform(0.01, 2.0)),
                                  now=t0, tag="sweep"))
    topo._runtime.run()
    assert topo.in_flight == 0
    assert len(topo.completed("sweep")) == len(sent)
    for tr in sent:
        # conservation: the transfer delivered exactly its payload
        assert tr.done_gb == pytest.approx(tr.gb)
        # fair share can only ever be <= the exclusive bandwidth
        lower = tr.start_t + topo.latency_s(tr.src, tr.dst) \
            + tr.gb / topo.exclusive_gbps(tr.src, tr.dst)
        assert tr.finish_t >= lower - 1e-9, (tr, lower)
    # per-link ledgers fully drained
    assert all(l.n_flows == 0 for l in topo.links())


# --- the topo-aware router ---------------------------------------------------

def _nodes(n, hbm=1.0):
    return [Node(i, ResourceVector(hbm=hbm)) for i in range(n)]


def test_topo_aware_degrades_to_least_loaded_without_topology():
    router = get_router("topo-aware")
    assert router.uses_topology and router.topology is None
    nodes = _nodes(2)
    nodes[0].book("x", ResourceVector(hbm=0.8))
    picked = router.route(ResourceVector(hbm=0.1), nodes)
    assert picked.nid == 1                       # most headroom wins


def test_topo_aware_routes_by_path_residual_headroom():
    topo = get_topology("two-rack", nodes=4, gbps=10.0,
                        uplink_gbps=(2.0, 3.0))
    router = get_router("topo-aware")
    router.topology = topo
    nodes = _nodes(4)
    # idle fabric: rack1 uplink (3.0) beats rack0 (2.0) -> lowest-nid
    # rack1 node
    assert router.route(ResourceVector(hbm=0.1), nodes).nid == 2
    # two flows on the rack1 uplink drop its residual to 1.0 < 2.0
    uplink = [l for l in topo.path("ingress", "n2")
              if l.src == "core"][0]
    uplink.flows.update({97: None, 98: None})
    assert router.route(ResourceVector(hbm=0.1), nodes).nid == 0
    # a node off the fabric is the last resort
    nodes.append(Node(9, ResourceVector(hbm=1.0)))
    assert router.route(ResourceVector(hbm=0.1), nodes).nid == 0


def test_net_aware_shim_stays_registered():
    assert "net-aware" in available_routers()
    assert "topo-aware" in available_routers()
    assert not getattr(get_router("net-aware"), "uses_topology", False)


# --- measured net curves through the estimator registry ---------------------

def _make_estimator(name):
    if name != "moe":
        return get_estimator(name)
    from repro.core import MoEPredictor, spark_sim_suite, training_apps
    moe = MoEPredictor().fit(training_apps(spark_sim_suite()))
    return get_estimator("moe", predictor=moe)


@pytest.mark.parametrize("est", ["kv-growth", "moe"])
def test_estimator_learns_net_curve_from_probes(est):
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b", smoke=True)
    # linear duration == bytes: effective 1 GB/s per request, clean fit
    probes = tuple((gb, gb) for gb in (0.01, 0.02, 0.04, 0.08))
    de = _make_estimator(est).estimate(
        ModelTarget(cfg, 48, net_gbps_per_req=0.25, net_probes=probes))
    info = de.info["net_measured"]
    assert info["n_probes"] == len(probes)
    assert info["gbps_per_req"] == pytest.approx(1.0, rel=1e-6)
    # measured curve replaces the declared 0.25 constant
    assert de.model.curves["net"].b == pytest.approx(1.0, rel=1e-6)
    assert de.confidence["net"] == pytest.approx(1.0, abs=0.05)
    sd = ServingDemand.from_estimate(de, 48)
    assert sd.extra_axes["net"] == pytest.approx(1.0, rel=1e-6)


def test_estimator_keeps_declared_net_without_usable_probes():
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b", smoke=True)
    for probes in (None, (), ((0.01, 0.01),), ((0.0, 1.0), (-1.0, 2.0))):
        de = get_estimator("kv-growth").estimate(
            ModelTarget(cfg, 48, net_gbps_per_req=0.25,
                        net_probes=probes))
        assert de.model.curves["net"].b == 0.25
        assert de.info.get("net_measured") is None


def test_engine_probes_round_trip_into_estimator():
    """End to end: run a topology-bound engine, feed its observed
    transmissions back through the estimator."""
    from repro.configs import get_config
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 8.0)
    topo = get_topology("single-switch", nodes=2, gbps=1.0)
    eng = Engine(make_requests(12, seed=4, rate=100.0), demand, budget,
                 replicas=2, router="topo-aware", max_batch=16,
                 topology=topo, ingress_gb_per_token=1e-3)
    eng.run()
    probes = topo.net_probes("ingress")
    assert len(probes) == 12
    cfg = get_config("qwen3-0.6b", smoke=True)
    de = get_estimator("kv-growth").estimate(
        ModelTarget(cfg, 48, net_gbps_per_req=0.1, net_probes=probes))
    assert de.info["net_measured"] is not None
    assert de.model.curves["net"].b != 0.1


# --- engine integration: migration + heterogeneous budgets ------------------

def _topo_engine(migrate, router="topo-aware"):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 56 * 2.5, net=1.0)
    topo = get_topology("two-rack", nodes=4, gbps=10.0,
                        uplink_gbps=(0.2, 4.0))
    reqs = [Request(rid=r.rid, prompt_len=r.prompt_len,
                    max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                    ttft_deadline=0.5, tpot_deadline=0.05)
            for r in make_requests(24, seed=9, rate=120.0,
                                   prompt=(12, 25), new=(8, 33))]
    return Engine(reqs, demand, budget, mode="continuous",
                  placement="fcfs", max_batch=32, replicas=4,
                  router=router,
                  backends=[SimBackend(t_prefill_per_token=2e-3)
                            for _ in range(4)],
                  topology=topo, migrate=migrate,
                  ingress_gb_per_token=2e-3)


def test_kv_migration_fires_and_conserves_tokens():
    eng = _topo_engine(migrate=True)
    out = eng.run()
    assert out["completed"] == 24
    assert out["preemptions"] > 0
    assert out["migrations"] > 0
    assert out["kv_transfer_p99_s"] > 0.0
    # every request still produced its full decode budget exactly
    # once — adoption neither duplicated nor dropped a token
    for r in eng.requests:
        assert r.done and len(r.tokens) == r.max_new_tokens
    # migrated KV moved over real links: transfers logged with durations
    assert len(eng.topology.transfer_times("kv-migration")) \
        == out["migrations"]


def test_migration_beats_local_requeue_on_contended_fabric():
    mig = _topo_engine(migrate=True).run()
    req = _topo_engine(migrate=False).run()
    assert mig["migrations"] > 0 and req["migrations"] == 0
    # recompute burns virtual time; adopting shipped KV does not
    assert mig["goodput_tok_s"] > req["goodput_tok_s"]


def test_migrate_requires_topology():
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    with pytest.raises(ValueError, match="migrate"):
        Engine(make_requests(4), demand, ResourceVector(hbm=1.0),
               backend=SimBackend(), migrate=True)


def test_heterogeneous_budgets_skew_least_loaded():
    demand = ServingDemand(weights_gb=0.1, kv_gb_per_token=2e-4)
    big = ResourceVector(hbm=0.1 + 2e-4 * 72 * 8.0)
    small = ResourceVector(hbm=0.1 + 2e-4 * 72 * 2.0)
    eng = Engine(make_requests(24, seed=2, rate=200.0), demand, big,
                 replicas=2, router="least-loaded", max_batch=16,
                 budgets=[big, small])
    out = eng.run()
    assert out["completed"] == 24
    # the 4x node holds more in-flight work than the small one
    assert out["node_steps"][0] > out["node_steps"][1]
    with pytest.raises(ValueError, match="budgets"):
        Engine(make_requests(4), demand, big, replicas=2,
               router="least-loaded", budgets=[big])


# --- goldens: topology=None stays bit-identical ------------------------------

# captured on this setup immediately BEFORE the topology subsystem
# landed (2 replicas routed net-aware, no fabric): the topology=None
# default must keep reproducing these bits forever
NET_AWARE_2R_GOLDEN = {
    "goodput_tok_s": 539.4329169629722,
    "elapsed_s": 1.4886002962535556,
    "steps": 403, "completed": 32, "preemptions": 0, "forced_steps": 0,
    "ttft_mean_s": 0.40490060818929274,
    "binding_axes": {"hbm": 6, "net": 25}}
NET_AWARE_2R_NODE_STEPS = {0: 207, 1: 196}


def _pin(out, golden):
    for k, v in golden.items():
        if isinstance(v, float):
            assert out[k] == pytest.approx(v, rel=1e-12), k
        else:
            assert out[k] == v, k


def _golden_engine(**kw):
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           extra_axes={"net": 0.1})
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 8.0, net=0.25)
    return Engine(make_requests(32, seed=3, rate=50.0), demand, budget,
                  replicas=2, router="net-aware", max_batch=16, **kw)


def test_no_topology_default_matches_pretopology_golden():
    eng = _golden_engine()
    assert eng.topology is None
    out = eng.run()
    _pin(out, NET_AWARE_2R_GOLDEN)
    assert out["node_steps"] == NET_AWARE_2R_NODE_STEPS


def test_attached_but_inert_topology_changes_nothing():
    """A bound fabric with no ingress payload and no migration must
    reproduce the topology=None schedule bit-for-bit (the gen-counted
    step events are a pure re-encoding)."""
    eng = _golden_engine(
        topology=get_topology("single-switch", nodes=2))
    assert eng.topology is not None
    out = eng.run()
    _pin(out, NET_AWARE_2R_GOLDEN)
    assert out["node_steps"] == NET_AWARE_2R_NODE_STEPS
    assert out["migrations"] == 0
    assert eng.topology.completed() == []


# --- the batch simulator's staging path --------------------------------------

@pytest.mark.parametrize("topology", ["", "single-switch"])
def test_simulator_staging_only_with_topology(topology):
    from repro.core import (MoEPredictor, SimConfig, Simulator,
                            spark_sim_suite, training_apps)
    from repro.core.simulator import OursPolicy
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    jobs = [(apps[i], 30.0) for i in (0, 5)]
    base = Simulator(jobs, OursPolicy(moe),
                     SimConfig(n_hosts=2), seed=3).run()
    sim = Simulator(jobs, OursPolicy(moe),
                    SimConfig(n_hosts=2, topology=topology,
                              stage_gb_per_item=5e-4,
                              topology_gbps=0.5), seed=3)
    out = sim.run()
    if not topology:
        assert sim.topology is None
        for k in ("stp", "antt", "makespan"):
            assert out[k] == base[k], k        # "" stays bit-identical
    else:
        assert sim.topology is not None
        assert len(sim.topology.completed("stage")) > 0
        assert out["makespan"] >= base["makespan"]
